"""Quickstart: the paper's experiment in 40 lines.

Compares the four transport mechanisms on a single-client ResNet50 serving
pipeline (paper Fig. 5/6) and prints the per-stage latency breakdown that
off-the-shelf serving systems don't expose.

Run: PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    TABLE_II,
    ScenarioConfig,
    Transport,
    local_reference,
    run_scenario,
)

WORKLOAD = TABLE_II["resnet50"]

print(f"{'transport':10s} {'total':>9s} {'request':>9s} {'copy':>9s} "
      f"{'preproc':>9s} {'infer':>9s} {'response':>9s}")

loc = local_reference(ScenarioConfig(workload=WORKLOAD)) * 1e3
print(f"{'local':10s} {loc:8.2f}ms {'-':>9s} {'-':>9s} {'':>9s} {'':>9s} {'-':>9s}")

for transport in (Transport.GDR, Transport.RDMA, Transport.TCP):
    store = run_scenario(ScenarioConfig(workload=WORKLOAD, transport=transport))
    m = store.stage_means()
    total = store.summary()["mean"] * 1e3
    print(
        f"{transport.value:10s} {total:8.2f}ms "
        f"{m['request']*1e3:8.3f}m {m['copy_in']*1e3+m['copy_out']*1e3:8.3f}m "
        f"{m['preprocess']*1e3:8.3f}m {m['inference']*1e3:8.3f}m "
        f"{m['response']*1e3:8.3f}m"
    )

tcp = run_scenario(ScenarioConfig(workload=WORKLOAD, transport=Transport.TCP))
gdr = run_scenario(ScenarioConfig(workload=WORKLOAD, transport=Transport.GDR))
save = 1 - gdr.summary()["mean"] / tcp.summary()["mean"]
print(f"\nGDR saves {save:.1%} of end-to-end latency vs TCP "
      f"(paper: 15-50% across setups)")
