"""Train a ~small LM for a few hundred steps on CPU (full substrate demo:
data pipeline -> AdamW -> checkpointing -> restore).

Run: PYTHONPATH=src python examples/train_tiny.py [--steps 200] [--arch mamba2-130m]
"""

import argparse
import tempfile

from repro.configs import get_config
from repro.models import Model
from repro.training import AdamWConfig, DataConfig, TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg, remat=True)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        params, opt, hist = train(
            model,
            DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=0),
            TrainConfig(steps=args.steps, log_every=20,
                        ckpt_every=max(args.steps // 2, 1), ckpt_dir=ckpt_dir),
            AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        )
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.1 else 'check hyperparameters'})")


if __name__ == "__main__":
    main()
