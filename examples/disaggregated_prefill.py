"""Disaggregated prefill->decode serving: the paper's proxied-connection
study mapped onto a modern LLM serving pattern (DESIGN.md §2).

Pod 0 runs admission+prefill, the last pod owns the decode slot pool —
and with per-pod placement (the default) each stage's params and jitted
compute are COMMITTED to its own pod slice, so the handoff collective is
the only cross-slice hop; each admitted request's VALID KV PREFIX (plus
its slot metadata) crosses the pod boundary through
``core.transfer.kv_transfer`` under the deployment's mechanism —
DIRECT_HBM = GPUDirect, DIRECT_DMA = RDMA, HOST_STAGED = TCP
(int8-requantized with per-source-pod scales). The collective moves only
the admitted rows sliced to their prefix blocks — not the max_batch x
max_seq pool tree — and the decode side grows the landed prefix back to
the ring width after the wire. Runs end to end on 8 forced host devices
(2-pod mesh) and prints, per mechanism: wire bytes (vs the padded
admission tree the pre-fix handoff moved), the per-request handoff charge
folded into TTFT, and decode-token fidelity vs a single fused engine.

Run: PYTHONPATH=src python examples/disaggregated_prefill.py
"""

import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_config
from repro.core.transfer import MODE_TRANSPORT, TransferMode
from repro.models import Model
from repro.serving import DisaggregatedEngine, ServingEngine, make_pod_mesh
from repro.serving.request import Request


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


def drain(eng, cfg, lens):
    reqs = _requests(cfg, lens)
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained()
    assert len(out) == len(reqs)
    by_id = {r.request_id: r for r in out}
    return [tuple(by_id[r.request_id].tokens) for r in reqs], [
        by_id[r.request_id] for r in reqs
    ]


def main():
    cfg = get_config("llama3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    mesh = make_pod_mesh()
    lens = [6, 11, 19, 27]
    kw = dict(max_batch=2, max_seq=64)

    print(f"{cfg.name}: {len(jax.devices())} host devices, "
          f"{mesh.shape['pod']}-pod mesh (prefill pod 0 -> decode pod "
          f"{mesh.shape['pod'] - 1})")
    base_tokens, _ = drain(ServingEngine(model, params, **kw), cfg, lens)

    shown = False
    for mode in TransferMode:
        eng = DisaggregatedEngine(
            model, params, transfer_mode=mode, mesh=mesh, **kw
        )
        if not shown:  # per-pod placement (default): stage -> device slice
            pl = eng.placement
            print(f"  placement: prefill on {pl.prefill_devices()}, decode "
                  f"pool on {pl.decode_devices()} "
                  f"({'disjoint two-pool split' if pl.disjoint else 'degenerate shared slice'})")
            shown = True
        tokens, rsps = drain(eng, cfg, lens)
        match = sum(a == b for a, b in zip(tokens, base_tokens)) / len(tokens)
        recs = eng.store.records
        charge = sum(r.stage_s.get("transfer", 0.0) for r in recs) / len(recs)
        # what the pre-fix handoff put on the wire per admission: the full
        # max_batch x max_seq pool tree + full-width slot metadata
        padded = eng.handoffs * eng.padded_tree_wire_bytes()
        print(f"  {mode.value:12s} ({MODE_TRANSPORT[mode].value:4s}): "
              f"{eng.handoff_wire_bytes / 1e3:7.1f} KB on the wire over "
              f"{eng.handoffs} handoffs "
              f"({eng.handoff_wire_bytes / padded:.0%} of the padded "
              f"admission trees); "
              f"{charge * 1e6:7.1f} us/request handoff charge; "
              f"tokens vs fused engine: {match:.0%}")
    print("\ntakeaway: the wire carries only the admitted rows' valid KV "
          "prefix (the paper's 'useful\npayload'), so handoff bytes track "
          "prompt lengths, not pool capacity. DIRECT_HBM (GDR\nanalogue) "
          "lands the full-precision cache in decode-pod HBM with zero "
          "staging copies and\nstays bit-exact; HOST_STAGED pays "
          "requantization + staging copies + CPU — the paper's\n"
          "protocol-translation trade (finding 2), now measured on the "
          "live serving path.")


if __name__ == "__main__":
    main()
