"""Disaggregated prefill->decode: the paper's proxied-connection study mapped
onto a modern LLM serving pattern (DESIGN.md §2).

Pod 0 runs prefill, pod 1 decodes; the KV cache crosses the pod boundary via
``core.transfer.kv_transfer`` in each of the three modes (DIRECT_HBM = GDR,
DIRECT_DMA = RDMA, HOST_STAGED = TCP). Runs on 8 forced host devices
(2 pods x 2 data x 2 model) and reports per-mode wire bytes + the modeled
transfer latency on both calibration profiles.

Run: PYTHONPATH=src python examples/disaggregated_prefill.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import AxisType

from repro.configs import get_config
from repro.core.transfer import TransferMode, kv_transfer, transfer_bytes
from repro.core.transport import PAPER_A2, TPU_V5E, Transport
from repro.models import Model


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)
    cfg = get_config("llama3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    B, S = 2, 32
    toks = jnp.asarray(np.arange(B * S).reshape(B, S) % cfg.vocab_size, jnp.int32)
    _, caches, _ = model.prefill(params, {"tokens": toks})

    # tile the cache across pods: leaf -> [npods, ...] (pod-sharded)
    tiled = jax.tree.map(lambda x: jnp.stack([x, jnp.zeros_like(x)]), caches)

    print(f"prefill produced KV cache for {cfg.name}: "
          f"{sum(l.nbytes for l in jax.tree.leaves(caches))/1e6:.2f} MB/sequence-batch")
    with mesh:
        for mode in TransferMode:
            moved = kv_transfer(tiled, mesh, mode=mode)
            jax.block_until_ready(moved)
            # pod1 must now hold pod0's cache (ring 0->1)
            got = jax.tree.leaves(moved)[0][1]
            want = jax.tree.leaves(tiled)[0][0]
            if mode is not TransferMode.HOST_STAGED:  # staged is int8-lossy
                np.testing.assert_allclose(
                    np.asarray(got, np.float32), np.asarray(want, np.float32),
                    atol=1e-6,
                )
            nbytes = transfer_bytes(tiled, mode)
            t_a2 = PAPER_A2.wire_time(
                {TransferMode.DIRECT_HBM: Transport.GDR,
                 TransferMode.DIRECT_DMA: Transport.RDMA,
                 TransferMode.HOST_STAGED: Transport.TCP}[mode], nbytes)
            t_tpu = TPU_V5E.wire_time(
                {TransferMode.DIRECT_HBM: Transport.GDR,
                 TransferMode.DIRECT_DMA: Transport.RDMA,
                 TransferMode.HOST_STAGED: Transport.TCP}[mode], nbytes)
            extra = "" if mode is not TransferMode.DIRECT_DMA else " + copy-engine hop"
            print(f"  {mode.value:12s}: {nbytes/1e6:7.2f} MB on the wire; "
                  f"modeled {t_a2*1e3:7.2f} ms (25GbE A2) / "
                  f"{t_tpu*1e3:6.2f} ms (v5e DCN){extra}")
    print("\ntakeaway: DIRECT_HBM (GDR analogue) moves the full-precision cache "
          "with zero staging copies;\nHOST_STAGED pays requantization + staging "
          "— the paper's protocol-translation trade (finding 2).")


if __name__ == "__main__":
    main()
