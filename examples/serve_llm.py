"""End-to-end driver: serve a small LLM with batched requests (REAL compute).

A reduced llama3-family model is served through the continuous-batching
engine with 4 concurrent closed-loop clients; transports are swapped to show
the paper's effect on a REAL JAX inference pipeline (compute measured on this
machine, wires modeled by the calibrated profile).

Run: PYTHONPATH=src python examples/serve_llm.py [--arch llama3-8b] [--clients 4]
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.core.transport import Transport
from repro.models import Model
from repro.serving import ClosedLoopClient, ServingEngine, run_closed_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    print(f"serving {cfg.name} ({cfg.family}), vocab={cfg.vocab_size}")

    for transport in (Transport.GDR, Transport.RDMA, Transport.TCP):
        engine = ServingEngine(
            model, params, max_batch=4, max_seq=96, transport=transport
        )
        clients = [
            ClosedLoopClient(i, cfg.vocab_size, prompt_len=16,
                             max_new_tokens=args.new_tokens)
            for i in range(args.clients)
        ]
        t0 = time.perf_counter()
        run_closed_loop(engine, clients, requests_per_client=args.requests)
        wall = time.perf_counter() - t0
        n = sum(len(c.completed) for c in clients)
        s = engine.store
        stages = {k: round(v * 1e3, 3) for k, v in s.stage_means().items() if v}
        print(f"  {transport.value:5s}: {n} requests in {wall:.1f}s wall; "
              f"modeled transport+copy stages (ms): "
              f"req={stages.get('request', 0)} copy_in={stages.get('copy_in', 0)} "
              f"rsp={stages.get('response', 0)}")


if __name__ == "__main__":
    main()
