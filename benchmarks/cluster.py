"""Multi-replica cluster sweep: router policy x arrival rate x transfer
mechanism — the repo's first tail-latency trajectory.

Drives 2-replica clusters of real-compute engines (fused ServingEngine
replicas, and DisaggregatedEngine replicas whose internal prefill->decode
handoff runs under each TransferMode) with the open-loop Poisson and
trace-replay generators from ``serving/loadgen.py``, on 4 forced host
devices so every replica owns its own pod slice. Reports warmup-aware
p50/p95/p99 TTFT / TPOT / E2E / queue percentiles
(``core.metrics.slo_summary``) plus per-replica occupancy and Jain
balance indices per (policy, rate, mechanism) cell.

The skewed trace is the paper's load-balancing claim in miniature: one
long-budget decode arriving periodically among cheap requests. Blind
round-robin parks cheap requests behind the long decode (head-of-line
blocking: their 'queue' stage absorbs a full heavy service), while
queue/work-aware policies route around the busy replica. Asserted in CI
(--quick): jsq and least_loaded undercut round_robin's p99 TTFT, the
'queue' stage accounts for the difference (prefill/decode costs are
policy-independent), busy-slot balance improves, per-policy handoff
request bytes are conserved on disaggregated replicas (routing moves
requests, not bytes), and a 2-replica DIRECT_HBM/DIRECT_DMA cluster is
token-identical to the same requests on independent engines.

A deliberate caveat for reading the numbers: in the policy/rate sweeps
the replicas time-share one physical test CPU inside one interpreter
(``workload.parallelism = "sequential-in-process"``), so balancing cannot
raise aggregate throughput there (a balanced pair runs each other's
steps slower); what it CAN do — and what the assertions pin — is
eliminate head-of-line queueing, which is a latency-tail property, not a
capacity one. The ``process_cluster`` section is the counterpart with
that caveat REMOVED: 2 replicas as real OS processes behind the socket
RPC control plane (``parallelism = "process-per-replica"``), timed
sequential-vs-concurrent, with token identity and byte conservation
pinned against the in-process baseline. On hosts with >= 2 CPUs the
concurrent drain must beat 0.75x the sequential sum; on a 1-CPU host the
honest ~1.0 ratio is recorded with ``parallel_capacity_asserted:
false``.

Usage: PYTHONPATH=src python -m benchmarks.cluster [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

# 4 forced host devices: enough for 2 disaggregated replicas (2 pods
# each) while keeping XLA's per-device runtime threads from thrashing the
# small CI hosts this benchmark must stay stable on
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

# workload scale: ONE long-budget decode at t=0 among a stream of cheap
# requests. The arrival gap is CALIBRATED (see calibrate_gap) to a
# multiple of the measured light service time, which makes the two load
# ratios that the assertions depend on machine-speed-invariant: the heavy
# decode spans many gaps (blind routing provably parks lights behind it),
# and the light stream stays far below one replica's service rate (the
# dodging replica never saturates). A single heavy per trace means heavy
# arrivals can never collide with each other, however slow the host.
HEAVY_NEW = 192
LIGHT_NEW = 2
GAP_FLOOR_S = 0.03
GAP_LIGHT_MULT = 8.0  # offered light load ~1/8 of one replica's capacity
WARMUP_DROP = 2  # completions dropped from percentiles (cold-start aware)


def skewed_trace(n_req: int, gap_s: float, *, heavy_len: int = 24,
                 light_len: int = 8) -> list:
    """Open-loop trace entries: one heavy-budget request at position 0
    (even, so 2-replica round-robin parity routes half the light stream
    onto its replica), lights every ``gap_s`` after."""
    return [
        {
            "t": round(i * gap_s, 6),
            "prompt_len": heavy_len if i == 0 else light_len,
            "max_new": HEAVY_NEW if i == 0 else LIGHT_NEW,
        }
        for i in range(n_req)
    ]


def calibrate_gap(model, params, cfg) -> float:
    """Measure one warmed replica's light-request service wall and return
    the arrival gap ``GAP_LIGHT_MULT`` times it (floored at
    ``GAP_FLOOR_S``). Calibrating the offered load to the machine keeps
    the skewed-trace comparison meaningful on any host: the absolute
    times in BENCH_cluster.json scale with the hardware, the RATIOS the
    assertions pin do not."""
    from benchmarks.serving import make_requests
    from repro.serving import ServingEngine

    eng = ServingEngine(model, params, max_batch=1, max_seq=128, warmup=True)
    reqs = make_requests(cfg, [8] * 6, LIGHT_NEW, seed=3)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, time.perf_counter())
    assert len(eng.run_until_drained(max_steps=100_000)) == len(reqs)
    light_s = (time.perf_counter() - t0) / len(reqs)
    return max(GAP_FLOOR_S, GAP_LIGHT_MULT * light_s)


def build_cluster(model, params, *, mechanism: str, policy: str,
                  n_replicas: int = 2, warmup: bool = True, **kw):
    from repro.core.transfer import TransferMode
    from repro.serving import ServingCluster

    if mechanism == "fused":
        return ServingCluster.build(
            model, params, n_replicas=n_replicas, engine="fused",
            policy=policy, warmup=warmup, **kw,
        )
    return ServingCluster.build(
        model, params, n_replicas=n_replicas, engine="disagg",
        policy=policy, warmup=warmup,
        transfer_mode=TransferMode(mechanism), charge="modeled", **kw,
    )


def run_case(model, params, *, mechanism: str, policy: str, schedule,
             **kw) -> dict:
    from repro.serving import run_open_loop

    cl = build_cluster(model, params, mechanism=mechanism, policy=policy,
                       **kw)
    t0 = time.perf_counter()
    out = run_open_loop(cl, schedule)
    wall = time.perf_counter() - t0
    assert len(out) == len(schedule), (len(out), len(schedule))
    tele = cl.telemetry(warmup=WARMUP_DROP)
    row = {
        "wall_s": round(wall, 3),
        "slo": {
            k: {p: round(v[p], 5) for p in ("p50", "p95", "p99", "mean")}
            for k, v in tele["slo"].items() if k.endswith("_s")
        },
        "per_replica": tele["per_replica"],
        "balance_index_busy": tele["balance_index_busy"],
        "balance_index_routed": tele["balance_index_routed"],
    }
    if mechanism != "fused":
        row["handoff_wire_bytes"] = sum(
            rep.engine.handoff_wire_bytes for rep in cl.replicas
        )
        row["handoff_request_bytes"] = sum(
            rep.engine.handoff_request_bytes for rep in cl.replicas
        )
    return row


# --------------------------------------------------------------------------- #
def bench_skewed(model, params, cfg, *, mechanisms, policies, n_req,
                 base_gap) -> dict:
    """Policy comparison on the skewed trace — the acceptance claims.

    The trace goes through save_trace/load_trace so the trace-file
    arrival path is exercised end to end."""
    from repro.serving import load_trace, save_trace, trace_schedule

    out = {"trace": {"n_requests": n_req, "heavy_new": HEAVY_NEW,
                     "light_new": LIGHT_NEW, "base_gap_s": round(base_gap, 4)}}
    for mech in mechanisms:
        # disaggregated replicas pay a per-admission handoff, so their
        # light-request service is slower: space arrivals out so the
        # light replica keeps up and the comparison isolates head-of-line
        # blocking rather than saturation backlog
        gap = base_gap if mech == "fused" else 2.0 * base_gap
        entries = skewed_trace(n_req, gap)
        with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                         delete=False) as f:
            path = f.name
        save_trace(path, entries)
        try:
            loaded = load_trace(path)
            assert loaded == entries
            rows = {}
            for policy in policies:
                sched = trace_schedule(loaded, cfg.vocab_size, seed=17)
                # max_batch=1: one decode slot per replica, so a request
                # routed behind the heavy decode genuinely blocks — the
                # head-of-line regime the policy comparison is about
                # (max_batch=2 would hide it in the spare slot).
                # max_seq=256 keeps the heavy's prompt + budget inside the
                # KV ring (no wraparound mid-decode)
                rows[policy] = run_case(
                    model, params, mechanism=mech, policy=policy,
                    schedule=sched, max_batch=1, max_seq=256,
                )
        finally:
            os.unlink(path)
        out[mech] = {"gap_s": gap, **rows}

        rr = rows["round_robin"]["slo"]
        for policy in ("jsq", "least_loaded"):
            if policy not in rows:
                continue
            pol = rows[policy]["slo"]
            # the load-aware policies undercut blind rotation on tail
            # TTFT...
            assert pol["ttft_s"]["p99"] < rr["ttft_s"]["p99"], (
                mech, policy, pol, rr)
            # ...and the pre-admission queue stage accounts for the
            # difference (prefill/decode/transfer costs are
            # policy-independent)
            ttft_gain = rr["ttft_s"]["p99"] - pol["ttft_s"]["p99"]
            queue_gain = rr["queue_s"]["p99"] - pol["queue_s"]["p99"]
            assert queue_gain >= 0.5 * ttft_gain, (
                mech, policy, queue_gain, ttft_gain)
        # balance assertion: spreading the heavies balances busy-slot
        # time across replicas
        assert (rows["jsq"]["balance_index_busy"]
                >= rows["round_robin"]["balance_index_busy"]), rows
        if mech != "fused":
            # routing conservation: the same request set moves the same
            # useful prefix bytes across the pod boundary under every
            # policy — the router relocates requests, not bytes
            sizes = {p: rows[p]["handoff_request_bytes"] for p in rows}
            assert len(set(sizes.values())) == 1, sizes
            assert min(sizes.values()) > 0, sizes
    return out


def bench_rates(model, params, cfg, *, mechanisms, policies, rates,
                n_req) -> dict:
    """Open-loop Poisson sweep: policy x arrival rate x mechanism, the
    BENCH_cluster.json tail-latency grid."""
    from repro.serving import poisson_schedule

    out = {}
    for mech in mechanisms:
        out[mech] = {}
        for rate in rates:
            rows = {}
            for policy in policies:
                sched = poisson_schedule(
                    cfg.vocab_size, rate_rps=rate, n_requests=n_req,
                    prompt_lens=(8, 16, 32, 64), max_new=8, seed=23,
                )
                rows[policy] = run_case(
                    model, params, mechanism=mech, policy=policy,
                    schedule=sched, max_batch=2, max_seq=128,
                )
            out[mech][f"{rate}rps"] = rows
    return out


def bench_token_identity(model, params, cfg) -> dict:
    """A 2-replica cluster must be numerically invisible: the same
    requests, split the way round-robin routes them, produce identical
    tokens on two standalone engines (full-precision mechanisms only —
    HOST_STAGED is int8-lossy by design)."""
    from benchmarks.serving import make_requests
    from repro.core.transfer import TransferMode
    from repro.serving import DisaggregatedEngine, ServingCluster

    lens = [7 + 11 * i for i in range(8)]
    kw = dict(max_batch=2, max_seq=128)
    out = {}
    for mode in (TransferMode.DIRECT_HBM, TransferMode.DIRECT_DMA):
        cl = ServingCluster.build(
            model, params, n_replicas=2, engine="disagg",
            policy="round_robin", transfer_mode=mode, charge="modeled", **kw,
        )
        cl_reqs = make_requests(cfg, lens, 6, seed=31)
        for r in cl_reqs:
            cl.submit(r, time.perf_counter())
        assert len(cl.run_until_drained(max_steps=100_000)) == len(lens)

        solo_reqs = make_requests(cfg, lens, 6, seed=31)
        for k in range(2):
            eng = DisaggregatedEngine(model, params, transfer_mode=mode,
                                      charge="modeled", **kw)
            for r in solo_reqs[k::2]:
                eng.submit(r, time.perf_counter())
            eng.run_until_drained(max_steps=100_000)
        match = [tuple(a.generated) for a in cl_reqs] == \
            [tuple(b.generated) for b in solo_reqs]
        assert match, f"cluster tokens diverged under {mode.value}"
        out[mode.value] = {"token_identical_vs_independent_engines": True,
                           "requests": len(lens)}
    return out


def bench_process_cluster(model, params, cfg, *, quick: bool,
                          trace_out: str = "BENCH_trace.json") -> dict:
    """Process-per-replica measurement: REAL parallelism, not modeled.

    Two worker processes (each its own XLA client on one forced host
    device) are warmed once, then timed two ways on identical saturating
    workloads: **sequential** — each replica drains its half of the
    requests alone, walls summed — and **concurrent** — the same volume
    split round-robin and both replicas draining simultaneously. On a
    host with >= 2 CPUs the concurrent wall must come in under 0.75x the
    sequential sum (asserted); on a single-CPU host the replicas
    time-share and the honest ratio (~1.0) is recorded with
    ``parallel_capacity_asserted: false`` plus a sanity bound — the same
    caveat discipline the in-process sweep's workload note uses.

    Also pins the correctness half of the backend swap: the seeded trace
    through ``backend="process"`` is token-identical to the in-process
    Router baseline, with request payload bytes conserved across the RPC
    wire and one record per request surviving the merge.

    The timed run doubles as the multi-process tracing smoke: tracing is
    on in the router AND both workers (worker spans ride back on the
    harvest/drain RPC replies and are rebased onto the router clock), and
    the merged timeline exports to ``trace_out`` as Chrome trace-event
    JSON — asserted non-empty, json-round-trippable, and containing spans
    from >= 2 distinct processes on the one rebased clock.
    """
    from repro.core import trace as rtrace
    from repro.serving import ServingCluster, poisson_schedule, run_open_loop

    n_cpus = len(os.sched_getaffinity(0))
    # saturating enough that the drain walls dwarf RPC/scheduler noise
    # (tiny walls would make the 0.75x assertion a coin flip on shared CI
    # runners); prompts + budget stay inside the max_seq=128 KV ring
    per_replica = 8 if quick else 16
    max_new = 96
    kw = dict(max_batch=2, max_seq=128)
    drain_deadline = 600.0

    def requests(seed):
        from benchmarks.serving import make_requests

        return make_requests(
            cfg, [8, 16, 24, 8, 16, 24][:per_replica] * (
                (per_replica + 5) // 6),
            max_new, seed=seed,
        )[:per_replica]

    # tracing on BEFORE build: the init spec carries the flag to the
    # workers, so both sides of the RPC emit spans for the timed drains
    rtrace.enable_tracing(process="router")
    with ServingCluster.build(
        model, params, n_replicas=2, engine="fused", policy="round_robin",
        backend="process", param_seed=0, warmup=True,
        rpc_timeout_s=300.0, **kw,
    ) as pc:
        # --- sequential: each replica alone, walls summed -------------- #
        seq_walls = []
        for k, rep in enumerate(pc.replicas):
            for r in requests(seed=40 + k):
                rep.submit(r)
            t0 = time.perf_counter()
            done = rep.drain(drain_deadline)
            seq_walls.append(time.perf_counter() - t0)
            assert len(done) == per_replica, (k, len(done))
        # --- concurrent: same volume, both replicas at once ------------ #
        for k in range(2):
            for r in requests(seed=50 + k):
                pc.replicas[k].submit(r)
                pc.replicas[k].routed += 1
        t0 = time.perf_counter()
        done = pc.drain(drain_deadline)
        concurrent_s = time.perf_counter() - t0
        assert len(done) == 2 * per_replica, len(done)
        tel = pc.telemetry()

    # --- merged-timeline export: the multi-process tracing smoke ------- #
    tr = rtrace.Trace.from_buffer()
    procs = tr.processes()
    assert len(procs) >= 2 and any(p.startswith("replica") for p in procs), (
        f"trace must span the router and >= 1 worker process: {procs}"
    )
    obj = tr.export_chrome(trace_out)
    with open(trace_out) as f:
        reloaded = json.load(f)  # must round-trip
    assert reloaded["traceEvents"] and obj["traceEvents"], "empty trace export"
    trace_row = {
        "path": trace_out,
        "processes": procs,
        "spans": len(tr),
        "events": len(obj["traceEvents"]),
        "dropped": rtrace.tracer().stats()["dropped"],
        "export_ok": True,  # asserted above
    }
    rtrace.disable_tracing()

    seq_sum = sum(seq_walls)
    ratio = concurrent_s / seq_sum
    can_assert = n_cpus >= 2
    if can_assert:
        # the acceptance bar: real concurrency, not interleaving
        assert ratio < 0.75, (
            f"concurrent drain {concurrent_s:.2f}s not < 0.75x sequential "
            f"sum {seq_sum:.2f}s on {n_cpus} CPUs (ratio {ratio:.2f})"
        )
    else:
        # single CPU: replicas time-share; concurrent can't beat
        # sequential, but it must not be materially WORSE either (RPC +
        # scheduling overhead stays small)
        assert ratio < 1.35, (
            f"single-CPU concurrent drain overhead too high: {ratio:.2f}"
        )

    # --- token identity + conservation vs the in-process baseline ------ #
    sched_kw = dict(rate_rps=200.0, n_requests=8, prompt_lens=(8, 16, 24),
                    max_new=4, seed=61)
    base = ServingCluster.build(model, params, n_replicas=2,
                                policy="round_robin", **kw)
    out_a = run_open_loop(base, poisson_schedule(cfg.vocab_size, **sched_kw))
    toks_a = {r.request_id: r.tokens for r in out_a}
    with ServingCluster.build(
        model, params, n_replicas=2, engine="fused", policy="round_robin",
        backend="process", param_seed=0, rpc_timeout_s=300.0, **kw,
    ) as pc2:
        out_b = run_open_loop(
            pc2, poisson_schedule(cfg.vocab_size, **sched_kw))
        toks_b = {r.request_id: r.tokens for r in out_b}
        tel2 = pc2.telemetry()
    identical = [toks_a[i] for i in sorted(toks_a)] == \
        [toks_b[i] for i in sorted(toks_b)]
    assert identical, "process backend diverged from in-process tokens"
    bytes_ok = all(
        row["request_payload_bytes"] == row["submitted_bytes"]
        for row in tel2["ipc"]
    )
    records_ok = (sum(r["emitted"] for r in tel2["ipc"]) == len(out_b)
                  and all(r["submitted"] == r["emitted"]
                          for r in tel2["ipc"]))
    assert bytes_ok and records_ok, tel2["ipc"]

    return {
        "parallelism": "process-per-replica",
        "cpus": n_cpus,
        "n_replicas": 2,
        "requests_per_replica": per_replica,
        "max_new": max_new,
        "sequential_drain_s": [round(w, 3) for w in seq_walls],
        "sequential_drain_sum_s": round(seq_sum, 3),
        "concurrent_drain_s": round(concurrent_s, 3),
        "concurrent_vs_sequential_ratio": round(ratio, 3),
        "parallel_capacity_asserted": can_assert,
        "token_identical_vs_inprocess": identical,
        "request_bytes_conserved": bytes_ok,
        "records_conserved": records_ok,
        "ipc": tel["ipc"],
        "trace": trace_row,
    }


def bench_cluster(quick: bool, *, trace_out: str = "BENCH_trace.json") -> dict:
    import jax

    from benchmarks.serving import micro_config
    from repro.models import Model

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    if quick:
        mechanisms = ["fused", "direct_hbm"]
        policies = ["round_robin", "jsq", "least_loaded"]
        rate_mechs = ["fused"]
        rates = [30]
        n_req = 20
    else:
        mechanisms = ["fused", "direct_hbm", "host_staged"]
        policies = ["round_robin", "jsq", "least_loaded", "affinity"]
        rate_mechs = mechanisms
        rates = [10, 30]
        n_req = 32

    base_gap = calibrate_gap(model, params, cfg)

    return {
        "workload": {
            "model": cfg.name, "backend": jax.default_backend(),
            "devices": len(jax.devices()), "n_replicas": 2,
            # rate sweep: continuous batching (max_batch=2, max_seq=128);
            # skewed trace: one slot per replica, ring sized to the heavy
            # budget (max_batch=1, max_seq=256)
            "max_batch": 2, "max_seq": 128,
            "warmup_dropped_from_percentiles": WARMUP_DROP,
            # the regime these rows measure: every replica stepped
            # sequentially inside ONE interpreter. The process_cluster
            # section below is the "process-per-replica" counterpart —
            # don't conflate the two when reading throughput.
            "parallelism": "sequential-in-process",
            "note": "replicas time-share one test CPU: the sweep measures "
                    "queueing/head-of-line latency effects, not parallel "
                    "capacity",
        },
        # the acceptance comparison: policy effects on a skewed trace
        "skewed_trace": bench_skewed(
            model, params, cfg, mechanisms=mechanisms, policies=policies,
            n_req=n_req, base_gap=base_gap,
        ),
        # the tail-latency grid: policy x arrival rate x mechanism
        "rate_sweep": bench_rates(
            model, params, cfg, mechanisms=rate_mechs, policies=policies,
            rates=rates, n_req=n_req,
        ),
        "token_identity": bench_token_identity(model, params, cfg),
        # the multiprocess smoke: real OS-process replicas behind the
        # socket RPC control plane, timed sequential-vs-concurrent
        "process_cluster": bench_process_cluster(
            model, params, cfg, quick=quick, trace_out=trace_out,
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI smoke)")
    ap.add_argument("--out", default="BENCH_cluster.json")
    ap.add_argument("--trace-out", default="BENCH_trace.json",
                    help="Chrome trace-event JSON export from the "
                         "process-cluster smoke (Perfetto-loadable)")
    args = ap.parse_args()

    result = {
        "benchmark": "multi-replica cluster: router policy x arrival rate "
                     "x transfer mechanism",
        "cluster": bench_cluster(args.quick, trace_out=args.trace_out),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))

    sk = result["cluster"]["skewed_trace"]
    for mech, rows in sk.items():
        if mech == "trace":
            continue
        print(f"\n# skewed trace [{mech}] p99 ttft/queue (ms): " + "; ".join(
            f"{p}: {r['slo']['ttft_s']['p99']*1e3:.0f}/"
            f"{r['slo']['queue_s']['p99']*1e3:.0f}"
            for p, r in rows.items() if isinstance(r, dict) and "slo" in r
        ))
    ident = result["cluster"]["token_identity"]
    print("# token identity vs independent engines: " + "; ".join(
        f"{m}: {'ok' if v['token_identical_vs_independent_engines'] else 'FAIL'}"
        for m, v in ident.items()
    ))
    proc = result["cluster"]["process_cluster"]
    print(
        f"# process-per-replica: concurrent {proc['concurrent_drain_s']}s "
        f"vs sequential sum {proc['sequential_drain_sum_s']}s "
        f"(ratio {proc['concurrent_vs_sequential_ratio']}, "
        f"{proc['cpus']} cpu(s), "
        f"capacity asserted: {proc['parallel_capacity_asserted']}); "
        f"tokens vs in-process: "
        f"{'ok' if proc['token_identical_vs_inprocess'] else 'FAIL'}"
    )
    trc = proc["trace"]
    print(f"# chrome trace: {trc['path']} ({trc['events']} events, "
          f"{trc['spans']} spans from processes {trc['processes']})")


if __name__ == "__main__":
    main()
