"""One function per paper table/figure. Each returns rows AND checks the
paper's corresponding quantitative claim, reporting PASS/FAIL deltas."""

from __future__ import annotations

from repro.core import TABLE_II, ScenarioConfig, Transport, local_reference, run_scenario
from repro.core.metrics import cov

from benchmarks.common import T_NET, emit, mean_ms, run_ms


def fig05_transport_single_client():
    """Fig. 5: ResNet50, direct connection, with/without preprocessing."""
    claims = []
    for pre, tag in ((False, "raw"), (True, "pre")):
        row = {t: run_ms("resnet50", t, preprocessed=pre) for t in T_NET}
        loc = run_ms("resnet50", Transport.LOCAL, preprocessed=pre)
        for t in T_NET:
            emit(f"fig05/resnet50/{tag}/{t.value}", row[t] * 1e3)
        emit(f"fig05/resnet50/{tag}/local", loc * 1e3)
        gdr_save = (row[Transport.TCP] - row[Transport.GDR]) / row[Transport.TCP]
        rdma_save = (row[Transport.TCP] - row[Transport.RDMA]) / row[Transport.TCP]
        # paper: GDR 20.3/23.2 % and RDMA 11.4/15.2 % less than TCP
        target_g, target_r = (23.2, 15.2) if tag == "raw" else (20.3, 11.4)
        claims.append((f"fig05 {tag}: GDR saves {gdr_save:.1%} (paper {target_g}%)",
                       abs(gdr_save * 100 - target_g) < 8))
        claims.append((f"fig05 {tag}: RDMA saves {rdma_save:.1%} (paper {target_r}%)",
                       abs(rdma_save * 100 - target_r) < 8))
        claims.append((f"fig05 {tag}: GDR-local = {row[Transport.GDR]-loc:.2f}ms (paper 0.27-0.53)",
                       0.1 < row[Transport.GDR] - loc < 0.8))
    return claims


def fig06_breakdown():
    """Fig. 6: stage breakdown, ResNet50 — the whole delta is data movement."""
    claims = []
    for t in T_NET:
        s = run_scenario(ScenarioConfig(workload=TABLE_II["resnet50"], transport=t))
        means = s.stage_means()
        for stage, v in means.items():
            if v:
                emit(f"fig06/resnet50/{t.value}/{stage}", v * 1e6)
    s_tcp = run_scenario(ScenarioConfig(workload=TABLE_II["resnet50"], transport=Transport.TCP))
    s_gdr = run_scenario(ScenarioConfig(workload=TABLE_II["resnet50"], transport=Transport.GDR))
    dm = lambda s: sum(r.data_movement for r in s.records) / len(s.records)
    pr = lambda s: sum(r.processing for r in s.records) / len(s.records)
    claims.append(("fig06: TCP-GDR delta is data movement (processing ~equal)",
                   abs(pr(s_tcp) - pr(s_gdr)) < 0.3e-3 and dm(s_tcp) > dm(s_gdr)))
    return claims


def fig07_overhead_vs_local():
    """Fig. 7: offload overhead vs local across the six models."""
    claims = []
    over = {}
    for pre, tag in ((False, "raw"), (True, "pre")):
        for w in TABLE_II:
            loc = run_ms(w, Transport.LOCAL, preprocessed=pre)
            for t in T_NET:
                o = (run_ms(w, t, preprocessed=pre) - loc) / loc
                emit(f"fig07/{w}/{tag}/{t.value}", o * 1e6, "overhead_ppm")
                over[(w, tag, t)] = o
    claims.append(("fig07: mobilenet overhead > wideresnet101 overhead (all transports)",
                   all(over[("mobilenetv3", g, t)] > over[("wideresnet101", g, t)]
                       for g in ("raw", "pre") for t in T_NET)))
    claims.append(("fig07 pre: wideresnet101 overhead ~2% (paper)",
                   over[("wideresnet101", "pre", Transport.GDR)] < 0.06))
    claims.append(("fig07: large-I/O deeplab overhead high with TCP (paper: very high)",
                   over[("deeplabv3", "raw", Transport.TCP)] > 0.4))
    return claims


def fig08_stage_fractions():
    """Fig. 8: fraction of time in data movement per transport."""
    claims = []
    fr = {}
    for w in ("mobilenetv3", "wideresnet101", "deeplabv3"):
        for t in T_NET:
            s = run_scenario(ScenarioConfig(workload=TABLE_II[w], transport=t))
            f = sum(r.data_movement for r in s.records) / sum(r.total for r in s.records)
            fr[(w, t)] = f
            emit(f"fig08/{w}/{t.value}/data_movement_fraction", f * 1e6, "ppm")
    claims.append(("fig08: mobilenet TCP fraction > RDMA > GDR (paper 62/42/30%)",
                   fr[("mobilenetv3", Transport.TCP)] > fr[("mobilenetv3", Transport.RDMA)]
                   > fr[("mobilenetv3", Transport.GDR)]))
    claims.append(("fig08: wideresnet fraction < 12% all transports (paper <10%)",
                   all(fr[("wideresnet101", t)] < 0.12 for t in T_NET)))
    claims.append(("fig08: deeplab TCP ~60% vs GDR ~23% (paper)",
                   fr[("deeplabv3", Transport.TCP)] > 0.35
                   and fr[("deeplabv3", Transport.GDR)] < 0.30))
    return claims


def fig09_cpu_usage():
    claims = []
    cpu = {}
    for t in T_NET:
        s = run_scenario(ScenarioConfig(workload=TABLE_II["deeplabv3"], transport=t))
        cpu[t] = s.cpu_per_request()
        emit(f"fig09/deeplabv3/{t.value}/cpu", cpu[t] * 1e6)
    claims.append(("fig09: TCP CPU ~2x GDR on deeplab (paper: +100%)",
                   cpu[Transport.TCP] > 1.8 * max(cpu[Transport.GDR], 1e-9)))
    return claims


def fig10_proxied_single():
    """Fig. 10: proxied connection, MobileNetV3 raw, single client."""
    claims = []
    combos = [("rdma", "gdr"), ("rdma", "rdma"), ("tcp", "gdr"), ("tcp", "rdma"),
              ("tcp", "tcp")]
    res = {}
    for first, second in combos:
        s = run_scenario(ScenarioConfig(
            workload=TABLE_II["mobilenetv3"],
            transport=Transport(second), first_hop=Transport(first)))
        res[(first, second)] = mean_ms(s)
        emit(f"fig10/mobilenetv3/{first}-{second}", res[(first, second)] * 1e3)
    save_rdma = 1 - res[("tcp", "rdma")] / res[("tcp", "tcp")]
    save_gdr = 1 - res[("tcp", "gdr")] / res[("tcp", "tcp")]
    claims.append((f"fig10: TCP/RDMA saves {save_rdma:.0%} vs TCP/TCP (paper 23%)",
                   0.05 < save_rdma < 0.45))
    claims.append((f"fig10: TCP/GDR saves {save_gdr:.0%} vs TCP/TCP (paper 57%)",
                   0.25 < save_gdr < 0.70))
    return claims


def fig11_scalability():
    """Fig. 11: total time vs #clients, raw images."""
    claims = []
    res = {}
    for w in ("mobilenetv3", "deeplabv3"):
        for n in (1, 4, 8, 16):
            for t in T_NET:
                s = run_scenario(ScenarioConfig(
                    workload=TABLE_II[w], transport=t, n_clients=n,
                    requests_per_client=40))
                res[(w, n, t)] = mean_ms(s)
                emit(f"fig11/{w}/n{n}/{t.value}", res[(w, n, t)] * 1e3)
    claims.append(("fig11: GDR best at 16 clients on both models",
                   all(res[(w, 16, Transport.GDR)] < res[(w, 16, Transport.RDMA)]
                       and res[(w, 16, Transport.GDR)] < res[(w, 16, Transport.TCP)]
                       for w in ("mobilenetv3", "deeplabv3"))))
    claims.append(("fig11: RDMA edge collapses at 16 clients on deeplab (paper: =TCP)",
                   res[("deeplabv3", 16, Transport.RDMA)] / res[("deeplabv3", 16, Transport.TCP)] > 0.85))
    claims.append((f"fig11: GDR saves {res[('deeplabv3',16,Transport.TCP)]-res[('deeplabv3',16,Transport.GDR)]:.0f}ms on deeplab@16 (paper 160ms)",
                   res[("deeplabv3", 16, Transport.TCP)] - res[("deeplabv3", 16, Transport.GDR)] > 25))
    return claims


def fig12_13_breakdown_scaling():
    """Figs. 12-13: stage-fraction evolution with #clients."""
    claims = []
    frac = {}
    for w in ("mobilenetv3", "deeplabv3"):
        for t in T_NET:
            for n in (1, 16):
                s = run_scenario(ScenarioConfig(
                    workload=TABLE_II[w], transport=t, n_clients=n,
                    requests_per_client=40))
                tot = sum(r.total for r in s.records)
                proc = sum(r.processing for r in s.records) / tot
                copy = sum(r.copy_time for r in s.records) / tot
                frac[(w, t, n)] = (proc, copy)
                emit(f"fig12/{w}/{t.value}/n{n}/processing", proc * 1e6, "ppm")
                emit(f"fig12/{w}/{t.value}/n{n}/copy", copy * 1e6, "ppm")
    claims.append(("fig12: mobilenet processing fraction rises with clients (GDR)",
                   frac[("mobilenetv3", Transport.GDR, 16)][0]
                   > frac[("mobilenetv3", Transport.GDR, 1)][0]))
    claims.append(("fig13: deeplab copy fraction rises with clients (RDMA, paper 12->28%)",
                   frac[("deeplabv3", Transport.RDMA, 16)][1]
                   > frac[("deeplabv3", Transport.RDMA, 1)][1]))
    return claims


def fig14_proxied_scaling():
    """Fig. 14: proxied configs under concurrency."""
    claims = []
    res = {}
    combos = [("rdma", "gdr"), ("rdma", "rdma"), ("tcp", "gdr"), ("tcp", "rdma"),
              ("tcp", "tcp")]
    for first, second in combos:
        s = run_scenario(ScenarioConfig(
            workload=TABLE_II["mobilenetv3"], transport=Transport(second),
            first_hop=Transport(first), n_clients=16, requests_per_client=40))
        res[(first, second)] = mean_ms(s)
        emit(f"fig14/mobilenetv3/n16/{first}-{second}", res[(first, second)] * 1e3)
    claims.append(("fig14: TCP/GDR beats RDMA/RDMA under concurrency (paper)",
                   res[("tcp", "gdr")] < res[("rdma", "rdma")]))
    claims.append(("fig14: last-hop GDR within 45% of RDMA/GDR (paper 4%; see EXPERIMENTS §Deviations)",
                   (res[("tcp", "gdr")] - res[("rdma", "gdr")]) / res[("rdma", "gdr")] < 0.45))
    claims.append(("fig14: TCP/TCP ~ TCP/RDMA ~ RDMA/RDMA (copy-engine bound, paper)",
                   res[("tcp", "rdma")] / res[("tcp", "tcp")] > 0.8))
    return claims


def fig15_concurrency_limit():
    """Fig. 15: limiting concurrent execution (streams), ResNet50."""
    claims = []
    tot = {}
    cv = {}
    for ns in (1, 2, 4, 8, 16):
        for t in (Transport.GDR, Transport.RDMA):
            s = run_scenario(ScenarioConfig(
                workload=TABLE_II["resnet50"], transport=t, n_clients=16,
                requests_per_client=40, max_streams=ns))
            tot[(ns, t)] = mean_ms(s)
            cv[(ns, t)] = s.processing_cov()
            emit(f"fig15/resnet50/streams{ns}/{t.value}", tot[(ns, t)] * 1e3)
            emit(f"fig15/resnet50/streams{ns}/{t.value}/cov", cv[(ns, t)] * 1e6, "ppm")
    claims.append((f"fig15: 1 stream {100*(tot[(1,Transport.GDR)]/tot[(16,Transport.GDR)]-1):.0f}% slower than 16 (paper 33%)",
                   1.1 < tot[(1, Transport.GDR)] / tot[(16, Transport.GDR)] < 2.0))
    claims.append(("fig15: latency decreases monotonically-ish with streams (GDR)",
                   tot[(1, Transport.GDR)] > tot[(4, Transport.GDR)] >= tot[(16, Transport.GDR)] * 0.95))
    claims.append(("fig15: GDR beats RDMA at 16 streams",
                   tot[(16, Transport.GDR)] < tot[(16, Transport.RDMA)]))
    claims.append(("fig15c: limited concurrency -> lower processing CoV",
                   cv[(1, Transport.GDR)] <= cv[(16, Transport.GDR)] + 1e-6))
    return claims


def fig16_priority():
    """Fig. 16: one priority client among normals, YoloV4 preprocessed."""
    claims = []
    res = {}
    for n in (2, 4, 8, 16):
        for t in (Transport.GDR, Transport.RDMA):
            s = run_scenario(ScenarioConfig(
                workload=TABLE_II["yolov4"], transport=t, preprocessed=True,
                n_clients=n, n_priority_clients=1, requests_per_client=30))
            hi = s.summary(priority=1)["mean"] * 1e3
            lo = s.summary(priority=0)["mean"] * 1e3
            res[(n, t)] = (hi, lo)
            emit(f"fig16/yolov4/n{n}/{t.value}/priority", hi * 1e3)
            emit(f"fig16/yolov4/n{n}/{t.value}/normal", lo * 1e3)
    claims.append(("fig16: GDR priority client protected at n=16 (paper: 54ms << normal)",
                   res[(16, Transport.GDR)][0] < 0.7 * res[(16, Transport.GDR)][1]))
    claims.append(("fig16: RDMA protection weaker than GDR at n=16 (copy engine)",
                   res[(16, Transport.RDMA)][0] / res[(16, Transport.RDMA)][1]
                   > res[(16, Transport.GDR)][0] / res[(16, Transport.GDR)][1]))
    claims.append(("fig16: priority latency ~flat until 8 clients (GDR)",
                   res[(8, Transport.GDR)][0] < 1.6 * res[(2, Transport.GDR)][0]))
    return claims


def fig17_sharing_modes():
    """Fig. 17: multi-stream vs multi-context vs MPS, EfficientNetB0 raw."""
    claims = []
    res = {}
    for t in (Transport.GDR, Transport.RDMA):
        for sharing in ("multi-stream", "multi-context", "mps"):
            s = run_scenario(ScenarioConfig(
                workload=TABLE_II["efficientnetb0"], transport=t,
                sharing=sharing, n_clients=8, requests_per_client=40))
            res[(t, sharing)] = mean_ms(s)
            emit(f"fig17/efficientnetb0/{t.value}/{sharing}", res[(t, sharing)] * 1e3)
    claims.append(("fig17: MPS beats multi-context (both transports, paper)",
                   all(res[(t, "mps")] < res[(t, "multi-context")]
                       for t in (Transport.GDR, Transport.RDMA))))
    claims.append(("fig17: GDR multi-stream ~ MPS (paper: identical)",
                   abs(res[(Transport.GDR, "multi-stream")] - res[(Transport.GDR, "mps")])
                   / res[(Transport.GDR, "mps")] < 0.10))
    claims.append(("fig17: RDMA MPS <= multi-stream (paper: MPS better)",
                   res[(Transport.RDMA, "mps")] <= res[(Transport.RDMA, "multi-stream")] * 1.02))
    return claims


def fig_prefix_hit_rate_sweep():
    """Repo-grown figure: the shared-prefix paged-KV sweep from
    ``BENCH_prefix.json`` (benchmarks/prefix.py). Same thesis as the
    paper's transport figures — bytes you don't move are latency you
    don't pay — applied to the KV handoff: as the prefix-hit rate rises,
    uncached prefill tokens, handoff wire bytes, and p99 TTFT all fall
    together. Validates the committed JSON's claims and, when matplotlib
    is importable, renders the sweep to ``BENCH_prefix.png``."""
    import json
    from pathlib import Path

    path = Path(__file__).resolve().parents[1] / "BENCH_prefix.json"
    if not path.exists():
        return [("fig-prefix: BENCH_prefix.json present "
                 "(run benchmarks.prefix first)", False)]
    data = json.loads(path.read_text())["prefix"]
    rows = data["hit_rate_sweep"]
    rates = sorted(rows, key=float)
    for k in rates:
        r = rows[k]
        emit(f"figprefix/hit{k}/uncached_tokens",
             r["prefill_tokens_uncached"], "tokens")
        emit(f"figprefix/hit{k}/handoff_wire_bytes",
             r["handoff_wire_bytes"], "bytes")
        emit(f"figprefix/hit{k}/ttft_p99", r["ttft_p99_s"] * 1e6)

    def series(field):
        return [rows[k][field] for k in rates]

    claims = [
        ("fig-prefix: uncached prefill tokens strictly fall with hit rate",
         all(a > b for a, b in zip(series("prefill_tokens_uncached"),
                                   series("prefill_tokens_uncached")[1:]))),
        ("fig-prefix: handoff wire bytes strictly fall with hit rate",
         all(a > b for a, b in zip(series("handoff_wire_bytes"),
                                   series("handoff_wire_bytes")[1:]))),
        ("fig-prefix: p99 TTFT strictly falls with hit rate",
         all(a > b for a, b in zip(series("ttft_p99_s"),
                                   series("ttft_p99_s")[1:]))),
        ("fig-prefix: wire bytes reconcile exactly at every hit rate",
         all(rows[k]["wire_reconciled_exact"] for k in rates)),
        ("fig-prefix: paged decode token-identical to ring (HBM + DMA)",
         all(v["token_match_vs_ring"] == 1.0
             for v in data["token_identity"].values())),
    ]
    _plot_prefix_sweep(rows, rates, path.with_suffix(".png"))
    return claims


def fig_stage_breakdown():
    """Repo-grown figure: stacked per-stage latency bars per KV-transfer
    mechanism (DIRECT_HBM / DIRECT_DMA / HOST_STAGED) — the repo's version
    of the paper's stage-breakdown figures (Figs. 6/8), rendered from the
    span walls exported by the traced drains in benchmarks/disagg.py
    (``stage_walls_s`` in ``BENCH_disagg.json``). The ``request`` root span
    covers its children and the ``submit`` span is instant, so both are
    excluded from the stack."""
    import json
    from pathlib import Path

    path = Path(__file__).resolve().parents[1] / "BENCH_disagg.json"
    if not path.exists():
        return [("fig-stage: BENCH_disagg.json present "
                 "(run benchmarks.disagg first)", False)]
    rows = json.loads(path.read_text())["disagg"]["disaggregated"]
    mechs = sorted(rows)
    walls = {m: rows[m].get("stage_walls_s", {}) for m in mechs}
    for m in mechs:
        for stage, v in sorted(walls[m].items()):
            emit(f"figstage/{m}/{stage}", v * 1e6)

    stacked = {
        m: {k: v for k, v in walls[m].items()
            if k not in ("request", "submit") and v > 0}
        for m in mechs
    }
    claims = [
        ("fig-stage: every mechanism exports traced stage walls",
         all(walls[m] for m in mechs)),
        ("fig-stage: every mechanism has a transfer span wall",
         all(walls[m].get("transfer", 0.0) > 0 for m in mechs)),
        ("fig-stage: every mechanism has prefill + decode span walls",
         all(any(k.startswith("prefill.") for k in walls[m])
             and "decode.window" in walls[m] for m in mechs)),
        ("fig-stage: stage vocabularies agree across mechanisms",
         len({frozenset(stacked[m]) for m in mechs}) == 1),
    ]
    _plot_stage_breakdown(stacked, mechs, path.parent / "BENCH_stages.png")
    return claims


def _plot_stage_breakdown(stacked, mechs, out_path):
    """Stacked-bar render (skipped when matplotlib is unavailable — the
    claims above carry the validation either way)."""
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return
    stages = sorted({k for m in mechs for k in stacked[m]})
    fig, ax = plt.subplots(figsize=(7, 4))
    bottom = [0.0] * len(mechs)
    for stage in stages:
        vals = [stacked[m].get(stage, 0.0) * 1e3 for m in mechs]
        ax.bar(mechs, vals, bottom=bottom, label=stage)
        bottom = [b + v for b, v in zip(bottom, vals)]
    ax.set_ylabel("summed span wall (ms)")
    ax.set_title("Per-stage breakdown by KV-transfer mechanism "
                 "(benchmarks/disagg.py traced drains)")
    ax.legend(fontsize=8)
    ax.grid(True, axis="y", alpha=0.3)
    fig.tight_layout()
    fig.savefig(out_path, dpi=120)
    plt.close(fig)


def _plot_prefix_sweep(rows, rates, out_path):
    """Three-panel hit-rate sweep plot (skipped when matplotlib is
    unavailable — the claims above carry the validation either way)."""
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return
    x = [rows[k]["hit_rate"] for k in rates]
    panels = [
        ("prefill_tokens_uncached", 1, "uncached prefill tokens"),
        ("handoff_wire_bytes", 1e-3, "handoff wire KB"),
        ("ttft_p99_s", 1e3, "p99 TTFT (ms)"),
    ]
    fig, axes = plt.subplots(1, 3, figsize=(10, 3.2))
    for ax, (field, scale, label) in zip(axes, panels):
        ax.plot(x, [rows[k][field] * scale for k in rates], "o-")
        ax.set_xlabel("prefix hit rate")
        ax.set_ylabel(label)
        ax.grid(True, alpha=0.3)
    fig.suptitle("Shared-prefix paged KV reuse (benchmarks/prefix.py)")
    fig.tight_layout()
    fig.savefig(out_path, dpi=120)
    plt.close(fig)


ALL_FIGURES = [
    fig05_transport_single_client,
    fig_prefix_hit_rate_sweep,
    fig_stage_breakdown,
    fig06_breakdown,
    fig07_overhead_vs_local,
    fig08_stage_fractions,
    fig09_cpu_usage,
    fig10_proxied_single,
    fig11_scalability,
    fig12_13_breakdown_scaling,
    fig14_proxied_scaling,
    fig15_concurrency_limit,
    fig16_priority,
    fig17_sharing_modes,
]
