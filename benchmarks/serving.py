"""Serving fast-path A/B benchmark: seed synchronous loop vs the rebuilt
hot path (bucketed prefill + device-resident async decode).

Drives the REAL-compute ServingEngine on the reduced-config CPU model with a
ragged closed-queue workload (many distinct prompt lengths — the regime the
paper's model-serving traces are in once the NIC stops being the
bottleneck), and records steps/s, tokens/s, end-to-end wall, and prefill
compile counts for both engines in ``BENCH_serving.json``.

Also micro-benchmarks the length-aware decode-attention kernel on a ragged
batch vs a dense full-window batch (interpret mode on CPU: the numbers are
correctness-representative; the HBM-bandwidth win is a TPU property of the
clamped BlockSpec index_map).

Usage: PYTHONPATH=src python -m benchmarks.serving [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import time


def make_requests(cfg, lens, max_new, seed=0):
    import numpy as np

    from repro.serving.request import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


def run_engine(model, params, cfg, lens, *, max_new, max_batch, max_seq, **kw):
    from repro.serving import ServingEngine

    eng = ServingEngine(model, params, max_batch=max_batch, max_seq=max_seq,
                        **kw)
    reqs = make_requests(cfg, lens, max_new)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained(max_steps=100_000)
    wall = time.perf_counter() - t0
    assert len(out) == len(reqs), (len(out), len(reqs))
    tokens = sum(len(r.tokens) for r in out)
    return {
        "wall_s": round(wall, 4),
        # dispatched includes the async window's overshoot past finished
        # requests; useful counts only steps that advanced a live request —
        # the honest A/B unit (legacy steps are all useful by construction).
        "decode_steps_dispatched": eng.decode_steps,
        "decode_steps": eng.useful_steps,
        "decode_steps_per_s": round(eng.useful_steps / wall, 2),
        "tokens_out": tokens,
        "tokens_per_s": round(tokens / wall, 2),
        "prefill_compiles": eng.prefill_compile_count,
        "requests": len(reqs),
    }


def micro_config():
    """Serving-overhead regime: model small enough that per-step FLOPs
    (which this PR does not change) stop masking the scheduling and
    data-movement costs it does — per-token host syncs, per-length
    recompiles, per-slot Python bookkeeping. This is the paper's
    small-model regime, where pipeline overhead dominates once the wire is
    fast."""
    import dataclasses

    from repro.configs import get_config

    return dataclasses.replace(
        get_config("llama3-8b").reduced(),
        name="llama3-8b-micro", d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=32,
    )


def bench_serving(quick: bool):
    import jax

    from repro.models import Model

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # ragged workload: every request a different prompt length — the seed
    # engine pays one prefill compile per length, the bucketed engine one
    # per pow2 bucket.
    n_req = 8 if quick else 32
    lens = [5 + 6 * i for i in range(n_req)]
    max_new = 8 if quick else 24
    common = dict(max_new=max_new, max_batch=4, max_seq=256)

    seed_sync = run_engine(model, params, cfg, lens, legacy=True, **common)
    fast = run_engine(model, params, cfg, lens, inflight=4, **common)
    return {
        "workload": {
            "model": cfg.name, "prompt_lens": lens,
            "max_new_tokens": max_new, "max_batch": common["max_batch"],
            "max_seq": common["max_seq"], "backend": jax.default_backend(),
        },
        "seed_sync_loop": seed_sync,
        "fast_path": fast,
        "speedup": {
            "decode_steps_per_s": round(
                fast["decode_steps_per_s"] / seed_sync["decode_steps_per_s"], 2
            ),
            "tokens_per_s": round(
                fast["tokens_per_s"] / seed_sync["tokens_per_s"], 2
            ),
            "prefill_compiles": (
                f'{seed_sync["prefill_compiles"]} -> {fast["prefill_compiles"]}'
            ),
        },
    }


def bench_ragged_kernel(quick: bool):
    """Ragged vs dense decode-attention (interpret mode on CPU)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    B, W, Hkv, G, hd = 4, 256, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.bfloat16)
    ragged = jnp.asarray([16, 48, 112, 256], jnp.int32)
    dense = jnp.full((B,), W, jnp.int32)

    def t(lens, n=2 if quick else 5):
        ops.decode_attention(q, k, v, lens, block_k=64).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n):
            r = ops.decode_attention(q, k, v, lens, block_k=64)
        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / n * 1e6

    return {
        "shape": {"B": B, "W": W, "Hkv": Hkv, "G": G, "hd": hd, "block_k": 64},
        "ragged_lens_us": round(t(ragged), 1),
        "dense_lens_us": round(t(dense), 1),
        "note": "interpret mode on CPU; the bandwidth win from clamped KV "
                "block fetches is a TPU property",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI smoke)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    result = {
        "benchmark": "serving fast path (bucketed prefill + async decode)",
        "serving": bench_serving(args.quick),
        "ragged_decode_kernel": bench_ragged_kernel(args.quick),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    s = result["serving"]["speedup"]
    print(f"\n# decode steps/s speedup: {s['decode_steps_per_s']}x; "
          f"tokens/s speedup: {s['tokens_per_s']}x; "
          f"prefill compiles: {s['prefill_compiles']}")


if __name__ == "__main__":
    main()
