"""Serving fast-path A/B benchmark: seed synchronous loop vs the rebuilt
hot path (bucketed prefill + device-resident async decode).

Drives the REAL-compute ServingEngine on the reduced-config CPU model with a
ragged closed-queue workload (many distinct prompt lengths — the regime the
paper's model-serving traces are in once the NIC stops being the
bottleneck), and records steps/s, tokens/s, end-to-end wall, and prefill
compile counts for both engines in ``BENCH_serving.json``.

Also A/Bs token-packed + chunked prefill (``packed_prefill`` section:
padded-token footprint and the decode head-of-line TPOT bound — both
asserted on every run), and micro-benchmarks the length-aware
decode-attention kernel on a ragged batch vs a dense full-window batch
(interpret mode on CPU: the numbers are correctness-representative; the
HBM-bandwidth win is a TPU property of the clamped BlockSpec index_map).

Usage: PYTHONPATH=src python -m benchmarks.serving [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import time


def make_requests(cfg, lens, max_new, seed=0):
    import numpy as np

    from repro.serving.request import Request

    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


def run_engine(model, params, cfg, lens, *, max_new, max_batch, max_seq, **kw):
    from repro.serving import ServingEngine

    eng = ServingEngine(model, params, max_batch=max_batch, max_seq=max_seq,
                        **kw)
    reqs = make_requests(cfg, lens, max_new)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained(max_steps=100_000)
    wall = time.perf_counter() - t0
    assert len(out) == len(reqs), (len(out), len(reqs))
    tokens = sum(len(r.tokens) for r in out)
    return {
        "wall_s": round(wall, 4),
        # dispatched includes the async window's overshoot past finished
        # requests; useful counts only steps that advanced a live request —
        # the honest A/B unit (legacy steps are all useful by construction).
        "decode_steps_dispatched": eng.decode_steps,
        "decode_steps": eng.useful_steps,
        "decode_steps_per_s": round(eng.useful_steps / wall, 2),
        "tokens_out": tokens,
        "tokens_per_s": round(tokens / wall, 2),
        "prefill_compiles": eng.prefill_compile_count,
        "requests": len(reqs),
    }


def micro_config():
    """Serving-overhead regime: model small enough that per-step FLOPs
    (which this PR does not change) stop masking the scheduling and
    data-movement costs it does — per-token host syncs, per-length
    recompiles, per-slot Python bookkeeping. This is the paper's
    small-model regime, where pipeline overhead dominates once the wire is
    fast."""
    import dataclasses

    from repro.configs import get_config

    return dataclasses.replace(
        get_config("llama3-8b").reduced(),
        name="llama3-8b-micro", d_model=64, n_heads=2, n_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=32,
    )


def bench_serving(quick: bool):
    import jax

    from repro.models import Model

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # ragged workload: every request a different prompt length — the seed
    # engine pays one prefill compile per length, the bucketed engine one
    # per pow2 bucket.
    n_req = 8 if quick else 32
    lens = [5 + 6 * i for i in range(n_req)]
    max_new = 8 if quick else 24
    common = dict(max_new=max_new, max_batch=4, max_seq=256)

    seed_sync = run_engine(model, params, cfg, lens, legacy=True, **common)
    fast = run_engine(model, params, cfg, lens, inflight=4, **common)
    return {
        "workload": {
            "model": cfg.name, "prompt_lens": lens,
            "max_new_tokens": max_new, "max_batch": common["max_batch"],
            "max_seq": common["max_seq"], "backend": jax.default_backend(),
        },
        "seed_sync_loop": seed_sync,
        "fast_path": fast,
        "speedup": {
            "decode_steps_per_s": round(
                fast["decode_steps_per_s"] / seed_sync["decode_steps_per_s"], 2
            ),
            "tokens_per_s": round(
                fast["tokens_per_s"] / seed_sync["tokens_per_s"], 2
            ),
            "prefill_compiles": (
                f'{seed_sync["prefill_compiles"]} -> {fast["prefill_compiles"]}'
            ),
        },
    }


def bench_packed_prefill(quick: bool):
    """Token-packed + chunked prefill A/B.

    Two claims, both asserted on every run (including ``--quick``):

    - ``footprint``: on a ragged co-arrival batch, packing the prompts
      into ONE pow2 sequence dispatches strictly fewer padded token rows
      than per-request pow2 buckets, with identical generated tokens.
    - ``head_of_line``: while a long prompt admits mid-decode, chunked
      prefill bounds the worst per-step stall a decoding victim sees (the
      TPOT head-of-line bound) below the monolithic admission's stall.
      Both stalls are self-calibrating ratios over the SAME engine's own
      steady decode step, so the bound holds on any machine.
    """
    import statistics

    import jax
    import numpy as np

    from repro.models import Model
    from repro.serving import ServingEngine
    from repro.serving.request import Request

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # --- padded-token footprint: ragged co-arrivals, packed vs bucketed --
    lens = [5, 17, 33, 50] if quick else [5, 11, 17, 24, 33, 50, 70, 90]
    max_new = 4 if quick else 8

    def footprint(**kw):
        # max_batch = len(lens): the whole ragged batch co-arrives in one
        # admission, the regime where per-request buckets pay the most pad
        eng = ServingEngine(model, params, max_batch=len(lens), max_seq=256,
                            temperature=0.0, **kw)
        reqs = make_requests(cfg, lens, max_new)
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r, time.perf_counter())
        eng.run_until_drained(max_steps=100_000)
        return {
            "wall_s": round(time.perf_counter() - t0, 4),
            "prefill_tokens_total": eng.prefill_tokens_total,
            "prefill_padded_tokens": eng.prefill_padded_tokens,
            # dispatched token rows per true prompt token (1.0 = no pad)
            "pad_overhead": round(
                eng.prefill_padded_tokens / eng.prefill_tokens_total, 2
            ),
        }, [tuple(r.generated) for r in reqs]

    bucketed, toks_b = footprint()
    packed, toks_p = footprint(packed=True)
    assert toks_p == toks_b, "packed prefill changed generated tokens"
    assert (
        packed["prefill_padded_tokens"] < bucketed["prefill_padded_tokens"]
    ), (packed, bucketed)

    # --- decode head-of-line: victim TPOT while a long prompt admits ----
    big_len, chunk = 448, 64
    victim_new = 24 if quick else 48

    def tpot_probe(prefill_chunk):
        eng = ServingEngine(model, params, max_batch=2, max_seq=512,
                            temperature=0.0, prefill_chunk=prefill_chunk)
        eng.warm()  # steady state: no compile walls inside the probe
        victim = Request(
            prompt_tokens=np.arange(16, dtype=np.int32) % cfg.vocab_size,
            max_new_tokens=victim_new,
        )
        eng.submit(victim, time.perf_counter())
        while len(victim.generated) < 4:  # settle into steady decode
            eng.step()
        base = []
        for _ in range(8):  # victim alone: the TPOT baseline
            t0 = time.perf_counter()
            eng.step()
            base.append(time.perf_counter() - t0)
        big = make_requests(cfg, [big_len], 2, seed=2)[0]
        eng.submit(big, time.perf_counter())
        gaps = []  # per-step walls across the admission window
        while (eng._chunk_jobs or not big.generated) and len(gaps) < 10_000:
            t0 = time.perf_counter()
            eng.step()
            gaps.append(time.perf_counter() - t0)
        eng.run_until_drained(max_steps=100_000)
        base_ms = statistics.median(base) * 1e3
        worst_ms = max(gaps) * 1e3
        return {
            "decode_step_ms": round(base_ms, 3),
            "worst_step_ms": round(worst_ms, 3),
            "admission_steps": len(gaps),
            # worst decode stall during the admission, in units of this
            # same engine's own steady decode step
            "head_of_line_ratio": round(worst_ms / base_ms, 2),
        }

    unchunked = tpot_probe(0)
    chunked = tpot_probe(chunk)
    # the TPOT bound: chunking must shrink the worst stall a decoding
    # request sees while a long prompt admits
    assert (
        chunked["head_of_line_ratio"] < unchunked["head_of_line_ratio"]
    ), (chunked, unchunked)

    return {
        "footprint": {
            "workload": {"prompt_lens": lens, "max_new_tokens": max_new,
                         "max_batch": len(lens), "max_seq": 256},
            "bucketed": bucketed,
            "packed": packed,
            "token_identical": True,  # asserted above
        },
        "head_of_line": {
            "workload": {"victim_prompt": 16, "victim_new": victim_new,
                         "big_prompt": big_len, "prefill_chunk": chunk,
                         "max_batch": 2, "max_seq": 512},
            "unchunked": unchunked,
            "chunked": chunked,
            "tpot_bound_ok": True,  # asserted above
        },
    }


def bench_tracing(quick: bool):
    """Tracing on/off A/B + span/stage reconciliation (both asserted).

    Two claims, asserted on every run (including ``--quick``):

    - ``overhead``: enabling span tracing on the warmed fast-path drain
      costs < 3% wall (min-of-rounds on both arms, so a scheduler blip on
      one round can't fake a regression either way).
    - ``reconcile``: the traced drain's span trees reconcile against its
      charged ``stage_s`` — every request has exactly one root span, the
      total span wall covers each charged stage, and process-level lanes
      are non-overlapping (``core.trace.Trace.reconcile``).
    """
    import jax

    from repro.core import trace
    from repro.models import Model
    from repro.serving import ServingEngine

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 8 if quick else 16
    lens = [5 + 6 * i for i in range(n_req)]
    max_new = 8 if quick else 16
    rounds = 5

    # one warmed engine per arm, built BEFORE timing: the A/B compares
    # steady-state drains, not construction/compile walls
    engines = {
        arm: ServingEngine(model, params, max_batch=4, max_seq=256,
                           inflight=4, warmup=True)
        for arm in ("off", "on")
    }

    def drain(arm: str) -> float:
        eng = engines[arm]
        reqs = make_requests(cfg, lens, max_new)
        if arm == "on":
            trace.enable_tracing(process="main")  # reset=True: fresh ring
        else:
            trace.disable_tracing()
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r, time.perf_counter())
        out = eng.run_until_drained(max_steps=100_000)
        wall = time.perf_counter() - t0
        assert len(out) == len(reqs), (len(out), len(reqs))
        return wall

    # interleaved rounds so drift (thermal, background load) hits both
    # arms equally; min-of-rounds is the steady-state estimate
    walls = {"off": [], "on": []}
    for _ in range(rounds):
        for arm in ("off", "on"):
            walls[arm].append(drain(arm))

    off_wall = min(walls["off"])
    on_wall = min(walls["on"])
    overhead = on_wall / off_wall - 1.0
    assert overhead < 0.03, (
        f"tracing overhead {overhead:.4f} exceeds the 3% budget "
        f"(off {off_wall:.4f}s, on {on_wall:.4f}s)"
    )

    # reconcile the LAST traced round (the buffer was reset each enable,
    # so exactly that round's spans are resident) against its records
    tr = trace.Trace.from_buffer()
    problems = tr.reconcile(engines["on"].store.records)
    assert not problems, "span/stage reconciliation failed:\n" + \
        "\n".join(problems)
    n_spanned = len(tr.by_request())
    trace.disable_tracing()  # don't leak tracing into later benches

    return {
        "workload": {
            "model": cfg.name, "requests": n_req, "max_new_tokens": max_new,
            "rounds": rounds, "max_batch": 4, "max_seq": 256,
        },
        "overhead": {
            "off_wall_s": round(off_wall, 4),
            "on_wall_s": round(on_wall, 4),
            "overhead_frac": round(overhead, 4),
            "overhead_ok": True,  # asserted above
        },
        "reconcile": {
            "n_requests": n_spanned,
            "n_spans": len(tr),
            "reconcile_ok": True,  # asserted above
        },
    }


def bench_ragged_kernel(quick: bool):
    """Ragged vs dense decode-attention (interpret mode on CPU)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    B, W, Hkv, G, hd = 4, 256, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.bfloat16)
    ragged = jnp.asarray([16, 48, 112, 256], jnp.int32)
    dense = jnp.full((B,), W, jnp.int32)

    def t(lens, n=2 if quick else 5):
        ops.decode_attention(q, k, v, lens, block_k=64).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(n):
            r = ops.decode_attention(q, k, v, lens, block_k=64)
        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / n * 1e6

    return {
        "shape": {"B": B, "W": W, "Hkv": Hkv, "G": G, "hd": hd, "block_k": 64},
        "ragged_lens_us": round(t(ragged), 1),
        "dense_lens_us": round(t(dense), 1),
        "note": "interpret mode on CPU; the bandwidth win from clamped KV "
                "block fetches is a TPU property",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI smoke)")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()

    result = {
        "benchmark": "serving fast path (bucketed prefill + async decode)",
        "serving": bench_serving(args.quick),
        "packed_prefill": bench_packed_prefill(args.quick),
        "ragged_decode_kernel": bench_ragged_kernel(args.quick),
        "tracing": bench_tracing(args.quick),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    s = result["serving"]["speedup"]
    print(f"\n# decode steps/s speedup: {s['decode_steps_per_s']}x; "
          f"tokens/s speedup: {s['tokens_per_s']}x; "
          f"prefill compiles: {s['prefill_compiles']}")


if __name__ == "__main__":
    main()
