"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for every measured point, then a
paper-claim validation summary (PASS/FAIL per claim). Also times the Pallas
kernels (interpret mode on CPU — correctness-representative, not wall-clock
-representative; TPU wall-clock comes from the §Roofline dry-run terms).

Usage: PYTHONPATH=src python -m benchmarks.run [--fig fig05] [--skip-kernels]
"""

from __future__ import annotations

import argparse
import sys
import time


def bench_kernels():
    """us/call for each Pallas kernel (interpret) vs its jnp oracle."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref
    from benchmarks.common import emit

    rng = np.random.default_rng(0)

    def t(fn, *a, n=3, **k):
        fn(*a, **k)  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(n):
            r = fn(*a, **k)
        import jax

        jax.block_until_ready(r)
        return (time.perf_counter() - t0) / n * 1e6

    q = jnp.asarray(rng.normal(size=(1, 256, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)), jnp.bfloat16)
    emit("kernel/flash_attention/interp", t(ops.flash_attention, q, k, v))
    emit("kernel/flash_attention/oracle", t(ref.flash_attention_ref, q, k, v))

    q1 = q[:, :1]
    lens = jnp.asarray([256], jnp.int32)
    emit("kernel/decode_attention/interp", t(ops.decode_attention, q1, k, v, lens))
    emit("kernel/decode_attention/oracle", t(ref.decode_attention_ref, q1, k, v, lens))
    # ragged batch: length-clamped KV BlockSpec streams only valid prefixes
    qr = jnp.asarray(rng.normal(size=(4, 1, 8, 64)), jnp.bfloat16)
    kr = jnp.asarray(rng.normal(size=(4, 256, 2, 64)), jnp.bfloat16)
    vr = jnp.asarray(rng.normal(size=(4, 256, 2, 64)), jnp.bfloat16)
    lens_r = jnp.asarray([16, 48, 112, 256], jnp.int32)
    emit("kernel/decode_attention_ragged/interp",
         t(ops.decode_attention, qr, kr, vr, lens_r))
    emit("kernel/decode_attention_ragged/oracle",
         t(ref.decode_attention_ref, qr, kr, vr, lens_r))

    x = jnp.asarray(rng.normal(size=(1, 256, 4, 32)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.normal(size=(1, 256, 4)), jnp.float32)) * 0.1
    A = -jnp.abs(jnp.asarray(rng.normal(size=(4,)), jnp.float32))
    B = jnp.asarray(rng.normal(size=(1, 256, 1, 16)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(1, 256, 1, 16)), jnp.float32)
    emit("kernel/ssd_scan/interp", t(ops.ssd_scan, x, dt, A, B, C, chunk=64))
    emit("kernel/ssd_scan/oracle", t(ref.ssd_scan_ref, x, dt, A, B, C))

    xr = jnp.asarray(rng.normal(size=(512, 512)), jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(512,)), jnp.bfloat16)
    emit("kernel/rmsnorm/interp", t(ops.rmsnorm, xr, w))
    xu = jnp.asarray(rng.integers(0, 256, (512, 512)), jnp.uint8)
    m = jnp.abs(jnp.asarray(rng.normal(size=(512,)), jnp.float32)) + 0.1
    s = jnp.abs(jnp.asarray(rng.normal(size=(512,)), jnp.float32)) + 0.3
    emit("kernel/preprocess/interp", t(ops.preprocess, xu, m, s))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fig", default=None, help="run a single figure, e.g. fig05")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    from benchmarks.figures import ALL_FIGURES

    claims = []
    for fn in ALL_FIGURES:
        if args.fig and not fn.__name__.startswith(args.fig):
            continue
        t0 = time.perf_counter()
        claims.extend(fn() or [])
        print(f"# {fn.__name__} done in {time.perf_counter()-t0:.1f}s", file=sys.stderr)

    if not args.skip_kernels and not args.fig:
        bench_kernels()

    print("\n# === paper-claim validation ===")
    fails = 0
    for desc, ok in claims:
        print(f"# {'PASS' if ok else 'FAIL'}  {desc}")
        fails += 0 if ok else 1
    print(f"# {len(claims)-fails}/{len(claims)} claims reproduced")
    if fails:
        sys.exit(1)


if __name__ == "__main__":
    main()
