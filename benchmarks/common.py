"""Shared helpers for the figure-reproduction benchmarks."""

from __future__ import annotations

from repro.core import (
    TABLE_II,
    ScenarioConfig,
    Transport,
    local_reference,
    run_scenario,
)

T_ALL = (Transport.LOCAL, Transport.GDR, Transport.RDMA, Transport.TCP)
T_NET = (Transport.GDR, Transport.RDMA, Transport.TCP)


def mean_ms(store) -> float:
    return store.summary()["mean"] * 1e3


def run_ms(workload: str, transport: Transport, **kw) -> float:
    if transport is Transport.LOCAL:
        return local_reference(
            ScenarioConfig(workload=TABLE_II[workload], **{
                k: v for k, v in kw.items() if k == "preprocessed"
            })
        ) * 1e3
    cfg = ScenarioConfig(workload=TABLE_II[workload], transport=transport, **kw)
    return mean_ms(run_scenario(cfg))


def emit(name: str, value_us: float, derived: str = ""):
    """CSV row in the harness's required format."""
    print(f"{name},{value_us:.2f},{derived}")
