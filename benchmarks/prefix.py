"""Shared-prefix KV reuse sweep: paged pool + radix prefix index on the
disaggregated serving path.

Drives the paged ``DisaggregatedEngine`` (DIRECT_DMA, modeled charge,
warmed) with the Zipf shared-prefix workload from ``serving/loadgen.py``
at three prefix-hit rates — 0% (independent prompts), 50%, and 90% of
each 160-token prompt already resident in the radix index — and records,
per rate, the uncached prefill tokens, the handoff wire bytes, and the
TTFT percentiles. Each rate's engine is primed with one request per
distinct system prompt (so the measured phase sees a warm index), and
counters are snapshotted after priming so the rows isolate the measured
requests.

Asserted on every run (including ``--quick``):

* three monotone wins — uncached prefill tokens, ``handoff_wire_bytes``,
  and p99 TTFT all STRICTLY decrease as the hit rate rises 0 -> 0.9
  (prefill cost tracks uncached tokens; the handoff moves only non-shared
  suffix blocks; both land in first-token latency);
* exact byte reconciliation at every hit rate —
  ``handoff_wire_bytes == handoff_payload_bytes`` (what the collective
  moved vs the geometry oracle for refcount-adjusted suffix payloads);
* paged decode is token-identical to the ring baseline under DIRECT_HBM
  and DIRECT_DMA (with prefix reuse on, against a fused ring engine).

Results land in ``BENCH_prefix.json`` (field reference in
docs/benchmarks.md); ``benchmarks/figures.py`` plots the sweep.

Usage: PYTHONPATH=src python -m benchmarks.prefix [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

PAGE = 16
PROMPT_LEN = 160  # every request, all rates: 10 KV pages
# hit rate -> shared prefix length (page-aligned; suffix = PROMPT_LEN - it)
SWEEP = ((0.0, 0), (0.5, 80), (0.9, 144))


def _p99(xs) -> float:
    import numpy as np

    return float(np.percentile(np.asarray(xs, float), 99))


def _drain(eng, reqs):
    """Submit all, drain, return ({request_id: response}, wall_s)."""
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained(max_steps=100_000)
    wall = time.perf_counter() - t0
    assert len(out) == len(reqs), (len(out), len(reqs))
    return {r.request_id: r for r in out}, wall


def _prime_requests(sched, prefix_len, vocab, seed=99):
    """One request per distinct system prompt in ``sched``: the full
    shared prefix plus a throwaway page of suffix, so the drain indexes
    every prefix page before the measured phase."""
    import numpy as np

    from repro.serving.request import Request

    rng = np.random.default_rng(seed)
    seen, out = set(), []
    for a in sched:
        key = tuple(int(t) for t in a.request.prompt_tokens[:prefix_len])
        if key in seen:
            continue
        seen.add(key)
        prompt = np.concatenate([
            a.request.prompt_tokens[:prefix_len],
            rng.integers(0, vocab, PAGE, dtype=np.int32),
        ])
        out.append(Request(prompt_tokens=prompt, max_new_tokens=2))
    return out


def bench_hit_sweep(model, params, cfg, mesh, quick):
    """The headline table: one warmed paged engine per hit rate."""
    from repro.core.transfer import TransferMode
    from repro.serving import DisaggregatedEngine
    from repro.serving.loadgen import shared_prefix_schedule

    n_req = 8 if quick else 16
    max_new = 4
    kw = dict(max_batch=4, max_seq=256, paged=True, page_size=PAGE,
              transfer_mode=TransferMode.DIRECT_DMA, mesh=mesh,
              charge="modeled", temperature=0.0, warmup=True)

    rows = {}
    for rate, plen in SWEEP:
        sched = shared_prefix_schedule(
            cfg.vocab_size, rate_rps=1000.0, n_requests=n_req,
            n_prefixes=2, prefix_len=plen, suffix_len=PROMPT_LEN - plen,
            zipf_a=1.1, max_new=max_new, seed=7,
        )
        eng = DisaggregatedEngine(model, params, **kw)
        if plen:
            _drain(eng, _prime_requests(sched, plen, cfg.vocab_size))
        base = (eng.prefill_tokens_total, eng.prefill_tokens_uncached,
                eng.prefix_hits, eng.handoff_wire_bytes)
        by_id, wall = _drain(eng, [a.request for a in sched])
        ttfts = [by_id[a.request.request_id].ttft_s for a in sched]
        total = eng.prefill_tokens_total - base[0]
        uncached = eng.prefill_tokens_uncached - base[1]
        hits = eng.prefix_hits - base[2]
        wire = eng.handoff_wire_bytes - base[3]
        # exact reconciliation: what the collectives moved vs the geometry
        # oracle for the refcount-adjusted (suffix-only) payloads
        assert eng.handoff_wire_bytes == eng.handoff_payload_bytes, (
            rate, eng.handoff_wire_bytes, eng.handoff_payload_bytes,
        )
        # every measured request against a primed index scores a hit
        assert hits == (n_req if plen else 0), (rate, hits)
        assert uncached == total - n_req * plen, (rate, uncached, total)
        rows[f"{rate:.1f}"] = {
            "hit_rate": rate,
            "prefix_len": plen,
            "suffix_len": PROMPT_LEN - plen,
            "requests": n_req,
            "prefill_tokens_total": total,
            "prefill_tokens_uncached": uncached,
            "uncached_fraction": round(uncached / total, 4),
            "prefix_hits": hits,
            "handoff_wire_bytes": wire,
            "wire_reconciled_exact": True,  # asserted above
            "ttft_p99_s": round(_p99(ttfts), 5),
            "ttft_mean_s": round(sum(ttfts) / len(ttfts), 5),
            "wall_s": round(wall, 3),
        }

    r0, r5, r9 = (rows["0.0"], rows["0.5"], rows["0.9"])
    # the three monotone wins, strict at every step of the sweep
    assert (r0["prefill_tokens_uncached"] > r5["prefill_tokens_uncached"]
            > r9["prefill_tokens_uncached"]), rows
    assert (r0["handoff_wire_bytes"] > r5["handoff_wire_bytes"]
            > r9["handoff_wire_bytes"]), rows
    assert r0["ttft_p99_s"] > r5["ttft_p99_s"] > r9["ttft_p99_s"], rows
    return rows


def bench_token_identity(model, params, cfg, mesh, quick):
    """Paged decode == ring decode, token for token: the same shared-prefix
    workload (prime + measured, so the paged engines exercise reuse)
    through a fused ring engine and a paged DisaggregatedEngine under each
    full-precision mechanism."""
    from repro.core.transfer import TransferMode
    from repro.serving import DisaggregatedEngine, ServingEngine
    from repro.serving.loadgen import shared_prefix_schedule

    n_req = 6 if quick else 12
    plen = 80
    sched = shared_prefix_schedule(
        cfg.vocab_size, rate_rps=1000.0, n_requests=n_req, n_prefixes=2,
        prefix_len=plen, suffix_len=PROMPT_LEN - plen, max_new=6, seed=11,
    )
    prime = _prime_requests(sched, plen, cfg.vocab_size)
    kw = dict(max_batch=4, max_seq=256, temperature=0.0)

    def _fresh(r):
        from repro.serving.request import Request

        # engines mutate their requests (stamps, generated tokens), so
        # each engine gets its own copies of the same prompt stream
        return Request(prompt_tokens=r.prompt_tokens.copy(),
                       max_new_tokens=r.max_new_tokens)

    def tokens_of(eng):
        _drain(eng, [_fresh(r) for r in prime])
        reqs = [_fresh(a.request) for a in sched]
        by_id, _ = _drain(eng, reqs)
        return [tuple(by_id[r.request_id].tokens) for r in reqs]

    base = tokens_of(ServingEngine(model, params, **kw))
    out = {}
    for mode in (TransferMode.DIRECT_HBM, TransferMode.DIRECT_DMA):
        eng = DisaggregatedEngine(
            model, params, transfer_mode=mode, mesh=mesh, charge="modeled",
            paged=True, page_size=PAGE, **kw,
        )
        toks = tokens_of(eng)
        match = sum(a == b for a, b in zip(toks, base)) / len(base)
        assert match == 1.0, (mode, match)
        assert eng.prefix_hits > 0, mode  # reuse genuinely exercised
        out[mode.value] = {
            "token_match_vs_ring": match,
            "prefix_hits": eng.prefix_hits,
        }
    return out


def main():
    import jax

    from benchmarks.serving import micro_config
    from repro.models import Model
    from repro.serving import make_pod_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI smoke)")
    ap.add_argument("--out", default="BENCH_prefix.json")
    args = ap.parse_args()

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    mesh = make_pod_mesh()

    result = {
        "benchmark": "shared-prefix paged KV reuse sweep",
        "prefix": {
            "workload": {
                "model": cfg.name, "prompt_len": PROMPT_LEN,
                "page_size": PAGE, "n_prefixes": 2, "zipf_a": 1.1,
                "max_batch": 4, "max_seq": 256,
                "transfer_mode": "direct_dma", "charge": "modeled",
                "backend": jax.default_backend(),
                "devices": len(jax.devices()),
            },
            "hit_rate_sweep": bench_hit_sweep(
                model, params, cfg, mesh, args.quick
            ),
            "token_identity": bench_token_identity(
                model, params, cfg, mesh, args.quick
            ),
        },
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    rows = result["prefix"]["hit_rate_sweep"]
    print("\n# hit-rate sweep: " + "; ".join(
        f"{k}: {r['prefill_tokens_uncached']} uncached tok, "
        f"{r['handoff_wire_bytes']/1e3:.0f} KB wire, "
        f"p99 ttft {r['ttft_p99_s']*1e3:.1f} ms"
        for k, r in rows.items()
    ))


if __name__ == "__main__":
    main()
