"""Disaggregated prefill->decode handoff sweep: the paper's pipeline
finding on the REAL serving path.

Runs the same ragged workload through the single-node ServingEngine and
the DisaggregatedEngine under each TransferMode on 8 forced host devices
(2-pod mesh: the pod-axis collective genuinely crosses devices). Reports
per-mechanism handoff bytes (wire + useful per-request prefixes), the
handoff charge folded into TTFT, raw TTFT, and token fidelity vs the
single-engine baseline. Asserts the paper's ordering on the deterministic
per-request handoff charge — DIRECT_HBM <= DIRECT_DMA <= HOST_STAGED (the
TTFT transfer component; raw TTFT additionally carries mode-independent
prefill/queue wall) — and that DIRECT_HBM / DIRECT_DMA decode output is
token-identical to the single engine (HOST_STAGED is int8-lossy by
design).

Usage: PYTHONPATH=src python -m benchmarks.disagg [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def run_workload(eng, cfg, lens, max_new):
    from benchmarks.serving import make_requests

    reqs = make_requests(cfg, lens, max_new)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained(max_steps=100_000)
    wall = time.perf_counter() - t0
    assert len(out) == len(reqs), (len(out), len(reqs))
    by_id = {r.request_id: r for r in out}
    tokens = [tuple(by_id[r.request_id].tokens) for r in reqs]
    ttfts = [by_id[r.request_id].ttft_s for r in reqs]
    return tokens, ttfts, wall


def bench_disagg(quick: bool):
    import jax

    from benchmarks.serving import micro_config
    from repro.core.transfer import TransferMode
    from repro.models import Model
    from repro.serving import DisaggregatedEngine, ServingEngine, make_pod_mesh

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 6 if quick else 16
    lens = [7 + 11 * i for i in range(n_req)]
    max_new = 4 if quick else 12
    kw = dict(max_batch=4, max_seq=256)

    mesh = make_pod_mesh()  # 2 pods on the forced-host backend
    base_tokens, base_ttfts, base_wall = run_workload(
        ServingEngine(model, params, **kw), cfg, lens, max_new
    )

    rows = {}
    for mode in TransferMode:
        eng = DisaggregatedEngine(
            model, params, transfer_mode=mode, mesh=mesh, **kw
        )
        tokens, ttfts, wall = run_workload(eng, cfg, lens, max_new)
        recs = eng.store.records
        charge = sum(r.stage_s.get("transfer", 0.0) for r in recs) / len(recs)
        match = sum(a == b for a, b in zip(tokens, base_tokens)) / len(tokens)
        rows[mode.value] = {
            "handoffs": eng.handoffs,
            "handoff_wire_bytes": eng.handoff_wire_bytes,
            "request_prefix_bytes_mean": round(
                eng.handoff_request_bytes / n_req
            ),
            "handoff_wall_s_total": round(eng.handoff_wall_s, 4),
            "handoff_charge_s_mean": round(charge, 6),
            "ttft_s_mean": round(sum(ttfts) / len(ttfts), 5),
            "wall_s": round(wall, 3),
            "token_match_vs_single_engine": round(match, 3),
        }

    hbm = rows[TransferMode.DIRECT_HBM.value]
    dma = rows[TransferMode.DIRECT_DMA.value]
    tcp = rows[TransferMode.HOST_STAGED.value]
    # the paper's headline: last-hop hardware acceleration recovers most of
    # the inter-stage cost (deterministic modeled charge on host devices)
    assert (hbm["handoff_charge_s_mean"] <= dma["handoff_charge_s_mean"]
            <= tcp["handoff_charge_s_mean"]), rows
    # full-precision mechanisms are bit-exact end to end
    assert hbm["token_match_vs_single_engine"] == 1.0, rows
    assert dma["token_match_vs_single_engine"] == 1.0, rows
    # staged undercuts full-precision wire bytes via int8 requantization
    assert tcp["handoff_wire_bytes"] < hbm["handoff_wire_bytes"], rows

    return {
        "workload": {
            "model": cfg.name, "prompt_lens": lens,
            "max_new_tokens": max_new, "max_batch": kw["max_batch"],
            "max_seq": kw["max_seq"], "backend": jax.default_backend(),
            "devices": len(jax.devices()), "pods": mesh.shape["pod"],
        },
        "single_engine": {
            "wall_s": round(base_wall, 3),
            "ttft_s_mean": round(sum(base_ttfts) / len(base_ttfts), 5),
        },
        "disaggregated": rows,
        "ordering_ok": {
            "handoff_charge": True,  # asserted above
            "raw_ttft": (hbm["ttft_s_mean"] <= dma["ttft_s_mean"]
                         <= tcp["ttft_s_mean"]),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI smoke)")
    ap.add_argument("--out", default="BENCH_disagg.json")
    args = ap.parse_args()

    result = {
        "benchmark": "disaggregated prefill->decode KV handoff sweep",
        "disagg": bench_disagg(args.quick),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    d = result["disagg"]["disaggregated"]
    print("\n# per-mechanism handoff (mean/request): " + "; ".join(
        f"{m}: {r['request_prefix_bytes_mean']/1e3:.1f} KB, "
        f"{r['handoff_charge_s_mean']*1e6:.0f} us charge, "
        f"ttft {r['ttft_s_mean']*1e3:.2f} ms, "
        f"match {r['token_match_vs_single_engine']:.0%}"
        for m, r in d.items()
    ))


if __name__ == "__main__":
    main()
