"""Disaggregated prefill->decode handoff sweep: the paper's pipeline
finding on the REAL serving path.

Runs the same ragged workload through the single-node ServingEngine and
the DisaggregatedEngine under each TransferMode on 8 forced host devices
(2-pod mesh: the pod-axis collective genuinely crosses devices). Reports
per-mechanism handoff bytes (wire + useful per-request prefixes), the
handoff charge folded into TTFT, raw TTFT, and token fidelity vs the
single-engine baseline. Asserts the paper's ordering on the deterministic
per-request handoff charge — DIRECT_HBM <= DIRECT_DMA <= HOST_STAGED (the
TTFT transfer component; raw TTFT additionally carries mode-independent
prefill/queue wall) — and that DIRECT_HBM / DIRECT_DMA decode output is
token-identical to the single engine (HOST_STAGED is int8-lossy by
design).

The occupancy sweep pins the prefix-only handoff: wire bytes (and the
HOST_STAGED/DMA handoff charge) must scale with admitted rows and true
prefix length, NOT with the max_batch x max_seq pool size — a single
short-prompt admission moves a per-row prefix share of the padded
admission tree the collective used to permute. The monotonicity
assertions run in the CI --quick smoke.

The warmup sweep runs one engine with ``warmup=True`` (construction
pre-traces the pow2 bucket + handoff extent grids) and asserts the
steady-state property: the drain compiles NOTHING — no new prefill
bucket, no new handoff extent — and its wall undercuts a cold engine's
first drain, which pays those compiles inline. Also asserted in the CI
--quick smoke.

Per-pod compute placement is ON (the default): prefill params/compute sit
on pod 0, the decode pool on the last pod, and the handoff collective is
the only cross-slice hop. See docs/benchmarks.md for every output field.

Usage: PYTHONPATH=src python -m benchmarks.disagg [--quick] [--out PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def run_workload(eng, cfg, lens, max_new):
    from benchmarks.serving import make_requests

    reqs = make_requests(cfg, lens, max_new)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained(max_steps=100_000)
    wall = time.perf_counter() - t0
    assert len(out) == len(reqs), (len(out), len(reqs))
    by_id = {r.request_id: r for r in out}
    tokens = [tuple(by_id[r.request_id].tokens) for r in reqs]
    ttfts = [by_id[r.request_id].ttft_s for r in reqs]
    return tokens, ttfts, wall


def bench_occupancy(model, params, cfg, mesh):
    """Wire bytes / handoff charge vs admissions and prefix length.

    Cases share one pow2 bucket per admission so each drain is exactly one
    collective; 'padded_tree_wire_bytes' is what the pre-fix handoff moved
    (the full max_batch x max_seq pool tree + full-width metadata) for
    every admission regardless of occupancy."""
    from repro.core.transfer import TransferMode
    from repro.serving import DisaggregatedEngine

    kw = dict(max_batch=4, max_seq=256)
    cases = {
        "occ1_short": [7],  # 1 admitted row, 16-slot pow2 prefix
        "occ1_long": [100],  # 1 row, 128-slot prefix: mid-ring scaling
        "occ_full_short": [7] * kw["max_batch"],  # full-pool admission
    }
    out = {}
    for mode in (TransferMode.DIRECT_DMA, TransferMode.HOST_STAGED):
        rows = {}
        padded = None
        for case, lens in cases.items():
            # modeled charge: the sweep's assertions must stay deterministic
            # on accelerator backends too (measured walls of KB-scale hops
            # invert from scheduling noise; the wire-byte invariants are
            # charge-independent)
            eng = DisaggregatedEngine(
                model, params, transfer_mode=mode, mesh=mesh,
                charge="modeled", **kw
            )
            run_workload(eng, cfg, lens, max_new=2)
            assert eng.handoffs == 1, (case, eng.handoffs)
            recs = eng.store.records
            charge = sum(r.stage_s["transfer"] for r in recs) / len(recs)
            rows[case] = {
                "handoff_wire_bytes": eng.handoff_wire_bytes,
                "request_prefix_bytes": eng.handoff_request_bytes,
                "handoff_charge_s_mean": round(charge, 7),
            }
            if padded is None:
                padded = eng.padded_tree_wire_bytes()
        short, long_, full = (rows["occ1_short"], rows["occ1_long"],
                              rows["occ_full_short"])
        # wire bytes are monotone in prefix length and in occupancy...
        assert (short["handoff_wire_bytes"] < long_["handoff_wire_bytes"]
                < padded), rows
        if mode is TransferMode.HOST_STAGED:
            # per-pod int8 scales are per-leaf, not per-row, so a full
            # pool rides marginally under rows x the single admission
            assert (short["handoff_wire_bytes"] < full["handoff_wire_bytes"]
                    <= kw["max_batch"] * short["handoff_wire_bytes"]), rows
        else:
            assert (full["handoff_wire_bytes"]
                    == kw["max_batch"] * short["handoff_wire_bytes"]), rows
        # ...and a single short admission moves a small prefix share of the
        # padded admission tree (the acceptance bar is < 1/4)
        assert short["handoff_wire_bytes"] < padded / 4, rows
        # the modeled handoff charge follows the request's true prefix
        assert (short["handoff_charge_s_mean"]
                < long_["handoff_charge_s_mean"]), rows
        out[mode.value] = {
            "padded_tree_wire_bytes": padded,
            "occupancy": rows,
            "occ1_short_vs_padded_tree": round(
                short["handoff_wire_bytes"] / padded, 4
            ),
        }
    return out


def bench_warmup(model, params, cfg, mesh):
    """Warmed steady-state: with ``warmup=True`` the engine pre-traces the
    pow2 bucket grid and every (rows, prefix) handoff extent at
    construction, so the serving path never compiles — asserted by
    snapshotting the compile-tracking sets around the drain — and the
    warmed drain wall undercuts a cold engine's first drain (which pays
    the same compiles inline)."""
    from repro.core.transfer import TransferMode
    from repro.serving import DisaggregatedEngine

    kw = dict(max_batch=4, max_seq=128, transfer_mode=TransferMode.DIRECT_HBM,
              mesh=mesh, charge="modeled")
    lens = [7, 23, 55, 100]

    cold = DisaggregatedEngine(model, params, **kw)
    _, _, cold_wall = run_workload(cold, cfg, lens, max_new=4)

    warm = DisaggregatedEngine(model, params, warmup=True, **kw)
    extents, buckets = set(warm._xfer_warm), warm.prefill_compile_count
    _, _, warm_wall = run_workload(warm, cfg, lens, max_new=4)
    # steady-state walls: the timed drain compiled nothing — no new
    # handoff extent, no new prefill bucket...
    assert warm._xfer_warm == extents, "handoff extent compiled in drain"
    assert warm.prefill_compile_count == buckets, "bucket compiled in drain"
    # ...so the warmed drain undercuts the cold drain that pays the
    # bucket/extent compiles inside its wall
    assert warm_wall < cold_wall, (warm_wall, cold_wall)
    return {
        "warm_construction_s": round(warm.warm_s, 3),
        "extents_pretraced": len(extents),
        "prefill_buckets_pretraced": buckets,
        "warm_drain_wall_s": round(warm_wall, 3),
        "cold_drain_wall_s": round(cold_wall, 3),
        "steady_state": True,  # asserted above
    }


def bench_disagg(quick: bool):
    import jax

    from benchmarks.serving import micro_config
    from repro.core import trace
    from repro.core.transfer import TransferMode
    from repro.models import Model
    from repro.serving import DisaggregatedEngine, ServingEngine, make_pod_mesh

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    n_req = 6 if quick else 16
    lens = [7 + 11 * i for i in range(n_req)]
    max_new = 4 if quick else 12
    kw = dict(max_batch=4, max_seq=256)

    mesh = make_pod_mesh()  # 2 pods on the forced-host backend
    base_tokens, base_ttfts, base_wall = run_workload(
        ServingEngine(model, params, **kw), cfg, lens, max_new
    )

    rows = {}
    placement_info = None
    for mode in TransferMode:
        eng = DisaggregatedEngine(
            model, params, transfer_mode=mode, mesh=mesh, **kw
        )
        if placement_info is None:  # report what the engines actually run
            pl = eng.placement
            placement_info = {
                "prefill_pods": list(pl.prefill_pods),
                "decode_pods": list(pl.decode_pods),
                "disjoint": pl.disjoint,
            }
        # per-mechanism traced drain: fresh span ring per mode, so the
        # exported per-stage walls (what fig_stage_breakdown stacks) are
        # this mechanism's alone
        trace.enable_tracing(process="main")
        tokens, ttfts, wall = run_workload(eng, cfg, lens, max_new)
        stage_walls: dict = {}
        for s in trace.Trace.from_buffer().spans:
            stage_walls[s.name] = stage_walls.get(s.name, 0.0) + s.wall
        trace.disable_tracing()
        recs = eng.store.records
        charge = sum(r.stage_s.get("transfer", 0.0) for r in recs) / len(recs)
        match = sum(a == b for a, b in zip(tokens, base_tokens)) / len(tokens)
        rows[mode.value] = {
            "handoffs": eng.handoffs,
            "handoff_wire_bytes": eng.handoff_wire_bytes,
            "request_prefix_bytes_mean": round(
                eng.handoff_request_bytes / n_req
            ),
            "handoff_wall_s_total": round(eng.handoff_wall_s, 4),
            "handoff_charge_s_mean": round(charge, 6),
            "ttft_s_mean": round(sum(ttfts) / len(ttfts), 5),
            "wall_s": round(wall, 3),
            "token_match_vs_single_engine": round(match, 3),
            # summed span wall per span name over the traced drain — the
            # per-mechanism stage breakdown fig_stage_breakdown renders
            "stage_walls_s": {
                k: round(v, 5) for k, v in sorted(stage_walls.items())
            },
        }

    hbm = rows[TransferMode.DIRECT_HBM.value]
    dma = rows[TransferMode.DIRECT_DMA.value]
    tcp = rows[TransferMode.HOST_STAGED.value]
    # the paper's headline: last-hop hardware acceleration recovers most of
    # the inter-stage cost (deterministic modeled charge on host devices)
    assert (hbm["handoff_charge_s_mean"] <= dma["handoff_charge_s_mean"]
            <= tcp["handoff_charge_s_mean"]), rows
    # full-precision mechanisms are bit-exact end to end
    assert hbm["token_match_vs_single_engine"] == 1.0, rows
    assert dma["token_match_vs_single_engine"] == 1.0, rows
    # staged undercuts full-precision wire bytes via int8 requantization
    assert tcp["handoff_wire_bytes"] < hbm["handoff_wire_bytes"], rows

    return {
        "workload": {
            "model": cfg.name, "prompt_lens": lens,
            "max_new_tokens": max_new, "max_batch": kw["max_batch"],
            "max_seq": kw["max_seq"], "backend": jax.default_backend(),
            "devices": len(jax.devices()), "pods": mesh.shape["pod"],
            # per-pod compute placement (on by default), read from the
            # engines' actual PodPlacement: the handoff collective is the
            # only cross-slice hop
            "placement": placement_info,
        },
        "single_engine": {
            "wall_s": round(base_wall, 3),
            "ttft_s_mean": round(sum(base_ttfts) / len(base_ttfts), 5),
        },
        "disaggregated": rows,
        "ordering_ok": {
            "handoff_charge": True,  # asserted above
            "raw_ttft": (hbm["ttft_s_mean"] <= dma["ttft_s_mean"]
                         <= tcp["ttft_s_mean"]),
        },
        # prefix-only handoff: wire bytes follow occupancy x prefix, not
        # pool size (monotonicity asserted inside)
        "occupancy_sweep": bench_occupancy(model, params, cfg, mesh),
        # warmup=True: extent grid pre-traced, zero compiles in the drain
        # (steady-state walls asserted inside)
        "warmup_sweep": bench_warmup(model, params, cfg, mesh),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (CI smoke)")
    ap.add_argument("--out", default="BENCH_disagg.json")
    args = ap.parse_args()

    result = {
        "benchmark": "disaggregated prefill->decode KV handoff sweep",
        "disagg": bench_disagg(args.quick),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    d = result["disagg"]["disaggregated"]
    print("\n# per-mechanism handoff (mean/request): " + "; ".join(
        f"{m}: {r['request_prefix_bytes_mean']/1e3:.1f} KB, "
        f"{r['handoff_charge_s_mean']*1e6:.0f} us charge, "
        f"ttft {r['ttft_s_mean']*1e3:.2f} ms, "
        f"match {r['token_match_vs_single_engine']:.0%}"
        for m, r in d.items()
    ))
    occ = result["disagg"]["occupancy_sweep"]
    print("# prefix-only wire bytes (1 short admission / padded tree): "
          + "; ".join(
              f"{m}: {r['occ1_short_vs_padded_tree']:.1%}"
              for m, r in occ.items()
          ))
    w = result["disagg"]["warmup_sweep"]
    print(f"# warmup: {w['prefill_buckets_pretraced']} buckets + "
          f"{w['extents_pretraced']} handoff extents pre-traced in "
          f"{w['warm_construction_s']}s; steady-state drain "
          f"{w['warm_drain_wall_s']}s vs cold {w['cold_drain_wall_s']}s")


if __name__ == "__main__":
    main()
