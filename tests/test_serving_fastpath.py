"""Serving fast-path invariants: bucketed prefill compile count, ragged-batch
decode-attention equivalence (length-clamped KV streaming), and drain
equivalence between the async device-resident loop and the legacy
synchronous loop."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.serving import ServingEngine
from repro.serving.request import Request


def _requests(cfg, lens, max_new=5, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


# --------------------------------------------------------------------------- #
# Length-aware KV streaming: clamped BlockSpec index_map must be a no-op
# numerically — ragged batches match the jnp oracle bit-for-tolerance.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("length_aware", [True, False])
def test_ragged_decode_attention_matches_oracle(length_aware):
    rng = np.random.default_rng(0)
    B, W, Hkv, G, hd = 5, 128, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    lens = jnp.asarray([1, 17, 64, 128, 33], jnp.int32)
    out = ops.decode_attention(q, k, v, lens, block_k=32,
                               length_aware=length_aware)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), atol=3e-5, rtol=1e-3
    )


def test_ragged_decode_attention_zero_length_rows():
    """Empty slots (length 0) must not poison the batch with NaNs."""
    rng = np.random.default_rng(1)
    B, W, Hkv, G, hd = 3, 64, 2, 1, 8
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    lens = jnp.asarray([0, 5, 64], jnp.int32)
    out = np.asarray(ops.decode_attention(q, k, v, lens, block_k=16))
    assert np.isfinite(out[1:]).all()
    want = np.asarray(ref.decode_attention_ref(q[1:], k[1:], v[1:], lens[1:]))
    np.testing.assert_allclose(out[1:], want, atol=3e-5, rtol=1e-3)


# --------------------------------------------------------------------------- #
# Bucketed prefill: compile count is O(log max_seq), not O(distinct lengths).
# --------------------------------------------------------------------------- #
def test_bucketed_prefill_compile_count(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    max_seq = 256
    eng = ServingEngine(model, params, max_batch=1, max_seq=max_seq)
    lens = list(range(5, 245, 12))  # 20 distinct prompt lengths
    assert len(set(lens)) == 20
    for req in _requests(cfg, lens, max_new=2):
        eng.submit(req, time.perf_counter())
    out = eng.run_until_drained()
    assert len(out) == 20
    # pow2 buckets in [min_bucket, max_seq]: at most log2(max_seq) shapes,
    # far below the 20 per-length compiles the seed engine paid.
    bound = int(np.log2(max_seq)) + 1
    assert eng.prefill_compile_count <= bound, (
        f"{eng.prefill_compile_count} prefill compiles > O(log max_seq) "
        f"bound {bound}"
    )


def test_legacy_engine_compiles_per_length(model_bank):
    """The baseline really does pay one compile per distinct length."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=1, max_seq=64, legacy=True)
    lens = [5, 9, 13, 21]
    for req in _requests(cfg, lens, max_new=2):
        eng.submit(req, time.perf_counter())
    eng.run_until_drained()
    assert eng.prefill_compile_count == len(set(lens))


# --------------------------------------------------------------------------- #
# Drain equivalence: async device-resident loop == legacy synchronous loop.
# --------------------------------------------------------------------------- #
def test_drain_tokens_match_legacy_sync_loop(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 8, 13, 21, 16, 30]

    def drain(**kw):
        eng = ServingEngine(model, params, max_batch=2, max_seq=64, **kw)
        reqs = _requests(cfg, lens, max_new=6, seed=7)
        for req in reqs:
            eng.submit(req, time.perf_counter())
        out = eng.run_until_drained()
        assert len(out) == len(lens)
        return [tuple(r.generated) for r in reqs], eng

    fast, eng_fast = drain(inflight=4)
    sync, _ = drain(legacy=True)
    assert fast == sync
    # every harvested slot ended done on device too
    assert eng_fast.done_mask.all()


@pytest.mark.parametrize("arch", ["mamba2-130m", "jamba-v0.1-52b"])
def test_ssm_archs_route_to_exact_prefill_and_match_legacy(arch, model_bank):
    """Right-padded bucketing would corrupt SSM/hybrid recurrent state (pad
    tokens flow through conv/SSD), so the engine must fall back to exact
    prefill for those stacks — and still match the legacy loop's tokens."""
    from conftest import nodrop

    cfg = nodrop(get_config(arch).reduced())
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 9, 14]

    def drain(**kw):
        eng = ServingEngine(model, params, max_batch=2, max_seq=32, **kw)
        reqs = _requests(cfg, lens, max_new=4, seed=2)
        for req in reqs:
            eng.submit(req, time.perf_counter())
        out = eng.run_until_drained()
        assert len(out) == len(lens)
        return [tuple(r.generated) for r in reqs], eng

    fast, eng = drain(inflight=3)
    assert not eng.bucketed_prefill  # ssm layers force the exact path
    sync, _ = drain(legacy=True)
    assert fast == sync


def test_eos_stops_generation(model_bank):
    """Device-side EOS detection must cut sequences short, async window and
    all."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    # discover the greedy continuation, then replay with its 2nd token as EOS
    eng = ServingEngine(model, params, max_batch=1, max_seq=64)
    probe = _requests(cfg, [9], max_new=6, seed=3)[0]
    eng.submit(probe, time.perf_counter())
    eng.run_until_drained()
    eos = probe.generated[1]

    eng2 = ServingEngine(model, params, max_batch=1, max_seq=64,
                         eos_token=eos, inflight=4)
    req = _requests(cfg, [9], max_new=6, seed=3)[0]
    eng2.submit(req, time.perf_counter())
    out = eng2.run_until_drained()
    assert len(out) == 1
    assert out[0].tokens == probe.generated[:2]


def test_max_new_tokens_one_finishes_at_prefill(model_bank):
    """The prefill token alone satisfies max_new_tokens=1 — no decode step,
    exactly one token (the legacy loop's off-by-one returned two)."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=1, max_seq=64)
    req = _requests(cfg, [8], max_new=1)[0]
    eng.submit(req, time.perf_counter())
    out = eng.run_until_drained()
    assert len(out) == 1
    assert len(out[0].tokens) == 1
    assert eng.decode_steps == 0


def test_priority_admission_order(model_bank):
    """Higher-priority queued requests still win the free slot."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=1, max_seq=64)
    lo = _requests(cfg, [8], max_new=2, seed=0)[0]
    hi = _requests(cfg, [8], max_new=2, seed=1)[0]
    hi.priority = 5
    eng.submit(lo, time.perf_counter())
    eng.submit(hi, time.perf_counter())
    out = eng.run_until_drained()
    assert [r.request_id for r in out] == [hi.request_id, lo.request_id]


def test_ttft_single_clock(model_bank):
    """ttft must be sane even when the caller passes a foreign clock value."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=1, max_seq=64)
    req = _requests(cfg, [8], max_new=2)[0]
    eng.submit(req, now=1e12)  # e.g. time.time() epoch seconds
    out = eng.run_until_drained()
    assert len(out) == 1
    assert 0 <= out[0].ttft_s < 60
    assert out[0].total_s > 0


def test_e2e_latency_includes_modeled_ingress_and_egress(model_bank):
    """The modeled ingress stages (request wire + copy_in) charged at submit
    must reach ttft/total just like the egress stages reach total — the
    pre-fix engine folded only the response wire in, so
    ``total_s >= sum(stage_s)`` failed by the ingress (+copy_out) delta."""
    from repro.core.transport import Transport

    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=1, max_seq=64,
                        transport=Transport.RDMA)  # has copy_in AND copy_out
    req = _requests(cfg, [8], max_new=2)[0]
    eng.submit(req, time.perf_counter())
    out = eng.run_until_drained()
    rec = eng.store.records[0]
    ingress = rec.stage_s["request"] + rec.stage_s["copy_in"]
    assert ingress > 0
    raw_ttft = req.t_first_token - req.t_arrival
    assert out[0].ttft_s == pytest.approx(raw_ttft + ingress, abs=1e-9)
    # every charged stage is now inside the end-to-end stamp
    assert out[0].total_s + 1e-9 >= sum(out[0].stage_s.values())
    assert rec.t_done == req.t_done
