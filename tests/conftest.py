# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
import dataclasses

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def nodrop(cfg):
    """MoE variant with capacity_factor high enough that nothing drops —
    required for exact prefill/decode vs full-forward equivalence."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k
        ),
    )


@pytest.fixture(scope="session")
def model_bank():
    """Session-scoped (Model, params) cache.

    Params are shared across Model variants that don't change the schema
    (remat/unroll flags), so e.g. the forward-, decode- and train-step smoke
    tests for one architecture initialize weights once instead of three
    times. ModelConfig is a frozen dataclass, so it keys the cache directly.
    """
    import jax
    import jax.numpy as jnp

    from repro.models import Model

    models: dict = {}
    params: dict = {}

    def get(cfg, dtype=jnp.bfloat16, seed=0, **model_kw):
        mkey = (cfg, str(dtype), tuple(sorted(model_kw.items())))
        pkey = (cfg, str(dtype), seed)
        if mkey not in models:
            models[mkey] = Model(cfg, dtype=dtype, **model_kw)
        if pkey not in params:
            params[pkey] = models[mkey].init(jax.random.key(seed))
        return models[mkey], params[pkey]

    return get


def arch_cases(slow_names=()):
    """Parametrize over all architectures, marking the named ones slow."""
    from repro.configs import ARCHITECTURES

    slow = set(slow_names)
    return [
        pytest.param(n, marks=pytest.mark.slow) if n in slow else n
        for n in sorted(ARCHITECTURES)
    ]
