# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
import dataclasses

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def nodrop(cfg):
    """MoE variant with capacity_factor high enough that nothing drops —
    required for exact prefill/decode vs full-forward equivalence."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k
        ),
    )
