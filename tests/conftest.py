# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
import dataclasses

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def nodrop(cfg):
    """MoE variant with capacity_factor high enough that nothing drops —
    required for exact prefill/decode vs full-forward equivalence."""
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k
        ),
    )


@pytest.fixture(scope="session")
def model_bank():
    """Session-scoped (Model, params) cache.

    Params are shared across Model variants that don't change the schema
    (remat/unroll flags), so e.g. the forward-, decode- and train-step smoke
    tests for one architecture initialize weights once instead of three
    times. ModelConfig is a frozen dataclass, so it keys the cache directly.
    """
    import jax
    import jax.numpy as jnp

    from repro.models import Model

    models: dict = {}
    params: dict = {}

    def get(cfg, dtype=jnp.bfloat16, seed=0, **model_kw):
        mkey = (cfg, str(dtype), tuple(sorted(model_kw.items())))
        pkey = (cfg, str(dtype), seed)
        if mkey not in models:
            models[mkey] = Model(cfg, dtype=dtype, **model_kw)
        if pkey not in params:
            params[pkey] = models[mkey].init(jax.random.key(seed))
        return models[mkey], params[pkey]

    return get


@pytest.fixture(scope="session")
def engine_bank(model_bank):
    """Session-scoped warmed-ServingEngine cache, KEYED ON THE ENGINE-KNOB
    TUPLE (plus cfg/dtype/seed), so A/B tests that toggle knobs
    (packed/paged/chunked/...) re-trace each variant once per session
    instead of once per test.

    A cache hit asserts the engine drained clean and then resets its
    mutable serving state (pool state, records, store, counters) while
    KEEPING the compiled jits — the whole point of sharing. Tests that
    mutate engine structure (placement, legacy loop) or need a cold
    engine should construct their own.
    """
    import jax.numpy as jnp

    engines: dict = {}

    def get(cfg, dtype=jnp.bfloat16, seed=0, *, max_batch, max_seq,
            **engine_kw):
        from repro.serving.engine import ServingEngine

        key = (cfg, str(dtype), seed, max_batch, max_seq,
               tuple(sorted(engine_kw.items())))
        if key not in engines:
            model, params = model_bank(cfg, dtype, seed)
            engines[key] = ServingEngine(
                model, params, max_batch=max_batch, max_seq=max_seq,
                **engine_kw,
            )
            return engines[key]
        eng = engines[key]
        assert eng.idle, "engine_bank reuse requires a drained engine"
        # fresh serving state, warm jit caches
        eng.pool.reset_state()
        eng.queue.clear()
        eng._records.clear()
        eng._finished_ids.clear()
        eng._backlog_entries.clear()
        eng._prefill_finished = []
        eng._chunk_jobs.clear()
        eng._chunk_slots.clear()
        eng.store.__init__()
        if eng.prefix_reuse:
            # reset_state re-zeroed the block allocator; a stale radix
            # index would dangle references into it
            from repro.serving.prefix import RadixPrefixIndex

            eng.prefix_index = RadixPrefixIndex(eng.page)
        eng.prefill_tokens_total = 0
        eng.prefill_tokens_uncached = 0
        eng.prefill_padded_tokens = 0
        eng.prefix_hits = 0
        eng.prefix_hit_tokens = 0
        eng.decode_steps = 0
        eng.useful_steps = 0
        return eng

    return get


def arch_cases(slow_names=()):
    """Parametrize over all architectures, marking the named ones slow."""
    from repro.configs import ARCHITECTURES

    slow = set(slow_names)
    return [
        pytest.param(n, marks=pytest.mark.slow) if n in slow else n
        for n in sorted(ARCHITECTURES)
    ]
