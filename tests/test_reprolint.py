"""reprolint: every rule fires on a seeded violation and stays silent on
the matching compliant snippet; suppressions and baselines behave; the
shipped tree is clean; and the PR 7 gateway busy-spin shape — the bug
the async_draining fix removed — is flagged as a regression fixture.

Fixtures go through ``lint_source`` with a repo-shaped ``filename`` so
the path-scoped rules (RL001 hot files, RL005 serving/) engage."""

import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

from tools.reprolint import (  # noqa: E402
    RULES, lint_paths, lint_source, load_baseline, save_baseline,
)

ENGINE = "src/repro/serving/engine.py"  # hot-path + serving/ scoped


def codes(findings):
    return [f.rule for f in findings]


def lint(src, filename=ENGINE):
    return lint_source(textwrap.dedent(src), filename=filename)


# --------------------------------------------------------------------------- #
# RL001 host-sync-in-hot-path
# --------------------------------------------------------------------------- #
TIMED_SYNC = """
    import time
    import numpy as np

    def _prefill(self, rec, toks):
        t0 = time.perf_counter()
        host = np.asarray(toks)           # device->host sync, timed stage
        rec.add("preprocess", time.perf_counter() - t0)
        return host
"""


def test_rl001_flags_sync_in_timed_stage():
    found = lint(TIMED_SYNC)
    # RL007 also fires: the fixture charges a stage with no span emitter
    assert sorted(codes(found)) == ["RL001", "RL007"]
    msg = next(f for f in found if f.rule == "RL001").message
    assert "np.asarray" in msg or "numpy.asarray" in msg


def test_rl001_flags_item_blockuntilready_and_device_int():
    found = lint("""
        import time
        import jax

        def _step(self, rec, x):
            t0 = time.perf_counter()
            x.block_until_ready()
            n = x.item()
            tok = int(jax.numpy.argmax(x))
            rec.add("inference", time.perf_counter() - t0)
            return n, tok
    """)
    assert sorted(codes(found)) == ["RL001", "RL001", "RL001", "RL007"]


def test_rl001_import_alias_does_not_dodge():
    found = lint("""
        import time
        from jax import device_get as dg

        def _drain(self, rec, x):
            t0 = time.perf_counter()
            y = dg(x)
            rec.add("transfer", time.perf_counter() - t0)
            return y
    """)
    assert sorted(codes(found)) == ["RL001", "RL007"]


def test_rl001_silent_on_untimed_and_harvest_and_literals():
    # not a timed-stage function (no stage charge): the designated
    # harvest thread's device_get must stay legal
    assert lint("""
        import jax

        def _harvest_loop(self):
            toks, done = jax.device_get((self.entry.tokens, self.entry.done))
            return toks, done
    """) == []
    # np.asarray over a host literal inside a timed stage is host-only
    # (the _trace_admission call keeps RL007 satisfied so this fixture
    # stays about RL001's silence)
    assert lint("""
        import time
        import numpy as np

        def _admit(self, rec, slot):
            t0 = time.perf_counter()
            idx = np.asarray([slot], np.int32)
            rec.add("preprocess", time.perf_counter() - t0)
            self._trace_admission(rec, t0)
            return idx
    """) == []


def test_rl001_scoped_to_hot_files():
    # identical code outside engine/disagg/cluster is out of scope
    assert lint(TIMED_SYNC, filename="src/repro/serving/loadgen.py") == []


# --------------------------------------------------------------------------- #
# RL002 impure-jit (applies to every file — fixtures use a non-serving
# path so RL005's serving-scoped warm-table check stays out of the way)
# --------------------------------------------------------------------------- #
KERNEL = "src/repro/models/attention.py"


def test_rl002_flags_clock_in_jitted_fn():
    found = lint("""
        import time
        import jax

        def _step_impl(params, cache):
            t0 = time.perf_counter()      # traced once; times nothing
            return cache

        step = jax.jit(_step_impl)
    """, filename=KERNEL)
    assert codes(found) == ["RL002"]
    assert "time.perf_counter" in found[0].message


def test_rl002_flags_lambda_print_and_transitive_callee():
    found = lint("""
        import jax

        f = jax.jit(lambda x: print(x) or x)

        def _helper(x):
            import numpy as np
            return np.random.rand() * x   # host RNG via transitive call

        def _outer(x):
            return _helper(x)

        g = jax.jit(_outer)
    """, filename=KERNEL)
    assert sorted(codes(found)) == ["RL002", "RL002"]
    scopes = {f.scope for f in found}
    assert "_helper" in scopes  # reached through _outer, not directly jitted


def test_rl002_flags_self_mutation_and_decorator_form():
    found = lint("""
        import functools
        import jax

        class Pool:
            @functools.partial(jax.jit, static_argnums=(0,))
            def _step(self, cache):
                self.calls += 1           # mutates at trace time only
                return cache
    """, filename=KERNEL)
    assert codes(found) == ["RL002"]
    assert "self.calls" in found[0].message


def test_rl002_silent_on_pure_jit_and_host_side_time():
    assert lint("""
        import time
        import jax
        import jax.numpy as jnp

        def _step_impl(params, cache, key):
            key, sub = jax.random.split(key)       # in-jit PRNG is fine
            return cache + jnp.float32(1), key

        step = jax.jit(_step_impl)

        def harvest(self, rec):
            t0 = time.perf_counter()               # NOT jitted: fine
            return t0
    """, filename=KERNEL) == []


# --------------------------------------------------------------------------- #
# RL003 lock discipline
# --------------------------------------------------------------------------- #
def test_rl003_flags_unguarded_access_and_blocking_put_under_lock():
    found = lint("""
        import queue as queue_mod
        import threading

        class EnginePipeline:
            _REPROLINT_GUARDED = ("_outputs", "emitted")

            def __init__(self, backlog):
                self._lock = threading.RLock()
                self._q = queue_mod.Queue(maxsize=backlog)
                self._outputs = []
                self.emitted = 0

            def bad_read(self):
                return len(self._outputs)          # no lock held

            def bad_put(self, item):
                with self._lock:
                    self._q.put(item)              # bounded put under lock
                    self.emitted += 1
    """)
    assert codes(found) == ["RL003", "RL003"]
    assert any("_outputs" in f.message and "outside" in f.message
               for f in found)
    assert any("_q.put" in f.message for f in found)


def test_rl003_flags_blocking_helper_called_under_lock():
    found = lint("""
        import queue as queue_mod
        import threading

        class EnginePipeline:
            _REPROLINT_GUARDED = ("_outstanding",)

            def __init__(self):
                self._lock = threading.RLock()
                self._q = queue_mod.Queue(maxsize=2)
                self._outstanding = 0

            def _put(self, q, item):
                q.put(item, timeout=0.05)

            def dispatch(self, entry):
                with self._lock:
                    self._outstanding += 1
                    self._put(self._q, entry)      # helper blocks
    """)
    assert codes(found) == ["RL003"]
    assert "_put" in found[0].message


def test_rl003_silent_on_disciplined_pipeline_and_undeclared_class():
    # the shipped shape: guarded state under the lock, puts outside it
    assert lint("""
        import queue as queue_mod
        import threading

        class EnginePipeline:
            _REPROLINT_GUARDED = ("_outputs",)

            def __init__(self, backlog):
                self._lock = threading.RLock()
                self._q = queue_mod.Queue(maxsize=backlog)
                self._outputs = []

            def dispatch(self, entry):
                with self._lock:
                    self._outputs.append(entry)
                self._q.put(entry)                 # outside the lock: ok
    """) == []
    # classes without a _REPROLINT_GUARDED declaration are out of scope
    assert lint("""
        class Plain:
            def touch(self):
                return self._outputs
    """) == []


# --------------------------------------------------------------------------- #
# RL004 IPC frame safety
# --------------------------------------------------------------------------- #
def test_rl004_flags_params_and_jax_values_in_frames():
    found = lint("""
        from repro.serving import ipc

        def serve(sock, pipe, params):
            ipc.send_msg(sock, "ok", {"params": params})
    """, filename="src/repro/serving/worker.py")
    assert codes(found) == ["RL004"]
    found = lint("""
        import jax
        from repro.serving.ipc import send_msg

        def snapshot(sock, pipe):
            send_msg(sock, "ok", jax.device_get(pipe.engine.caches))
    """, filename="src/repro/serving/worker.py")
    assert codes(found) == ["RL004"]


def test_rl004_traces_one_level_through_local_helpers():
    found = lint("""
        from repro.serving import ipc

        def _snapshot(pipe):
            return {"caches": pipe.engine.caches}

        def serve(sock, pipe):
            ipc.send_msg(sock, "ok", _snapshot(pipe))
    """, filename="src/repro/serving/worker.py")
    assert codes(found) == ["RL004"]
    assert "_snapshot" in found[0].message


def test_rl004_silent_on_scalar_payloads():
    assert lint("""
        import time
        import jax
        from repro.serving import ipc

        def serve(sock, pipe):
            ipc.send_msg(sock, "ok", {
                "t_child": time.perf_counter(),
                "devices": jax.device_count(),     # host int, not an array
                "emitted": pipe.emitted,
            })
    """, filename="src/repro/serving/worker.py") == []


# --------------------------------------------------------------------------- #
# RL005 warmup coverage
# --------------------------------------------------------------------------- #
def test_rl005_flags_unregistered_jit_in_serving():
    found = lint("""
        import jax

        WARM_PRETRACE_TABLE = frozenset({"_step_jit"})

        class Pool:
            def __init__(self, impl):
                self._step_jit = jax.jit(impl)
                self._rogue_jit = jax.jit(impl)    # not in the table
    """)
    assert codes(found) == ["RL005"]
    assert "_rogue_jit" in found[0].message


def test_rl005_silent_when_registered_or_suppressed_or_outside_serving():
    assert lint("""
        import jax

        WARM_PRETRACE_TABLE = frozenset({"_step_jit", "_splice_jit"})

        class Pool:
            def __init__(self, impl):
                self._step_jit = jax.jit(impl)
                self._splice_jit = jax.jit(impl, donate_argnums=(0,))
                self._legacy = jax.jit(impl)  # reprolint: disable=RL005 legacy retraces by design
    """) == []
    # jits outside serving/ (kernels, tests) are out of scope
    assert lint("""
        import jax

        def make(impl):
            return jax.jit(impl)
    """, filename="src/repro/models/attention.py") == []


# --------------------------------------------------------------------------- #
# RL006 swallowed-failure hygiene
# --------------------------------------------------------------------------- #
def test_rl006_flags_bare_except_and_unguarded_daemon():
    found = lint("""
        import threading

        class Pipeline:
            def _loop(self):
                while True:
                    self.tick()                    # no failure capture

            def start(self):
                t = threading.Thread(target=self._loop, daemon=True)
                t.start()

            def close(self):
                try:
                    self.sock.close()
                except:
                    pass
    """)
    assert sorted(codes(found)) == ["RL006", "RL006"]
    assert any("bare `except:`" in f.message for f in found)
    assert any("_loop" in f.message for f in found)


def test_rl006_silent_on_guarded_runner_and_typed_except():
    assert lint("""
        import threading
        import traceback

        class Pipeline:
            def _run_guarded(self, fn):
                try:
                    fn()
                except BaseException:
                    self._exc = traceback.format_exc()
                    self._stop.set()

            def start(self, fn):
                t = threading.Thread(target=self._run_guarded,
                                     args=(fn,), daemon=True)
                t.start()

            def close(self):
                try:
                    self.sock.close()
                except Exception:
                    pass                           # typed: out of scope
    """) == []


# --------------------------------------------------------------------------- #
# regression fixture: PR 7's gateway busy-spin poll shape
# --------------------------------------------------------------------------- #
def test_pr7_gateway_busy_spin_regression_is_flagged():
    """Before the async_draining fix, the gateway's drain loop busy-spun:
    a timed poll loop synced the device every iteration, and its watchdog
    daemon swallowed failures behind a bare except. Reintroducing that
    shape must trip RL001 AND RL006."""
    found = lint("""
        import threading
        import time
        import numpy as np

        class Gateway:
            def run_until_drained(self, rec, engine):
                t0 = time.perf_counter()
                while not engine.idle:
                    # busy-spin: device sync per poll, all of it timed
                    toks = np.asarray(engine.pool.tokens)
                    self.emit(toks)
                rec.add("response", time.perf_counter() - t0)

            def _watchdog(self):
                while True:
                    try:
                        self.poke()
                    except:
                        pass

            def start(self):
                t = threading.Thread(target=self._watchdog, daemon=True)
                t.start()
    """, filename="src/repro/serving/cluster.py")
    assert "RL001" in codes(found), found
    assert "RL006" in codes(found), found


# --------------------------------------------------------------------------- #
# RL007 trace coverage
# --------------------------------------------------------------------------- #
UNTRACED_STAGE = """
    import time

    def _prefill_bucket(self, rec, toks):
        t0 = time.perf_counter()
        rec.add("inference", time.perf_counter() - t0)
"""


def test_rl007_flags_untraced_stage_charge():
    found = lint(UNTRACED_STAGE)
    assert codes(found) == ["RL007"]
    assert "emits no span" in found[0].message


def test_rl007_silent_with_emit_or_trace_helper():
    # direct trace.tracer().emit(...)
    assert lint("""
        import time
        from repro.core import trace

        def _prefill_bucket(self, rec, toks):
            t0 = time.perf_counter()
            rec.add("inference", time.perf_counter() - t0)
            trace.tracer().emit("prefill.bucket", t0, time.perf_counter())
    """) == []
    # indirect: a _trace* helper carries the emit
    assert lint("""
        import time

        def _finish(self, rec, entry):
            t0 = time.perf_counter()
            rec.add("inference", time.perf_counter() - t0)
            self._trace_flush_window(entry)
    """) == []


def test_rl007_scoped_to_hot_files_and_untimed_functions():
    # same shape outside the hot files: out of scope
    assert lint(UNTRACED_STAGE, filename="src/repro/serving/loadgen.py") == []
    # charges a stage but never reads the clock (modeled cost): not a
    # timed-stage function, so no span is demanded
    assert lint("""
        def submit(self, rec, hop):
            rec.add("request", hop)
    """) == []


# --------------------------------------------------------------------------- #
# suppressions, baselines, CLI, shipped tree
# --------------------------------------------------------------------------- #
def test_suppression_requires_justification():
    # justified: silent.  bare: the suppression itself is reported (RL000)
    assert lint("""
        import time
        import numpy as np

        def _prefill(self, rec, toks):
            t0 = time.perf_counter()
            host = np.asarray(toks)  # reprolint: disable=RL001 deliberate timing fence
            rec.add("preprocess", time.perf_counter() - t0)
            self._trace_admission(rec, t0)
            return host
    """) == []
    found = lint("""
        import time
        import numpy as np

        def _prefill(self, rec, toks):
            t0 = time.perf_counter()
            host = np.asarray(toks)  # reprolint: disable=RL001
            rec.add("preprocess", time.perf_counter() - t0)
            self._trace_admission(rec, t0)
            return host
    """)
    assert codes(found) == ["RL000"]


def test_def_line_suppression_covers_whole_function():
    assert lint("""
        import time
        import numpy as np

        def _step_legacy(self, rec):  # reprolint: disable=RL001,RL007 legacy baseline blocks and is trace-exempt by design
            t0 = time.perf_counter()
            a = np.asarray(self.tokens)
            b = self.logits.item()
            rec.add("inference", time.perf_counter() - t0)
            return a, b
    """) == []


def test_syntax_error_becomes_finding_not_crash(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def broken(:\n")
    found = lint_paths([bad])
    assert codes(found) == ["RL000"]
    assert "does not parse" in found[0].message


def test_baseline_fingerprints_survive_line_moves(tmp_path):
    src = textwrap.dedent(TIMED_SYNC)
    f = tmp_path / "engine.py"
    f.write_text(src)
    mod_path = "src/repro/serving/engine.py"
    first = lint_source(src, filename=mod_path)
    # grandfather it, then shift every line down: same fingerprint
    base = tmp_path / "baseline.json"
    save_baseline(base, first)
    shifted = lint_source("# header comment\n\n" + src, filename=mod_path)
    assert [x.fingerprint for x in shifted] == \
        [x.fingerprint for x in first]
    assert {x.fingerprint for x in shifted} <= load_baseline(base)


def test_cli_strict_clean_on_shipped_tree_and_lists_rules():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.reprolint", "--strict"],
        capture_output=True, text=True, cwd=ROOT, timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout
    proc = subprocess.run(
        [sys.executable, "-m", "tools.reprolint", "--list-rules"],
        capture_output=True, text=True, cwd=ROOT, timeout=60,
    )
    assert proc.returncode == 0
    for code in ("RL001", "RL002", "RL003", "RL004", "RL005", "RL006",
                 "RL007"):
        assert code in proc.stdout


def test_unified_checks_entry_point_runs_all():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.checks"],
        capture_output=True, text=True, cwd=ROOT, timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for tag in ("[docs]", "[bench]", "[lint]"):
        assert f"{tag} ok" in proc.stdout
    # unknown checker name -> usage error, not a silent pass
    proc = subprocess.run(
        [sys.executable, "-m", "tools.checks", "--only", "nope"],
        capture_output=True, text=True, cwd=ROOT, timeout=60,
    )
    assert proc.returncode == 2


def test_every_rule_is_registered_and_documented():
    have = {r.code for r in RULES}
    assert have == {"RL001", "RL002", "RL003", "RL004", "RL005", "RL006",
                    "RL007"}
    lint_md = (ROOT / "docs" / "lint.md").read_text()
    for code in sorted(have):
        assert code in lint_md, f"docs/lint.md must document {code}"
