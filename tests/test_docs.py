"""Docs stay real: the architecture/benchmark guides exist, are linked
from the README, every relative markdown link resolves, and the doctested
snippets in docs/ execute. (CI's docs job runs the same checks via
tools/check_docs.py + python -m doctest.)"""

import doctest
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_docs_exist_and_linked_from_readme():
    for doc in ("docs/architecture.md", "docs/benchmarks.md"):
        assert (ROOT / doc).exists(), f"missing {doc}"
    readme = (ROOT / "README.md").read_text()
    assert "docs/architecture.md" in readme, "README must link the arch guide"
    assert "docs/benchmarks.md" in readme, "README must link the bench guide"


def test_doc_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py")],
        capture_output=True, text=True, timeout=60,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "links ok" in proc.stdout


def test_anchor_validation_catches_drift(tmp_path):
    """check_docs validates #fragment anchors against real headings —
    both cross-file (file.md#frag) and in-page (#frag) — with GitHub
    slug rules (case/punctuation folding, -N dup suffixes)."""
    sys.path.insert(0, str(ROOT))
    try:
        from tools import check_docs
    finally:
        sys.path.pop(0)
    target = tmp_path / "guide.md"
    target.write_text(
        "# The `warm()` Pre-Trace Table\n"
        "## Setup\n"
        "## Setup\n"            # duplicate heading -> setup, setup-1
        "```\n# not a heading (code fence)\n```\n"
    )
    page = tmp_path / "page.md"
    page.write_text(
        "[ok](guide.md#the-warm-pre-trace-table)\n"
        "[ok-dup](guide.md#setup-1)\n"
        "[in-page](#local)\n"
        "\n# Local\n"
        "[drift](guide.md#renamed-section)\n"
        "[fence](guide.md#not-a-heading-code-fence)\n"
        "[bad-in-page](#nowhere)\n"
    )
    broken = check_docs.check([page])
    assert len(broken) == 3, broken
    assert any("#renamed-section" in b for b in broken)
    assert any("#not-a-heading-code-fence" in b for b in broken)
    assert any("#nowhere" in b for b in broken)


def test_docs_doctests_pass():
    for md in sorted((ROOT / "docs").glob("*.md")):
        result = doctest.testfile(str(md), module_relative=False)
        assert result.failed == 0, f"{md.name}: {result.failed} doctest failures"
    # the benchmark guide's pow2 walkthrough must actually be doctested
    assert doctest.testfile(
        str(ROOT / "docs" / "benchmarks.md"), module_relative=False
    ).attempted >= 3
