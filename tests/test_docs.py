"""Docs stay real: the architecture/benchmark guides exist, are linked
from the README, every relative markdown link resolves, and the doctested
snippets in docs/ execute. (CI's docs job runs the same checks via
tools/check_docs.py + python -m doctest.)"""

import doctest
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_docs_exist_and_linked_from_readme():
    for doc in ("docs/architecture.md", "docs/benchmarks.md"):
        assert (ROOT / doc).exists(), f"missing {doc}"
    readme = (ROOT / "README.md").read_text()
    assert "docs/architecture.md" in readme, "README must link the arch guide"
    assert "docs/benchmarks.md" in readme, "README must link the bench guide"


def test_doc_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py")],
        capture_output=True, text=True, timeout=60,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "links ok" in proc.stdout


def test_docs_doctests_pass():
    for md in sorted((ROOT / "docs").glob("*.md")):
        result = doctest.testfile(str(md), module_relative=False)
        assert result.failed == 0, f"{md.name}: {result.failed} doctest failures"
    # the benchmark guide's pow2 walkthrough must actually be doctested
    assert doctest.testfile(
        str(ROOT / "docs" / "benchmarks.md"), module_relative=False
    ).attempted >= 3
