"""prefill(S) + K decode steps must reproduce forward(S+K) logits exactly
(fp32, no-drop MoE capacity) — the core serving-correctness invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import arch_cases, nodrop

from repro.configs import ARCHITECTURES
from repro.models import FRONTEND_DIM, Model
from repro.models.kvcache import grow_cache

TOL = 5e-4


@pytest.mark.parametrize(
    "name", arch_cases(("deepseek-v2-236b", "jamba-v0.1-52b"))
)
def test_prefill_decode_matches_forward(name, model_bank):
    cfg = nodrop(ARCHITECTURES[name].reduced())
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    B, S, K = 2, 16, 4
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + K)), jnp.int32)
    batch = {"tokens": toks}
    off = 0
    if cfg.is_encdec or cfg.frontend:
        batch["features"] = jnp.asarray(
            rng.normal(size=(B, 8, FRONTEND_DIM)), jnp.float32
        )
        if cfg.frontend and not cfg.is_encdec:
            off = 8

    logits_full, _, _ = model.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = toks[:, :S]
    lg, caches, lengths = model.prefill(params, pre)
    caches = grow_cache(caches, off + S + K)

    errs = [float(jnp.max(jnp.abs(lg - logits_full[:, off + S - 1])))]
    for k in range(K):
        lg, caches, lengths = model.decode_step(
            params, caches, toks[:, S + k : S + k + 1], lengths
        )
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, off + S + k]))))
    assert max(errs) < TOL, f"{name}: max logit err {max(errs):.2e}"


@pytest.mark.parametrize("name", ["llama3-8b", "qwen3-32b"])
def test_bucketed_prefill_matches_exact(name, model_bank):
    """Padded-bucket prefill (ragged batch) == per-row exact prefill on the
    last-token logits, for attention-only stacks (the only archs the engine
    buckets — SSM/hybrid recurrences would integrate pad tokens into their
    state, so the engine routes them to the exact path; see below)."""
    cfg = nodrop(ARCHITECTURES[name].reduced())
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    L = 32
    lens = [5, 17, 32, 9]
    rng = np.random.default_rng(3)
    rows = [rng.integers(0, cfg.vocab_size, s, dtype=np.int32) for s in lens]
    toks = np.zeros((len(lens), L), np.int32)
    for i, r in enumerate(rows):
        toks[i, : len(r)] = r
    lg_b, caches_b, lens_b = model.prefill_bucketed(
        params, {"tokens": jnp.asarray(toks)}, jnp.asarray(lens, jnp.int32)
    )
    assert (np.asarray(lens_b) == lens).all()
    for i, r in enumerate(rows):
        lg_e, _, _ = model.prefill(params, {"tokens": jnp.asarray(r[None, :])})
        err = float(jnp.max(jnp.abs(lg_b[i] - lg_e[0])))
        assert err < TOL, f"row {i} (len {lens[i]}): {err:.2e}"


@pytest.mark.slow
def test_ring_buffer_sliding_window_equivalence():
    """A full-capacity ring cache must equal attention over the last W tokens."""
    import dataclasses

    cfg = dataclasses.replace(
        ARCHITECTURES["llama3-8b"].reduced(), sliding_window=8
    )
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    B, S = 1, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    # reference: forward with sliding window mask
    logits_full, _, _ = model.forward(params, {"tokens": toks})
    # decode token-by-token through a W-slot ring
    W = cfg.sliding_window
    caches = model.init_cache(B, W)
    lengths = jnp.zeros((B,), jnp.int32)
    outs = []
    for t in range(S):
        lg, caches, lengths = model.decode_step(
            params, caches, toks[:, t : t + 1], lengths
        )
        outs.append(lg)
    for t in range(W, S):  # steady-state ring positions only
        err = float(jnp.max(jnp.abs(outs[t] - logits_full[:, t])))
        assert err < TOL, f"pos {t}: {err:.2e}"
