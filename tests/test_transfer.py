"""Transfer-layer numerics and accounting: round-trips for all three
TransferModes, per-source-pod quantization scales, wire-byte counts across
mixed-dtype cache trees, and the per-request cache-prefix byte helper.

Round-trips run on the 1-pod degenerate mesh (one CPU device — the pod
permute is an identity ring), which still executes the full quantize /
permute / dequantize path; CI's 8-device smoke covers the real 2-pod
collective."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import transfer as tr
from repro.core.transfer import TransferMode
from repro.models import kvcache as kvc


def pod1_mesh():
    return Mesh(np.asarray(jax.devices()[:1]), ("pod",))


def _tiled_tree(rng, npods=1):
    """Pod-tiled cache-like tree with float payload + int32 slot metadata."""
    k = rng.normal(size=(npods, 2, 8, 2, 4)).astype(np.float32) * 3.0
    v = rng.normal(size=(npods, 2, 8, 2, 4)).astype(np.float32)
    lens = rng.integers(0, 8, size=(npods, 2)).astype(np.int32)
    return {"k": jnp.asarray(k), "v": jnp.asarray(v),
            "meta": {"lengths": jnp.asarray(lens)}}


# --------------------------------------------------------------------------- #
# Numeric round-trips per mechanism
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "mode", [TransferMode.DIRECT_HBM, TransferMode.DIRECT_DMA]
)
def test_direct_modes_roundtrip_bit_exact(mode, rng):
    tree = _tiled_tree(rng)
    moved = tr.kv_transfer(tree, pod1_mesh(), mode=mode)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_host_staged_fp_within_int8_tolerance_ints_exact(rng):
    tree = _tiled_tree(rng)
    moved = tr.kv_transfer(tree, pod1_mesh(), mode=TransferMode.HOST_STAGED)
    # slot metadata must cross unquantized, bit-exact
    np.testing.assert_array_equal(
        np.asarray(moved["meta"]["lengths"]),
        np.asarray(tree["meta"]["lengths"]),
    )
    for key in ("k", "v"):
        a, b = np.asarray(tree[key]), np.asarray(moved[key])
        tol = np.abs(a).max() / 127.0  # one int8 quantization step
        np.testing.assert_allclose(b, a, atol=tol + 1e-6)


def test_host_staged_small_magnitude_reconstruction(rng):
    """Dequantization error must track the LEAF's own scale, not some global
    maximum — 0.01-magnitude data reconstructs to ~1e-4 absolute error."""
    x = {"k": jnp.asarray(rng.normal(size=(1, 4, 8)).astype(np.float32)) * 0.01}
    moved = tr.kv_transfer(x, pod1_mesh(), mode=TransferMode.HOST_STAGED)
    a, b = np.asarray(x["k"]), np.asarray(moved["k"])
    np.testing.assert_allclose(b, a, atol=np.abs(a).max() / 127 + 1e-9)


def test_pod_scales_are_per_source_pod():
    """Pod 0 holds unit-scale data, pod 1 holds 1000x data: pod 0's int8
    scale must NOT see pod 1's shard (the pre-fix global-max scale would
    blow pod 0's quantization step up 1000x)."""
    x = jnp.stack([jnp.linspace(-1.0, 1.0, 16),
                   1000.0 * jnp.linspace(-1.0, 1.0, 16)])
    s = np.asarray(tr._pod_scales(x))
    assert s.shape == (2,)
    np.testing.assert_allclose(s[0], 1.0 / 127.0, rtol=1e-5)
    np.testing.assert_allclose(s[1], 1000.0 / 127.0, rtol=1e-5)


# --------------------------------------------------------------------------- #
# Wire-byte accounting
# --------------------------------------------------------------------------- #
def test_transfer_bytes_counts_actual_itemsize_mixed_dtypes():
    """HOST_STAGED permutes float leaves as int8 (+ a per-pod fp32 scale)
    but integer leaves at FULL width — the pre-fix count charged 1
    byte/element for every leaf, undercounting int32 metadata 4x."""
    tiled = {
        "k": jnp.zeros((2, 3, 4), jnp.bfloat16),  # 12 elem/pod, quantized
        "lengths": jnp.zeros((2, 5), jnp.int32),  # 5 elem/pod, full width
        "q8": jnp.zeros((2, 7), jnp.int8),  # 7 elem/pod, full width
    }
    full = 12 * 2 + 5 * 4 + 7 * 1
    assert tr.transfer_bytes(tiled, TransferMode.DIRECT_HBM) == full
    assert tr.transfer_bytes(tiled, TransferMode.DIRECT_DMA) == full
    staged = 12 * 1 + 4 + 5 * 4 + 7 * 1  # int8 payload + scale; ints full
    assert tr.transfer_bytes(tiled, TransferMode.HOST_STAGED) == staged


def test_payload_wire_bytes_matches_tiled_accounting():
    payload = {"k": jnp.zeros((3, 4), jnp.bfloat16),
               "m": jnp.zeros((5,), jnp.int32)}
    tiled = tr.pod_tile(payload, 2, 0)
    for mode in TransferMode:
        assert (tr.payload_wire_bytes(payload, mode)
                == tr.transfer_bytes(tiled, mode))


def test_pod_tile_take_roundtrip():
    payload = {"a": jnp.arange(6).reshape(2, 3)}
    tiled = tr.pod_tile(payload, 3, src=1)
    assert jax.tree.leaves(tiled)[0].shape == (3, 2, 3)
    np.testing.assert_array_equal(
        np.asarray(tr.pod_take(tiled, 1)["a"]), np.asarray(payload["a"])
    )
    assert np.asarray(tr.pod_take(tiled, 0)["a"]).sum() == 0


# --------------------------------------------------------------------------- #
# Per-request cache-prefix bytes (what a disagg handoff charges one request)
# --------------------------------------------------------------------------- #
def test_request_cache_nbytes_mixed_tree():
    caches = {"g0": {
        "l0": {"k": jnp.zeros((2, 8, 2, 4), jnp.bfloat16),
               "v": jnp.zeros((2, 8, 2, 4), jnp.bfloat16)},
        "l1": {"conv": jnp.zeros((2, 3, 5), jnp.float32),
               "state": jnp.zeros((2, 2, 4, 3), jnp.float32)},
    }}
    # k/v per-token per-seq: 2*4 elem * 2B = 16B each; conv/state static
    # per-seq: 15*4=60B and 24*4=96B
    assert kvc.request_cache_nbytes(caches, 5) == 5 * 16 * 2 + 60 + 96
    # ring cap: true_len clamps at W=8
    assert kvc.request_cache_nbytes(caches, 99) == 8 * 16 * 2 + 60 + 96
    # wire-format override (int8 host staging)
    assert kvc.request_cache_nbytes(
        caches, 5, itemsize=lambda l: 1
    ) == 5 * 8 * 2 + 15 + 24


def test_request_cache_nbytes_scan_stacked():
    # stacked [L, B, W, H, hd]: the layer dim multiplies per-token bytes
    caches = {"g0": {"l0": {"k": jnp.zeros((3, 2, 8, 2, 4), jnp.float32)}}}
    assert kvc.request_cache_nbytes(caches, 4) == 4 * (3 * 2 * 4) * 4


# --------------------------------------------------------------------------- #
# Prefix slicing (what a prefix-only handoff puts on the wire)
# --------------------------------------------------------------------------- #
def _mixed_tree(rng):
    return {"g0": {
        "l0": {"k": jnp.asarray(rng.normal(size=(2, 8, 2, 4)), jnp.float32),
               "v": jnp.asarray(rng.normal(size=(2, 8, 2, 4)), jnp.float32)},
        "l1": {"conv": jnp.asarray(rng.normal(size=(2, 3, 5)), jnp.float32),
               "state": jnp.asarray(rng.normal(size=(2, 2, 4, 3)),
                                    jnp.float32)},
    }}


def test_slice_cache_ring_vs_static_leaves(rng):
    """Seq-keyed leaves slice both rows and ring prefix; static per-row
    leaves (SSM conv/state) slice rows only and keep their full payload."""
    tree = _mixed_tree(rng)
    s = kvc.slice_cache(tree, 1, 5)
    assert s["g0"]["l0"]["k"].shape == (1, 5, 2, 4)
    assert s["g0"]["l1"]["conv"].shape == (1, 3, 5)
    assert s["g0"]["l1"]["state"].shape == (1, 2, 4, 3)
    np.testing.assert_array_equal(
        np.asarray(s["g0"]["l0"]["k"]),
        np.asarray(tree["g0"]["l0"]["k"][:1, :5]),
    )
    # clamps to the leaf extent rather than over-slicing
    full = kvc.slice_cache(tree, 99, 999)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_slice_cache_scan_stacked_rows():
    # stacked [L, B, W, H, hd]: batch/ring axes sit behind the layer dim
    tree = {"k": jnp.zeros((3, 4, 16, 2, 4), jnp.float32)}
    assert kvc.slice_cache(tree, 2, 8)["k"].shape == (3, 2, 8, 2, 4)


def test_slice_pad_grow_roundtrip(rng):
    """slice -> pad_cache_rows -> grow_cache restores the pool shape with
    the valid prefix intact and zeros elsewhere (what the decode side does
    after the wire)."""
    tree = _mixed_tree(rng)
    s = kvc.slice_cache(tree, 1, 5)
    back = kvc.grow_cache(kvc.pad_cache_rows(s, 2), 8)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert a.shape == b.shape
    np.testing.assert_array_equal(
        np.asarray(back["g0"]["l0"]["k"][:1, :5]),
        np.asarray(tree["g0"]["l0"]["k"][:1, :5]),
    )
    assert np.asarray(back["g0"]["l0"]["k"][1:]).sum() == 0
    assert np.asarray(back["g0"]["l0"]["k"][:, 5:]).sum() == 0
