"""Per-arch smoke tests (assignment requirement): instantiate the REDUCED
variant of each family and run one forward/train step on CPU, asserting
output shapes and no NaNs.

The heaviest (arch, test) pairs are marked ``slow`` (see pyproject
``addopts``) so the default suite keeps one fast representative per family:
llama3/qwen3/starcoder2 (dense), granite (MoE), mamba2 (ssm).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import arch_cases

from repro.configs import ARCHITECTURES
from repro.models import FRONTEND_DIM, Model
from repro.models.layers import pad_vocab
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

SLOW_TRAIN = (
    "deepseek-v2-236b", "jamba-v0.1-52b", "grok-1-314b", "pixtral-12b",
    "seamless-m4t-large-v2",
)


def make_batch(cfg, B=2, S=32, rng=None):
    rng = rng or np.random.default_rng(0)
    if cfg.is_encdec or cfg.frontend:
        return {
            "features": jnp.asarray(
                rng.normal(size=(B, S // 2, FRONTEND_DIM)), jnp.bfloat16
            ),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S // 2)), jnp.int32
            ),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S // 2)), jnp.int32
            ),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }


@pytest.mark.parametrize("name", arch_cases(("deepseek-v2-236b",)))
def test_forward_shapes_no_nans(name, model_bank):
    cfg = ARCHITECTURES[name].reduced()
    model, params = model_bank(cfg)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, aux, _ = model.forward(params, batch)
    seq = batch["tokens"].shape[1] + (
        batch["features"].shape[1] if (cfg.frontend and not cfg.is_encdec) else 0
    )
    assert logits.shape == (B, seq, pad_vocab(cfg.vocab_size))
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("name", arch_cases(SLOW_TRAIN))
def test_one_train_step(name, model_bank):
    cfg = ARCHITECTURES[name].reduced()
    model, params = model_bank(cfg, remat=True)
    opt = adamw_init(params)
    batch = make_batch(cfg)
    loss0 = model.loss(params, batch)
    grads = jax.grad(lambda p: model.loss(p, batch))(params)
    new_params, new_opt, gnorm = adamw_update(AdamWConfig(), grads, opt, params)
    assert not bool(jnp.isnan(loss0)) and float(loss0) > 0
    assert float(gnorm) > 0 and not bool(jnp.isnan(gnorm))
    # parameters actually changed
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        params, new_params,
    )
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("name", arch_cases())
def test_decode_step_shapes(name, model_bank):
    cfg = ARCHITECTURES[name].reduced()
    model, params = model_bank(cfg)
    B, W = 2, 16
    caches = model.init_cache(B, W)
    lengths = jnp.full((B,), W, jnp.int32)  # steady-state ring
    toks = jnp.ones((B, 1), jnp.int32)
    logits, new_caches, new_len = model.decode_step(params, caches, toks, lengths)
    assert logits.shape == (B, pad_vocab(cfg.vocab_size))
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)
    assert (np.asarray(new_len) == W + 1).all()
