"""Paged KV pool + shared-prefix reuse invariants.

Covers the allocator's refcount protocol, the paged decode-attention
kernel vs the ring kernel, ring-vs-paged token identity across
architectures (fused engine; the disaggregated modes are asserted in
benchmarks/prefix.py on every CI run), the radix index's
longest-prefix-match law (hypothesis), wire-byte reconciliation at
0%/partial/100% prefix-hit rates, prefill sampling (top_k=1 == argmax),
the paged warmup grid (zero compiles in the serving window), and the
router's prefix_cache policy.
"""

import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import nodrop

from repro.configs import ARCHITECTURES, get_config
from repro.kernels import ops
from repro.models import kvcache as kvc
from repro.serving import ServingEngine
from repro.serving.cluster import Router
from repro.serving.prefix import RadixPrefixIndex
from repro.serving.request import Request


def _requests(cfg, prompts, max_new=4):
    return [
        Request(prompt_tokens=np.asarray(p, np.int32), max_new_tokens=max_new)
        for p in prompts
    ]


def _shared_prefix_prompts(cfg, *, n_families=2, per_family=2,
                           prefix_len=32, suffix_len=16, seed=0):
    """Interleaved families so later admission waves hit earlier waves'
    indexed prefixes."""
    rng = np.random.default_rng(seed)
    fams = [rng.integers(0, cfg.vocab_size, prefix_len, dtype=np.int32)
            for _ in range(n_families)]
    out = []
    for _ in range(per_family):
        for f in fams:
            out.append(np.concatenate(
                [f, rng.integers(0, cfg.vocab_size, suffix_len,
                                 dtype=np.int32)]
            ))
    return out


def _drain_tokens(eng, cfg, prompts, max_new=4):
    reqs = _requests(cfg, prompts, max_new)
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained(max_steps=100_000)
    assert len(out) == len(reqs)
    by_id = {r.request_id: r for r in out}
    return [tuple(by_id[r.request_id].tokens) for r in reqs]


# --------------------------------------------------------------------------- #
# Allocator: refcount round-trips
# --------------------------------------------------------------------------- #
def test_pool_refcount_roundtrip():
    pool = kvc.PagedKVPool(8, 16)
    assert pool.live_blocks == 0 and pool.free_count == 7

    ids = pool.alloc(3)
    assert ids is not None and 0 not in ids  # sentinel never handed out
    assert pool.live_blocks == 3

    pool.ref(ids)  # second reader (a prefix index, say)
    assert pool.deref(ids) == []  # still referenced: nothing freed
    assert pool.live_blocks == 3
    freed = pool.deref(ids)  # last reader drops
    assert sorted(freed) == sorted(ids)
    assert pool.live_blocks == 0 and pool.free_count == 7

    with pytest.raises(RuntimeError):
        pool.deref([ids[0]])  # double free
    with pytest.raises(RuntimeError):
        pool.ref([ids[0]])  # ref of a free block

    assert pool.alloc(8) is None  # only 7 non-sentinel blocks exist
    again = pool.alloc(7)
    assert sorted(again) == list(range(1, 8))  # deterministic ascending
    # sentinel refs survive everything
    pool.ref([0])
    assert pool.deref([0]) == []
    pool.reset()
    assert pool.live_blocks == 0 and pool.free_count == 7


# --------------------------------------------------------------------------- #
# Kernel: page-table gather == ring attention
# --------------------------------------------------------------------------- #
def test_paged_decode_attention_matches_ring_kernel():
    rng = np.random.default_rng(0)
    B, W, Hkv, G, hd, page = 3, 64, 2, 2, 16, 16
    n_pages = W // page
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, W, Hkv, hd)), jnp.float32)
    lens = jnp.asarray([9, 33, 64], jnp.int32)

    # scatter the dense rows into a shuffled block pool (block 0 = zero
    # sentinel), record where each logical page landed
    perm = rng.permutation(B * n_pages) + 1
    kb = np.zeros((B * n_pages + 1, page, Hkv, hd), np.float32)
    vb = np.zeros_like(kb)
    pt = np.zeros((B, n_pages), np.int32)
    for b in range(B):
        for j in range(n_pages):
            dst = perm[b * n_pages + j]
            kb[dst] = np.asarray(k[b, j * page:(j + 1) * page])
            vb[dst] = np.asarray(v[b, j * page:(j + 1) * page])
            pt[b, j] = dst

    out_ring = ops.decode_attention(q, k, v, lens, block_k=page)
    out_paged = ops.paged_decode_attention(
        q, jnp.asarray(kb), jnp.asarray(vb), jnp.asarray(pt), lens
    )
    np.testing.assert_allclose(
        np.asarray(out_paged), np.asarray(out_ring), atol=1e-6, rtol=0
    )


# --------------------------------------------------------------------------- #
# Engine: ring vs paged token identity across architectures
# --------------------------------------------------------------------------- #
_PAGED_ARCHS = [
    "llama3-8b",
    "starcoder2-3b",
    pytest.param("qwen3-32b", marks=pytest.mark.slow),
    pytest.param("grok-1-314b", marks=pytest.mark.slow),
    # MLA: paged pool without prefix reuse (latent prior can't be gathered)
    pytest.param("deepseek-v2-236b", marks=pytest.mark.slow),
]


@pytest.mark.parametrize("name", _PAGED_ARCHS)
def test_paged_vs_ring_token_identity(name, model_bank):
    cfg = nodrop(ARCHITECTURES[name].reduced())
    model, params = model_bank(cfg)
    prompts = _shared_prefix_prompts(cfg)
    kw = dict(max_batch=2, max_seq=128, temperature=0.0)

    ring = _drain_tokens(ServingEngine(model, params, **kw), cfg, prompts)
    eng = ServingEngine(model, params, paged=True, page_size=16, **kw)
    assert eng.prefix_reuse == (model.cfg.mla is None)
    paged = _drain_tokens(eng, cfg, prompts)
    assert paged == ring
    if eng.prefix_reuse:
        # the interleaved families genuinely exercised reuse
        assert eng.prefix_hits > 0
        assert eng.prefill_tokens_uncached < eng.prefill_tokens_total


def test_paged_reuse_counters_and_no_block_leak(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=2, max_seq=128,
                        paged=True, page_size=16, temperature=0.0)
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab_size, 48, dtype=np.int32)
    mk = lambda: np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)]
    )
    _drain_tokens(eng, cfg, [mk()])  # prime: indexes the prefix pages
    t0, u0 = eng.prefill_tokens_total, eng.prefill_tokens_uncached
    assert t0 == u0 == 64  # nothing cached on the first admission
    _drain_tokens(eng, cfg, [mk()])  # same system prompt, fresh suffix
    assert eng.prefix_hits == 1
    assert eng.prefix_hit_tokens == 48
    assert eng.prefill_tokens_total - t0 == 64
    assert eng.prefill_tokens_uncached - u0 == 16  # suffix only

    # every live block is accounted for: slots are free post-drain, so
    # clearing the index (deref both of each payload's references) must
    # drain the allocator to zero — the refcount protocol leaks nothing
    for (p, d) in eng.prefix_index.clear():
        eng.pool.allocator.deref([p])
        eng.pool.allocator.deref([d])
    assert eng.pool.allocator.live_blocks == 0


# --------------------------------------------------------------------------- #
# Radix index: longest-prefix-match law (hypothesis when available, a
# seeded random sweep of the same property otherwise)
# --------------------------------------------------------------------------- #
def _check_lpm_law(corpus, query, page=2):
    """match(query) length == the longest page-aligned common prefix
    between the query and ANY inserted prompt (tiny alphabet so overlaps
    actually occur), and the returned payloads identify those pages."""
    idx = RadixPrefixIndex(page)
    for i, toks in enumerate(corpus):
        n = len(toks) // page
        idx.insert(toks, [(i, j) for j in range(n)])

    got = idx.match(query)

    def common_pages(a, b):
        n = 0
        while ((n + 1) * page <= min(len(a), len(b))
               and a[n * page:(n + 1) * page] == b[n * page:(n + 1) * page]):
            n += 1
        return n

    want = max((common_pages(toks, query) for toks in corpus), default=0)
    assert len(got) == want, (corpus, query, got)
    # each matched page's payload points at a prompt that shares the
    # query's prefix through that page
    for j, (i, jj) in enumerate(got):
        assert jj == j
        assert corpus[i][: (j + 1) * page] == query[: (j + 1) * page]


try:
    from hypothesis import given, settings, strategies as st

    _tokens = st.lists(st.integers(0, 3), min_size=0, max_size=24)

    @given(corpus=st.lists(_tokens, min_size=0, max_size=6), query=_tokens)
    @settings(max_examples=200, deadline=None)
    def test_radix_longest_prefix_match_law(corpus, query):
        _check_lpm_law(corpus, query)

except ImportError:

    @pytest.mark.parametrize("seed", range(50))
    def test_radix_longest_prefix_match_law(seed):
        rng = np.random.default_rng(seed)
        corpus = [
            [int(t) for t in rng.integers(0, 4, rng.integers(0, 25))]
            for _ in range(rng.integers(0, 7))
        ]
        query = [int(t) for t in rng.integers(0, 4, rng.integers(0, 25))]
        _check_lpm_law(corpus, query)


def test_radix_capacity_evicts_lru_leaves():
    idx = RadixPrefixIndex(1, capacity_pages=3)
    idx.insert([1, 2], ["a1", "a2"])
    # shares page [1] (first writer wins there) -> only 1 new page
    idx.insert([1, 3], ["b1", "b2"])
    assert idx.n_pages == 3
    idx.match([1, 2])  # touch the [1,2] chain; [1,3] is now LRU leaf
    idx.insert([9], ["c1"])
    assert idx.n_pages == 3  # evicted one leaf to fit
    assert idx.match([1, 3], peek=True) == ["a1"]  # leaf gone, trunk kept
    assert idx.match([1, 2], peek=True) == ["a1", "a2"]


# --------------------------------------------------------------------------- #
# Disaggregated tier: exact wire-byte reconciliation at 0/partial/100% hit
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("prefix_len,prompt_len", [
    (0, 48),    # 0% hit: nothing primed
    (32, 64),   # partial: half the prompt cached
    (48, 49),   # 100%: every full page cached, one suffix token remains
])
def test_disagg_paged_wire_reconciliation(prefix_len, prompt_len,
                                          model_bank):
    from repro.core.transfer import TransferMode
    from repro.serving import DisaggregatedEngine, make_pod_mesh

    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM,
        mesh=make_pod_mesh(), charge="modeled", max_batch=2, max_seq=128,
        paged=True, page_size=16, temperature=0.0,
    )
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len, dtype=np.int32)
    mk = lambda: np.concatenate([
        prefix,
        rng.integers(0, cfg.vocab_size, prompt_len - prefix_len,
                     dtype=np.int32),
    ])
    if prefix_len:
        _drain_tokens(eng, cfg, [np.concatenate(
            [prefix, rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)]
        )], max_new=2)
    u0, wire0 = eng.prefill_tokens_uncached, eng.handoff_wire_bytes
    _drain_tokens(eng, cfg, [mk(), mk()], max_new=2)
    # what the collective moved == the geometry oracle for the
    # refcount-adjusted suffix payloads, byte for byte
    assert eng.handoff_wire_bytes == eng.handoff_payload_bytes
    assert eng.handoff_wire_bytes > wire0
    # prefill paid only the uncached suffixes
    assert (eng.prefill_tokens_uncached - u0
            == 2 * (prompt_len - prefix_len))


# --------------------------------------------------------------------------- #
# Prefill sampling: top_k=1 must equal the greedy argmax path exactly
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("paged", [False, True])
def test_prefill_sampling_topk1_equals_argmax(paged, model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    kw = dict(max_batch=2, max_seq=128)
    if paged:
        kw.update(paged=True, page_size=16)
    prompts = _shared_prefix_prompts(cfg)

    greedy = _drain_tokens(
        ServingEngine(model, params, temperature=0.0, **kw), cfg, prompts
    )
    top1 = _drain_tokens(
        ServingEngine(model, params, temperature=0.7, top_k=1,
                      sample_seed=123, **kw),
        cfg, prompts,
    )
    # a top-1 categorical IS the argmax, whatever the key or temperature
    assert top1 == greedy

    sampled = _drain_tokens(
        ServingEngine(model, params, temperature=1.5, top_k=0,
                      sample_seed=7, **kw),
        cfg, prompts,
    )
    assert all(len(t) == len(g) for t, g in zip(sampled, greedy))


# --------------------------------------------------------------------------- #
# Warmup: the paged jit grid is pre-traced
# --------------------------------------------------------------------------- #
class _LogGrab(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.messages = []

    def emit(self, record):
        self.messages.append(record.getMessage())


def _compiles_during(fn):
    grab = _LogGrab()
    logger = logging.getLogger("jax")
    old_level = logger.level
    logger.addHandler(grab)
    logger.setLevel(logging.DEBUG)
    try:
        with jax.log_compiles():
            fn()
    finally:
        logger.removeHandler(grab)
        logger.setLevel(old_level)
    return [m for m in grab.messages if m.startswith("Compiling ")]


def test_paged_warmup_zero_compiles(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    kw = dict(max_batch=2, max_seq=64, paged=True, page_size=16,
              temperature=0.0)
    prompts = _shared_prefix_prompts(cfg, prefix_len=16, suffix_len=9)

    # positive control: a cold paged engine's drain compiles
    cold = ServingEngine(model, params, **kw)
    assert _compiles_during(
        lambda: _drain_tokens(cold, cfg, prompts, max_new=2)
    ), "log capture saw no compiles from a cold paged engine"

    warm = ServingEngine(model, params, warmup=True, **kw)
    assert warm.warm_s > 0
    compiles = _compiles_during(
        lambda: _drain_tokens(warm, cfg, prompts, max_new=2)
    )
    assert compiles == [], f"compiled inside timed window: {compiles}"
    assert warm.prefix_hits > 0  # the suffix-prefill path ran, pre-traced


# --------------------------------------------------------------------------- #
# Engine gates
# --------------------------------------------------------------------------- #
def test_paged_engine_gates(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    with pytest.raises(ValueError, match="multiple of"):
        ServingEngine(model, params, max_batch=2, max_seq=128, paged=True,
                      page_size=24)  # min_bucket 16 not page-aligned
    eng = ServingEngine(model, params, max_batch=2, max_seq=64, paged=True,
                        page_size=16)
    with pytest.raises(ValueError, match="feature"):
        eng.submit(Request(
            prompt_tokens=np.arange(4, dtype=np.int32), max_new_tokens=2,
            features=np.zeros((1, 3, 8), np.float32),
        ))
    with pytest.raises(ValueError, match="ring-wraps"):
        eng.submit(Request(
            prompt_tokens=np.arange(60, dtype=np.int32), max_new_tokens=8,
        ))


# --------------------------------------------------------------------------- #
# Router: prefix_cache policy
# --------------------------------------------------------------------------- #
class _StubEngine:
    def __init__(self, score):
        self.score = score
        self.page = 16

    def prefix_lookup_tokens(self, tokens):
        return self.score


class _StubReplica:
    def __init__(self, score, outstanding=0, jobs=0):
        self.engine = _StubEngine(score)
        self.outstanding_tokens = outstanding
        self.jobs = jobs


def _req(first_page=0):
    return Request(
        prompt_tokens=np.full(40, first_page, np.int32), max_new_tokens=4
    )


def test_router_prefix_cache_routes_to_deepest_match():
    router = Router("prefix_cache")
    assert "prefix_cache" in Router.POLICIES
    reps = [_StubReplica(0), _StubReplica(48), _StubReplica(16)]
    assert router.pick(_req(), reps) == 1  # deepest cached prefix wins
    # ties break toward the less-loaded replica
    reps = [_StubReplica(32, outstanding=10), _StubReplica(32, outstanding=2)]
    assert router.pick(_req(), reps) == 1


def test_router_prefix_cache_cold_fallback_is_sticky():
    router = Router("prefix_cache")
    reps = [_StubReplica(0, outstanding=5), _StubReplica(0, outstanding=1)]
    first = router.pick(_req(first_page=7), reps)
    assert first == 1  # least outstanding takes the cold prefix
    # load flips, but the same system prompt stays home...
    reps[0].outstanding_tokens, reps[1].outstanding_tokens = 1, 50
    assert router.pick(_req(first_page=7), reps) == first
    # ...while a different cold prefix goes to the now-lighter replica
    assert router.pick(_req(first_page=8), reps) == 0


def test_router_prefix_cache_on_real_cluster(model_bank):
    from repro.serving.cluster import ServingCluster
    from repro.serving.loadgen import shared_prefix_schedule

    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    cluster = ServingCluster.build(
        model, params, n_replicas=2, engine="fused", policy="prefix_cache",
        max_batch=2, max_seq=128, paged=True, page_size=16, temperature=0.0,
    )
    sched = shared_prefix_schedule(
        cfg.vocab_size, rate_rps=100.0, n_requests=8, n_prefixes=2,
        prefix_len=32, suffix_len=16, max_new=2, seed=5,
    )
    for a in sched:
        cluster.submit(a.request)
    assert len(cluster.run_until_drained()) == len(sched)
    # each system-prompt family lands wholly on one replica
    fams = {}
    for a in sched:
        key = tuple(int(t) for t in a.request.prompt_tokens[:16])
        fams.setdefault(key, set()).add(
            cluster.replica_of(a.request.request_id)
        )
    assert all(len(v) == 1 for v in fams.values()), fams
