"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def arr(rng, *s, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=s), dtype)


TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,H,Hkv,hd",
    [
        (1, 128, 128, 4, 4, 64),  # MHA, block-aligned
        (2, 200, 200, 8, 2, 64),  # GQA, ragged
        (1, 64, 256, 4, 1, 32),  # MQA, cross-length
        (2, 33, 129, 6, 3, 128),  # odd sizes
    ],
)
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention_sweep(rng, dtype, B, Sq, Skv, H, Hkv, hd, causal, window):
    if causal and Sq != Skv:
        pytest.skip("causal requires square")
    q = arr(rng, B, Sq, H, hd, dtype=dtype)
    k = arr(rng, B, Skv, Hkv, hd, dtype=dtype)
    v = arr(rng, B, Skv, Hkv, hd, dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=TOLS[dtype], rtol=1e-2
    )


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,W,H,Hkv,hd,block_k",
    [
        (1, 128, 4, 4, 64, 64),
        (2, 300, 8, 2, 64, 128),
        (3, 64, 6, 1, 128, 512),
        (2, 1024, 16, 8, 32, 256),
    ],
)
def test_decode_attention_sweep(rng, dtype, B, W, H, Hkv, hd, block_k):
    q = arr(rng, B, 1, H, hd, dtype=dtype)
    k = arr(rng, B, W, Hkv, hd, dtype=dtype)
    v = arr(rng, B, W, Hkv, hd, dtype=dtype)
    lens = jnp.asarray(rng.integers(1, W + 1, (B,)), jnp.int32)
    out = ops.decode_attention(q, k, v, lens, block_k=block_k)
    want = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=TOLS[dtype], rtol=1e-2
    )


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,S,nh,hd,ds,chunk",
    [
        (1, 64, 2, 32, 16, 16),
        (2, 96, 4, 32, 16, 32),
        (1, 128, 1, 64, 64, 64),
        (2, 100, 3, 16, 8, 32),  # ragged seq (padded inside ops)
    ],
)
def test_ssd_scan_sweep(rng, dtype, b, S, nh, hd, ds, chunk):
    x = arr(rng, b, S, nh, hd, dtype=dtype)
    dt = jnp.abs(arr(rng, b, S, nh)) * 0.1 + 0.01
    A = -jnp.abs(arr(rng, nh)) - 0.1
    B = arr(rng, b, S, 1, ds)
    C = arr(rng, b, S, 1, ds)
    y, st = ops.ssd_scan(x, dt.astype(dtype), A, B.astype(dtype), C.astype(dtype), chunk=chunk)
    yw, stw = ref.ssd_scan_ref(x, dt.astype(dtype), A, B.astype(dtype), C.astype(dtype))
    atol = 3e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        y.astype(jnp.float32), yw.astype(jnp.float32), atol=atol, rtol=2e-2
    )
    np.testing.assert_allclose(st, stw, atol=atol, rtol=2e-2)


# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,D", [(16, 128), (300, 512), (1, 64), (257, 384)])
def test_rmsnorm_sweep(rng, dtype, N, D):
    x = arr(rng, N, D, dtype=dtype)
    w = arr(rng, D, dtype=dtype)
    out = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=TOLS[dtype], rtol=1e-2
    )


@pytest.mark.parametrize("N,D", [(8, 128), (100, 512), (1000, 64)])
def test_preprocess_sweep(rng, N, D):
    x = jnp.asarray(rng.integers(0, 256, (N, D)), jnp.uint8)
    mean = jnp.abs(arr(rng, D)) * 0.4 + 0.1
    std = jnp.abs(arr(rng, D)) * 0.2 + 0.3
    out = ops.preprocess(x, mean, std)
    want = ref.preprocess_ref(x, mean, std)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=1e-2, rtol=1e-2
    )
