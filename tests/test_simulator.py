"""The simulator must reproduce the paper's findings (DESIGN.md §1 F1-F7)."""

import pytest

from repro.core import (
    TABLE_II,
    ScenarioConfig,
    Transport,
    local_reference,
    run_scenario,
)


def mean_ms(store, **kw):
    return store.summary(**kw)["mean"] * 1e3


def run(w, t, **kw):
    return run_scenario(ScenarioConfig(workload=TABLE_II[w], transport=t, **kw))


# F1 — single client: GDR < RDMA < TCP; GDR saves 15-50% on ResNet50
def test_f1_single_client_ordering_and_magnitude():
    res = {t: mean_ms(run("resnet50", t)) for t in
           (Transport.GDR, Transport.RDMA, Transport.TCP)}
    assert res[Transport.GDR] < res[Transport.RDMA] < res[Transport.TCP]
    save = (res[Transport.TCP] - res[Transport.GDR]) / res[Transport.TCP]
    assert 0.15 <= save <= 0.50, f"GDR saves {save:.0%}"


def test_f1_gdr_near_local():
    """GDR adds only ~0.27-0.53 ms over local processing (paper §IV-A)."""
    for pre in (False, True):
        s = run_scenario(ScenarioConfig(workload=TABLE_II["resnet50"],
                                        transport=Transport.GDR, preprocessed=pre))
        loc = local_reference(ScenarioConfig(workload=TABLE_II["resnet50"], preprocessed=pre))
        delta_ms = mean_ms(s) - loc * 1e3
        assert 0.1 < delta_ms < 0.8, delta_ms


def test_f1_deeplab_tcp_penalty():
    """Large I/O: TCP adds ~70ms (paper: 71/68ms) vs GDR/RDMA."""
    res = {t: mean_ms(run("deeplabv3", t)) for t in
           (Transport.GDR, Transport.RDMA, Transport.TCP)}
    assert 55 < res[Transport.TCP] - res[Transport.GDR] < 95
    assert 50 < res[Transport.TCP] - res[Transport.RDMA] < 90


# F2 — communication fraction: small models gain relatively more
def test_f2_overhead_ordering():
    over = {}
    for w in ("mobilenetv3", "resnet50", "wideresnet101"):
        s = run(w, Transport.GDR)
        loc = local_reference(ScenarioConfig(workload=TABLE_II[w])) * 1e3
        over[w] = (mean_ms(s) - loc) / loc
    assert over["mobilenetv3"] > over["resnet50"] > over["wideresnet101"]
    assert over["wideresnet101"] < 0.06  # paper: ~4.5%


# F3 — proxied: TCP/GDR captures most of the end-to-end RDMA/GDR gain
def test_f3_proxied_last_hop():
    w = TABLE_II["mobilenetv3"]

    def proxied(first, second):
        return mean_ms(run_scenario(ScenarioConfig(
            workload=w, transport=second, first_hop=first)))

    tcp_tcp = proxied(Transport.TCP, Transport.TCP)
    tcp_gdr = proxied(Transport.TCP, Transport.GDR)
    tcp_rdma = proxied(Transport.TCP, Transport.RDMA)
    assert tcp_gdr < tcp_rdma < tcp_tcp
    assert (tcp_tcp - tcp_gdr) / tcp_tcp > 0.15  # paper: 57% saved

    # under concurrency the last-hop GDR approaches end-to-end acceleration
    # (paper Fig. 14: +4%; ours ~+25-30% — the deviation comes from payload
    # assumptions: we model raw RGB frames where the paper's clients likely
    # send compressed captures. Recorded in EXPERIMENTS.md §Deviations.)
    kw = dict(n_clients=16, requests_per_client=20)
    tg = mean_ms(run_scenario(ScenarioConfig(
        workload=w, transport=Transport.GDR, first_hop=Transport.TCP, **kw)))
    rg = mean_ms(run_scenario(ScenarioConfig(
        workload=w, transport=Transport.GDR, first_hop=Transport.RDMA, **kw)))
    tt = mean_ms(run_scenario(ScenarioConfig(
        workload=w, transport=Transport.TCP, first_hop=Transport.TCP, **kw)))
    assert tg < tt
    assert abs(tg - rg) / rg < 0.45  # paper: within 4%; see §Deviations
    assert (tt - tg) / tt > 0.20  # paper: last-hop GDR saves 27% vs TCP/TCP


# F4 — concurrency: copy engine strips RDMA's advantage
def test_f4_rdma_converges_to_tcp():
    w = "deeplabv3"
    kw = dict(n_clients=16, requests_per_client=24)
    gdr = mean_ms(run(w, Transport.GDR, **kw))
    rdma = mean_ms(run(w, Transport.RDMA, **kw))
    tcp = mean_ms(run(w, Transport.TCP, **kw))
    assert gdr < rdma
    assert rdma / tcp > 0.85  # RDMA lost its edge (paper: ~equal)
    assert (tcp - gdr) > 25  # GDR still saves big (paper: 160ms)


# F5 — limiting concurrency trades queueing for variability
def test_f5_stream_limit_tradeoff():
    w = "resnet50"
    kw = dict(n_clients=16, requests_per_client=24, transport=Transport.GDR)
    one = run_scenario(ScenarioConfig(workload=TABLE_II[w], max_streams=1, **kw))
    sixteen = run_scenario(ScenarioConfig(workload=TABLE_II[w], max_streams=0, **kw))
    assert one.summary()["mean"] > sixteen.summary()["mean"]  # queueing up
    assert one.processing_cov() <= sixteen.processing_cov() + 1e-6  # variability down


# F6 — priorities: protected under GDR, lost under RDMA
def test_f6_priority_protection():
    w = TABLE_II["yolov4"]
    kw = dict(n_clients=16, n_priority_clients=1, requests_per_client=20,
              preprocessed=True)
    gdr = run_scenario(ScenarioConfig(workload=w, transport=Transport.GDR, **kw))
    rdma = run_scenario(ScenarioConfig(workload=w, transport=Transport.RDMA, **kw))

    def ratio(store):  # priority latency / normal latency
        hi = store.summary(priority=1)["mean"]
        lo = store.summary(priority=0)["mean"]
        return hi / lo

    assert ratio(gdr) < 0.75  # clearly protected
    assert ratio(rdma) > ratio(gdr)  # protection eroded by the copy engine


# F7 — sharing modes: mps >= multi-stream > multi-context; under RDMA
# mps beats multi-stream, under GDR they tie
def test_f7_sharing_modes():
    w = TABLE_II["efficientnetb0"]
    kw = dict(n_clients=8, requests_per_client=24)

    def m(transport, sharing):
        return mean_ms(run_scenario(ScenarioConfig(
            workload=w, transport=transport, sharing=sharing, **kw)))

    gdr = {s: m(Transport.GDR, s) for s in ("multi-stream", "multi-context", "mps")}
    rdma = {s: m(Transport.RDMA, s) for s in ("multi-stream", "multi-context", "mps")}
    assert gdr["multi-context"] > gdr["mps"]
    assert rdma["multi-context"] > rdma["mps"]
    assert rdma["mps"] <= rdma["multi-stream"] + 1e-6
    # GDR: stream ~ mps (no copies to interleave differently)
    assert abs(gdr["mps"] - gdr["multi-stream"]) / gdr["multi-stream"] < 0.10


def test_profiler_stage_accounting():
    """Stage times must (almost) add up to total for a single client."""
    s = run("resnet50", Transport.RDMA)
    rec = s.records[10]
    accounted = sum(rec.stage_s.values())
    assert accounted <= rec.total + 1e-9
    assert accounted / rec.total > 0.9


def test_cpu_usage_tcp_highest():
    cpu = {}
    for t in (Transport.GDR, Transport.RDMA, Transport.TCP):
        cpu[t] = run("deeplabv3", t).cpu_per_request()
    assert cpu[Transport.TCP] > 2 * cpu[Transport.GDR]  # paper Fig 9: ~2x
