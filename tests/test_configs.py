"""The 10 assigned architectures: exact hyper-parameters + registry."""

import pytest

from repro.configs import ARCHITECTURES, SHAPES, get_config, get_shape

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, family)
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072, "vlm"),
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256, "dense"),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, "hybrid"),
    "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400, "moe"),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206, "audio"),
    "qwen3-32b": (64, 5120, 64, 8, 25600, 151936, "dense"),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152, "dense"),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072, "moe"),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280, "ssm"),
    "granite-34b": (88, 6144, 48, 1, 24576, 49152, "dense"),
}

PARAM_BUDGET = {  # billions, |count - nominal|/nominal tolerance
    "pixtral-12b": (12, 0.15),
    "llama3-8b": (8, 0.1),
    "jamba-v0.1-52b": (52, 0.1),
    "deepseek-v2-236b": (236, 0.05),
    "grok-1-314b": (314, 0.05),
    "qwen3-32b": (32, 0.1),
    "mamba2-130m": (0.13, 0.6),
}


def test_all_ten_present():
    assert len(ARCHITECTURES) == 10
    assert set(EXPECTED) == set(ARCHITECTURES)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_hyperparams(name):
    c = get_config(name)
    L, d, h, kv, ff, v, fam = EXPECTED[name]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (L, d, h, kv)
    assert (c.d_ff, c.vocab_size, c.family) == (ff, v, fam)
    assert c.source  # every config must cite its source


def test_arch_specifics():
    ds = get_config("deepseek-v2-236b")
    assert ds.mla.kv_lora_rank == 512 and ds.moe.n_experts == 160
    assert ds.moe.top_k == 6 and ds.moe.n_shared_experts == 2
    jm = get_config("jamba-v0.1-52b")
    assert jm.moe.n_experts == 16 and jm.moe.top_k == 2
    assert jm.n_attn_layers() == 4  # 1:7 attention:mamba
    gk = get_config("grok-1-314b")
    assert gk.moe.n_experts == 8 and gk.moe.top_k == 2
    mb = get_config("mamba2-130m")
    assert mb.ssm.d_state == 128 and mb.is_attention_free
    qw = get_config("qwen3-32b")
    assert qw.qk_norm
    sm = get_config("seamless-m4t-large-v2")
    assert sm.is_encdec and sm.encoder_layers == 24


@pytest.mark.parametrize("name", sorted(PARAM_BUDGET))
def test_param_counts(name):
    nominal, tol = PARAM_BUDGET[name]
    n = get_config(name).param_count() / 1e9
    assert abs(n - nominal) / nominal <= tol, f"{name}: {n:.1f}B vs {nominal}B"


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_reduced_constraints(name):
    r = get_config(name).reduced()
    assert r.n_layers <= 2 and r.d_model <= 512
    if r.moe is not None:
        assert r.moe.n_experts <= 4
    assert r.family == get_config(name).family


def test_shapes():
    assert len(SHAPES) == 4
    s = get_shape("train_4k")
    assert s.seq_len == 4096 and s.global_batch == 256 and s.kind == "train"
    assert get_shape("prefill_32k").global_batch == 32
    assert get_shape("decode_32k").kind == "decode"
    assert get_shape("long_500k").seq_len == 524288
