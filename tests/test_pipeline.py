"""Threaded host pipeline (EnginePipeline): token identity with the
synchronous step() loop, record conservation, and failure surfacing."""

import numpy as np
import pytest

from benchmarks.serving import make_requests, micro_config


@pytest.fixture(scope="module")
def served():
    """One micro model + params shared across the module's engines."""
    import jax

    from repro.models.model import Model

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _engine(model, params, **kw):
    from repro.serving.engine import ServingEngine

    kw.setdefault("max_batch", 3)
    kw.setdefault("max_seq", 64)
    return ServingEngine(model, params, **kw)


def test_pipeline_token_identity_and_conservation(served):
    """The three-thread pipeline must produce byte-identical tokens to the
    synchronous engine on the same requests, and emit exactly one response
    per submission (the no-reorder/no-drop invariant)."""
    from repro.serving.engine import EnginePipeline

    cfg, model, params = served
    lens = [8, 12, 20, 5, 16, 9, 30, 7]

    eng = _engine(model, params)
    reqs = make_requests(cfg, lens, 6, seed=3)
    for r in reqs:
        eng.submit(r)
    base = {r.request_id: r.tokens for r in eng.run_until_drained()}

    eng2 = _engine(model, params)
    with EnginePipeline(eng2) as pipe:
        assert pipe.async_draining
        reqs2 = make_requests(cfg, lens, 6, seed=3)
        for r in reqs2:
            pipe.submit(r)
        out = pipe.run_until_drained(max_steps=200_000)
        # conservation: one response per submission, nothing dropped or
        # duplicated by the stale-snapshot handling across thread handoffs
        assert pipe.submitted == len(reqs2)
        assert pipe.emitted == len(reqs2)
        assert len(out) == len(reqs2)
        assert sorted(r.request_id for r in out) == \
            sorted(r.request_id for r in reqs2)
        assert pipe.idle
        # identity: align by submission order (fresh ids per run)
        a = [base[i] for i in sorted(base)]
        b = {r.request_id: r.tokens for r in out}
        b = [b[i] for i in sorted(b)]
        assert a == b
        snap = pipe.load_snapshot()
        assert snap["idle"] and snap["submitted"] == snap["emitted"]
        assert snap["submitted_bytes"] == sum(r.payload_bytes for r in reqs2)


def test_pipeline_records_complete(served):
    """Every finished request's record carries a t_done and the inference
    stage — finalize ran exactly once per request despite the handoffs."""
    from repro.serving.engine import EnginePipeline

    cfg, model, params = served
    eng = _engine(model, params)
    with EnginePipeline(eng) as pipe:
        reqs = make_requests(cfg, [8, 16, 24], 5, seed=1)
        for r in reqs:
            pipe.submit(r)
        out = pipe.run_until_drained(max_steps=200_000)
        assert len(out) == len(reqs)
        assert len(pipe.store.records) == len(reqs)
        for rec in pipe.store.records:
            assert rec.t_done > rec.t_issue
            assert rec.stage_s.get("inference", 0.0) >= 0.0
            assert "preprocess" in rec.stage_s  # the prefill stage
            assert "queue" in rec.stage_s


def test_pipeline_thread_failure_surfaces(served):
    """A crash on a pipeline thread must re-raise on the caller's next
    touch (with the worker traceback), never hang the facade."""
    from repro.serving.engine import EnginePipeline

    cfg, model, params = served
    eng = _engine(model, params)

    def boom():
        raise RuntimeError("synthetic admission failure")

    pipe = EnginePipeline(eng)
    try:
        eng._admit = boom
        with pytest.raises(RuntimeError, match="synthetic admission"):
            deadline = 200
            while deadline:
                pipe.idle  # noqa: B018 — poking the facade re-raises
                deadline -= 1
                import time

                time.sleep(0.01)
            raise AssertionError("pipeline failure never surfaced")
    finally:
        pipe.close()


def test_pipeline_rejects_legacy_and_bad_backlog(served):
    from repro.serving.engine import EnginePipeline

    cfg, model, params = served
    legacy = _engine(model, params, legacy=True)
    with pytest.raises(ValueError, match="legacy"):
        EnginePipeline(legacy)
    eng = _engine(model, params)
    with pytest.raises(ValueError, match="backlog"):
        EnginePipeline(eng, backlog=0)
    # close is idempotent
    pipe = EnginePipeline(eng)
    pipe.close()
    pipe.close()


def test_merge_record_streams_skew_tolerance():
    """Rebasing with per-stream clock offsets must put records on one
    timeline: absolute stamps shift by the offset, durations (stage_s,
    t_done - t_issue) are untouched, order is completion order."""
    from repro.core.metrics import merge_record_streams
    from repro.core.profiler import RequestRecord

    def rec(rid, t0, dur):
        r = RequestRecord(request_id=rid, client_id=0, priority=0,
                          t_issue=t0, bytes_in=4, bytes_out=4)
        r.t_done = t0 + dur
        r.add("inference", dur)
        return r

    # stream B's process booted with a perf_counter epoch 1000s ahead
    a = [rec(0, 10.0, 1.0), rec(2, 12.0, 2.0)]
    b = [rec(1, 1010.5, 1.0), rec(3, 1013.0, 0.5)]
    merged = merge_record_streams([a, b], offsets=[0.0, 1000.0])
    # rebased completions: 11.0, 11.5, 13.5, 14.0
    assert [r.request_id for r in merged] == [0, 1, 3, 2]
    by_id = {r.request_id: r for r in merged}
    assert by_id[1].t_issue == pytest.approx(10.5)
    assert by_id[3].t_done == pytest.approx(13.5)
    # durations are skew-invariant
    for src in (*a, *b):
        m = by_id[src.request_id]
        assert m.t_done - m.t_issue == pytest.approx(src.t_done - src.t_issue)
        assert m.stage_s == src.stage_s
    # sources not mutated
    assert b[0].t_issue == pytest.approx(1010.5)
    with pytest.raises(ValueError, match="offsets length"):
        merge_record_streams([a], offsets=[0.0, 1.0])


def test_cluster_telemetry_matches_single_process_golden():
    """SLO percentiles over responses merged from multiple replicas must
    equal the golden single-list math — merging adds no distortion."""
    from repro.core.metrics import percentile, slo_summary
    from repro.serving.request import Response

    def rsp(rid, ttft, total, n_tok):
        return Response(request_id=rid, tokens=list(range(n_tok)),
                        ttft_s=ttft, total_s=total, stage_s={"queue": 0.01})

    per_replica = [
        [rsp(0, 0.10, 0.50, 4), rsp(2, 0.30, 0.90, 4)],
        [rsp(1, 0.20, 0.70, 4), rsp(3, 0.40, 1.10, 4)],
    ]
    merged = [r for stream in per_replica for r in stream]
    s = slo_summary(merged)
    ttfts = sorted(r.ttft_s for r in merged)
    assert s["ttft_s"]["p50"] == pytest.approx(percentile(ttfts, 0.50))
    assert s["ttft_s"]["p99"] == pytest.approx(percentile(ttfts, 0.99))
    assert s["e2e_s"]["mean"] == pytest.approx(
        float(np.mean([r.total_s for r in merged]))
    )
    golden_tpot = [(r.total_s - r.ttft_s) / 3 for r in merged]
    assert s["tpot_s"]["mean"] == pytest.approx(float(np.mean(golden_tpot)))
