"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.metrics import cov, percentile, summarize
from repro.core.transport import PAPER_A2, Transport
from repro.kernels import ops, ref

SET = dict(max_examples=25, deadline=None)


# --------------------------------------------------------------------------- #
# transport model invariants
# --------------------------------------------------------------------------- #
@given(nbytes=st.integers(1, 10**8))
@settings(**SET)
def test_transport_ordering(nbytes):
    """For any payload: local <= GDR-ish <= RDMA-wire <= TCP (paper's core
    ordering on the wire)."""
    p = PAPER_A2
    assert p.wire_time(Transport.LOCAL, nbytes) == 0.0
    assert p.wire_time(Transport.RDMA, nbytes) <= p.wire_time(Transport.TCP, nbytes)
    # RDMA pays copy engine on top; GDR end-to-end = wire only
    gdr_total = p.wire_time(Transport.GDR, nbytes)
    rdma_total = p.wire_time(Transport.RDMA, nbytes) + p.copy_time(nbytes)
    assert gdr_total < rdma_total


@given(a=st.integers(1, 10**7), b=st.integers(1, 10**7))
@settings(**SET)
def test_wire_time_monotone(a, b):
    p = PAPER_A2
    lo, hi = min(a, b), max(a, b)
    for t in (Transport.TCP, Transport.RDMA, Transport.GDR):
        assert p.wire_time(t, lo) <= p.wire_time(t, hi) + 1e-12


# --------------------------------------------------------------------------- #
# metrics
# --------------------------------------------------------------------------- #
@given(xs=st.lists(st.floats(0.1, 1e3), min_size=2, max_size=50))
@settings(**SET)
def test_percentile_bounds(xs):
    assert min(xs) - 1e-9 <= percentile(xs, 0.5) <= max(xs) + 1e-9
    s = summarize(xs)
    assert s["p50"] <= s["p99"] + 1e-9
    assert cov(xs) >= 0


@given(scale=st.floats(0.5, 10.0), xs=st.lists(st.floats(0.1, 100), min_size=3, max_size=20))
@settings(**SET)
def test_cov_scale_invariant(scale, xs):
    assert abs(cov(xs) - cov([x * scale for x in xs])) < 1e-9


# --------------------------------------------------------------------------- #
# kernel math properties
# --------------------------------------------------------------------------- #
@given(
    seed=st.integers(0, 2**16),
    sq=st.integers(4, 48),
    h=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
)
@settings(**SET)
def test_flash_attention_matches_ref(seed, sq, h, g):
    rng = np.random.default_rng(seed)
    hd = 16
    q = jnp.asarray(rng.normal(size=(1, sq, h * g, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, sq, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, sq, h, hd)), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=1e-3)


@given(seed=st.integers(0, 2**16), w=st.integers(4, 64))
@settings(**SET)
def test_decode_attention_prob_simplex(seed, w):
    """Attention output is a convex combination of cached values: componentwise
    within [min(v), max(v)]."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 1, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, w, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, w, 2, 8)), jnp.float32)
    lens = jnp.asarray([w], jnp.int32)
    out = np.asarray(ops.decode_attention(q, k, v, lens, block_k=16))
    vmin = np.asarray(v).min(axis=1, keepdims=True)
    vmax = np.asarray(v).max(axis=1, keepdims=True)
    assert (out >= vmin - 1e-4).all() and (out <= vmax + 1e-4).all()


@given(seed=st.integers(0, 2**16), alpha=st.floats(0.25, 4.0))
@settings(**SET)
def test_ssd_linear_in_x(seed, alpha):
    """SSD output is linear in x for fixed (dt, A, B, C)."""
    rng = np.random.default_rng(seed)
    b, S, nh, hd, ds = 1, 32, 2, 8, 4
    x = jnp.asarray(rng.normal(size=(b, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, S, nh))) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(rng.normal(size=(nh,))) + 0.1, jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, S, 1, ds)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, S, 1, ds)), jnp.float32)
    y1, _ = ops.ssd_scan(x, dt, A, B, C, chunk=16)
    y2, _ = ops.ssd_scan(alpha * x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(alpha * y1, y2, atol=1e-4, rtol=1e-3)


@given(seed=st.integers(0, 2**16), c1=st.sampled_from([8, 16]), c2=st.sampled_from([32, 64]))
@settings(**SET)
def test_ssd_chunk_invariance(seed, c1, c2):
    """The chunked SSD result must not depend on the chunk size."""
    rng = np.random.default_rng(seed)
    b, S, nh, hd, ds = 1, 64, 2, 8, 4
    x = jnp.asarray(rng.normal(size=(b, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, S, nh))) * 0.1 + 0.01, jnp.float32)
    A = -jnp.asarray(np.abs(rng.normal(size=(nh,))) + 0.1, jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, S, 1, ds)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, S, 1, ds)), jnp.float32)
    y1, s1 = ops.ssd_scan(x, dt, A, B, C, chunk=c1)
    y2, s2 = ops.ssd_scan(x, dt, A, B, C, chunk=c2)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-3)


@given(seed=st.integers(0, 2**16), scale=st.floats(0.5, 8.0))
@settings(**SET)
def test_rmsnorm_scale_invariance(seed, scale):
    """rmsnorm(a*x) == rmsnorm(x) for any positive scalar a."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(8, 64)) + 0.1, jnp.float32)
    w = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    y1 = ops.rmsnorm(x, w)
    y2 = ops.rmsnorm(scale * x, w)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=2e-3)


# --------------------------------------------------------------------------- #
# MoE dispatch invariants
# --------------------------------------------------------------------------- #
@given(seed=st.integers(0, 2**16), t=st.integers(4, 32))
@settings(**SET)
def test_moe_router_weights_normalized(seed, t):
    from repro.models.moe import router_topk

    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(t, 8)), jnp.float32)
    w, ids = router_topk(logits, 2)
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, atol=1e-5)
    assert (np.asarray(ids) < 8).all()
    # top-k ids are distinct per token
    ids = np.asarray(ids)
    assert all(len(set(row)) == len(row) for row in ids)


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_moe_nodrop_matches_dense_experts(seed):
    """With no-drop capacity, gather/scatter dispatch == dense per-token mix."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.moe import moe_apply, moe_schema, router_topk
    from repro.models.schema import init_params

    cfg = get_config("grok-1-314b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts) / cfg.moe.top_k)
    )
    p = init_params(jax.random.key(seed % 1000), moe_schema(cfg), jnp.float32)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, cfg.d_model)), jnp.float32)
    out, _ = moe_apply(p, cfg, x)

    # dense reference: every token through its top-k experts explicitly
    logits = x @ p["router"]
    w, ids = router_topk(logits, cfg.moe.top_k)
    want = np.zeros_like(np.asarray(x))
    for t in range(x.shape[0]):
        for j in range(cfg.moe.top_k):
            e = int(ids[t, j])
            h = jax.nn.silu(x[t] @ p["w_gate"][e]) * (x[t] @ p["w_up"][e])
            want[t] += float(w[t, j]) * np.asarray(h @ p["w_down"][e])
    if cfg.moe.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        want += np.asarray(hs @ sp["w_down"])
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-3, rtol=2e-3)
