"""Closed-loop client + Gateway drain coverage (previously the untested
serving modules), and the open-loop driver's no-busy-wait contract
against asynchronously-draining engines."""

import time

import pytest

from benchmarks.serving import micro_config


@pytest.fixture(scope="module")
def served():
    import jax

    from repro.models.model import Model
    from repro.serving.engine import ServingEngine

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    def make_engine():
        return ServingEngine(model, params, max_batch=3, max_seq=64)

    return cfg, make_engine


def test_closed_loop_client_completion_and_order(served):
    """Each client gets exactly requests_per_client completions, in its
    own submission order (closed loop: at most one in flight per
    client), and every response belongs to its client."""
    from repro.serving.client import ClosedLoopClient, run_closed_loop

    cfg, make_engine = served
    eng = make_engine()
    clients = [
        ClosedLoopClient(i, cfg.vocab_size, prompt_len=12,
                         max_new_tokens=3, seed=0)
        for i in range(3)
    ]
    run_closed_loop(eng, clients, requests_per_client=3)
    for c in clients:
        assert len(c.completed) == 3
        assert c.inflight is None
        assert all(len(r.tokens) == 3 for r in c.completed)
        ids = [r.request_id for r in c.completed]
        assert ids == sorted(ids)  # one in flight => completion order
    assert eng.idle


def test_closed_loop_pins_open_loop_tokens(served):
    """The closed-loop path and the open-loop path must produce the same
    tokens for the same prompts — the loop discipline changes timing and
    concurrency, never sampling (greedy decode is schedule-invariant)."""
    import numpy as np

    from repro.serving.client import ClosedLoopClient, run_closed_loop
    from repro.serving.loadgen import Arrival, run_open_loop
    from repro.serving.request import Request

    cfg, make_engine = served

    eng1 = make_engine()
    clients = [ClosedLoopClient(0, cfg.vocab_size, prompt_len=10,
                                max_new_tokens=4, seed=9)]
    run_closed_loop(eng1, clients, requests_per_client=3)
    closed_toks = [r.tokens for r in clients[0].completed]

    # same prompt stream, rebuilt from the client's seeded rng
    rng = np.random.default_rng(9)
    sched = [
        Arrival(0.001 * k, Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 10,
                                       dtype=np.int32),
            max_new_tokens=4,
        ))
        for k in range(3)
    ]
    eng2 = make_engine()
    out = run_open_loop(eng2, sched)
    open_toks = [r.tokens for r in sorted(out, key=lambda r: r.request_id)]
    assert closed_toks == open_toks


def test_gateway_drain_idle_and_records(served):
    """Gateway.run_until_drained drains the wrapped engine, Gateway.idle
    tracks it, and both request and response hops land on the stored
    record (TCP CPU charged on both directions)."""
    from benchmarks.serving import make_requests
    from repro.serving.gateway import Gateway

    cfg, make_engine = served
    gw = Gateway(make_engine())
    assert gw.idle and not gw.queue
    reqs = make_requests(cfg, [8, 16], 3, seed=2)
    for r in reqs:
        gw.submit(r, time.perf_counter())
    assert not gw.idle
    out = gw.run_until_drained()
    assert gw.idle
    assert sorted(r.request_id for r in out) == \
        sorted(r.request_id for r in reqs)
    for rsp in out:
        rec = gw._records[rsp.request_id]
        assert rec.stage_s["request"] > 0.0
        assert rec.stage_s["response"] > 0.0
        assert rec.cpu_s > 0.0  # TCP keeps the CPU on the data path
        # the Response carries the extra first-hop charge symmetrically
        assert rsp.stage_s["response"] >= rec.stage_s["response"] / 2
    assert len(gw.store.records) == len(reqs)
    gw.close()  # no-op over a plain engine


class _FakeAsyncEngine:
    """Async-draining stand-in: completes each request a fixed wall-clock
    delay after submit, counts how often the driver polls step()."""

    def __init__(self, delay_s: float):
        self.delay = delay_s
        self.async_draining = True
        self.pending = []  # (due, request)
        self.step_calls = 0
        self._records = {}

    def submit(self, req, now=None):
        self.pending.append((time.perf_counter() + self.delay, req))

    def step(self):
        from repro.serving.request import Response

        self.step_calls += 1
        now = time.perf_counter()
        done = [(t, r) for t, r in self.pending if t <= now]
        self.pending = [(t, r) for t, r in self.pending if t > now]
        return [
            Response(request_id=r.request_id, tokens=[0], ttft_s=self.delay,
                     total_s=self.delay, stage_s={})
            for _, r in done
        ]

    @property
    def idle(self):
        return not self.pending


def test_open_loop_sleeps_instead_of_spinning():
    """Against an async-draining engine the open-loop driver must sleep
    between polls: over a ~100ms service delay the step() count stays
    near delay/poll_s, nowhere near a busy-spin's tens of thousands."""
    import numpy as np

    from repro.serving.loadgen import Arrival, run_open_loop
    from repro.serving.request import Request

    delay = 0.1
    eng = _FakeAsyncEngine(delay)
    sched = [
        Arrival(0.0, Request(prompt_tokens=np.zeros(4, np.int32),
                             max_new_tokens=1))
        for _ in range(2)
    ]
    out = run_open_loop(eng, sched, poll_s=0.002)
    assert len(out) == 2
    # a spin loop on this hardware makes >100k calls in 100ms; sleeping
    # at poll_s bounds it near delay/poll_s (=50) — leave generous slack
    assert eng.step_calls < 1000, eng.step_calls
