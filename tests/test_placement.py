"""Per-pod compute placement + construction-time warmup.

The disaggregated tier must (1) commit each stage's params and compute to
its own pod slice — proven by the committed device sets of every stage's
jit outputs on a real 2-pod mesh (subprocess with 2 forced host devices;
jit placement follows committed arguments, so an output living on a slice
means the compute ran there) — while staying token-identical to the fused
engine, and (2) with ``warmup=True``, pre-trace the whole pow2 shape grid
at construction so ZERO XLA compiles happen inside the timed serving
window (asserted via ``jax.log_compiles`` capture with a positive
control)."""

import logging
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.transfer import TransferMode
from repro.serving import DisaggregatedEngine, PodPlacement, ServingEngine
from repro.serving.request import Request


def _requests(cfg, lens, max_new=4, seed=7):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


def _drain(eng, cfg, lens, max_new=4, seed=7):
    reqs = _requests(cfg, lens, max_new, seed)
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained()
    assert len(out) == len(reqs)
    return reqs, out


# --------------------------------------------------------------------- #
# PodPlacement API (degenerate 1-device mesh)
# --------------------------------------------------------------------- #
def test_pod_placement_from_mesh_degenerate():
    from repro.serving import make_pod_mesh

    mesh = make_pod_mesh()  # 1 pod on the single test device
    pl = PodPlacement.from_mesh(mesh)
    assert pl.prefill_pods == (0,)
    assert pl.decode_pods == (mesh.shape["pod"] - 1,)
    if mesh.shape["pod"] == 1:
        assert not pl.disjoint  # both stages collapse onto one device
        assert pl.prefill_devices() == pl.decode_devices()
    # slice shardings are replicated over the slice by default
    assert pl.prefill_sharding().is_fully_replicated
    assert pl.decode_sharding().is_fully_replicated


def test_pod_slice_mesh_validation():
    from repro.serving import make_pod_mesh
    from repro.sharding.partition import pod_slice_mesh

    mesh = make_pod_mesh()
    with pytest.raises(ValueError, match="empty"):
        pod_slice_mesh(mesh, ())
    with pytest.raises(ValueError, match="out of range"):
        pod_slice_mesh(mesh, (99,))
    with pytest.raises(ValueError, match="no 'nope' axis"):
        pod_slice_mesh(mesh, (0,), axis="nope")
    sub = pod_slice_mesh(mesh, (0,))
    assert sub.axis_names == mesh.axis_names
    assert sub.shape["pod"] == 1


def test_placement_mesh_mismatch_rejected(model_bank):
    from repro.serving import make_pod_mesh
    from repro.sharding.partition import pod_slice_mesh

    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    mesh = make_pod_mesh()
    other = pod_slice_mesh(mesh, (0,))  # equal only if mesh is 1-pod
    pl = PodPlacement.from_mesh(other)
    if other != mesh:  # only meaningful when the meshes differ
        with pytest.raises(ValueError, match="placement.mesh"):
            DisaggregatedEngine(model, params, mesh=mesh, placement=pl,
                                max_batch=1, max_seq=32)


def test_placement_default_on_tokens_identical(model_bank):
    """Default placement on the degenerate mesh: both stages committed to
    the same device, decode tokens identical to the fused engine, and the
    pool state reports the decode slice as its committed device set."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 9, 17, 26]
    kw = dict(max_batch=2, max_seq=64)
    base, _ = _drain(ServingEngine(model, params, **kw), cfg, lens)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM, **kw
    )
    assert eng.placement is not None  # on by default
    dis, _ = _drain(eng, cfg, lens)
    assert [r.generated for r in dis] == [r.generated for r in base]
    ddev = set(eng.placement.decode_devices())
    for leaf in jax.tree.leaves(eng.pool.caches):
        assert set(leaf.devices()) == ddev
    for leaf in jax.tree.leaves(eng.decode_params):
        assert set(leaf.devices()) == ddev
    # equal slices share ONE committed replica (no weight triplication on
    # the degenerate mesh)
    if not eng.placement.disjoint:
        assert eng.decode_params is eng.prefill_params
    # placement=False restores uncommitted params (pre-placement behavior)
    off = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM,
        placement=False, **kw
    )
    assert off.placement is None
    assert off.prefill_params is params and off.decode_params is params




# --------------------------------------------------------------------- #
# Real 2-pod placement: subprocess with 2 forced host devices
# --------------------------------------------------------------------- #
_TWO_POD_SCRIPT = r"""
import time
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import Model
from repro.serving import DisaggregatedEngine, ServingEngine
from repro.core.transfer import TransferMode
from repro.serving.request import Request

assert len(jax.devices()) == 2, jax.devices()
cfg = get_config("llama3-8b").reduced()
model = Model(cfg, dtype=jnp.float32)
params = model.init(jax.random.key(1))
LENS, MAX_NEW = (5, 9, 17), 3
KW = dict(max_batch=2, max_seq=32)

def drain(eng):
    rng = np.random.default_rng(7)
    rs = [Request(prompt_tokens=rng.integers(0, cfg.vocab_size, s,
                                             dtype=np.int32),
                  max_new_tokens=MAX_NEW) for s in LENS]
    for r in rs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained()
    assert len(out) == len(rs)
    return [r.generated for r in rs]

def devset(tree):
    return {d for leaf in jax.tree.leaves(tree) for d in leaf.devices()}

base = drain(ServingEngine(model, params, **KW))
for i, mode in enumerate((TransferMode.DIRECT_HBM, TransferMode.DIRECT_DMA)):
    eng = DisaggregatedEngine(model, params, transfer_mode=mode,
                              warmup=(i == 0), **KW)
    pl = eng.placement
    assert pl.disjoint, pl  # a genuine two-pool split
    pdev, ddev = set(pl.prefill_devices()), set(pl.decode_devices())
    assert pdev != ddev and len(pdev) == len(ddev) == 1
    # params committed per stage slice
    assert devset(eng.prefill_params) == pdev
    assert devset(eng.decode_params) == ddev
    # decode pool state committed to the decode slice
    assert devset(eng.pool.caches) == ddev
    warmed, nshapes = set(eng._xfer_warm), eng.prefill_compile_count
    toks = drain(eng)
    assert toks == base, (mode, "tokens diverged from fused engine")
    if i == 0:  # warmed engine: the serving path compiled nothing new
        assert eng._xfer_warm == warmed
        assert eng.prefill_compile_count == nshapes
    assert eng.handoffs > 0
    # step-jit outputs live on the decode slice => decode compute ran there
    assert set(eng.pool.tokens.devices()) == ddev
    assert set(eng.pool.lengths.devices()) == ddev
    assert devset(eng.pool.caches) == ddev
    # prefill-jit outputs live on the prefill slice => prefill ran there
    nt, c1, _ = eng._prefill_bucket_jit(
        eng.prefill_params,
        jnp.zeros((KW["max_batch"], 16), jnp.int32),
        jnp.ones((KW["max_batch"],), jnp.int32),
        eng.prefill_key,
    )
    assert set(nt.devices()) == pdev
    assert devset(c1) == pdev
    # and the traced step compute carries the decode slice's sharding
    seen = []
    jax.jit(lambda x: jax.debug.inspect_array_sharding(
        x, callback=seen.append) or x + 1)(eng.pool.lengths)
    assert seen and set(seen[0].device_set) == ddev, seen

# the placed tiling enumerates one device per pod slot: a mesh with a
# non-trivial second axis must be refused (pointer at placement=False),
# not crash at the first handoff
from jax.sharding import Mesh
multi = Mesh(np.asarray(jax.devices()).reshape(1, 2), ("pod", "model"))
try:
    DisaggregatedEngine(model, params, mesh=multi, **KW)
except ValueError as e:
    assert "placement=False" in str(e), e
else:
    raise AssertionError("multi-axis mesh accepted with placement on")
print("TWO_POD_PLACEMENT_OK")
"""


def test_two_pod_placement_committed_and_token_identical():
    """On 2 forced host pods, each stage's jitted compute is committed to
    its own pod slice (params, pool state, and every stage output report
    exactly that slice's device) and decode output stays token-identical
    to the fused engine under DIRECT_HBM and DIRECT_DMA — with the warmed
    engine compiling nothing inside the serving window."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _TWO_POD_SCRIPT],
        capture_output=True, text=True, env=env, timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "TWO_POD_PLACEMENT_OK" in proc.stdout


# --------------------------------------------------------------------- #
# Warmup: zero compiles inside the timed serving window
# --------------------------------------------------------------------- #
class _LogGrab(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.DEBUG)
        self.messages = []

    def emit(self, record):
        self.messages.append(record.getMessage())


def _compiles_during(fn):
    """Run ``fn`` under jax.log_compiles and return the XLA 'Compiling'
    log messages it emitted."""
    grab = _LogGrab()
    logger = logging.getLogger("jax")
    old_level = logger.level
    logger.addHandler(grab)
    logger.setLevel(logging.DEBUG)
    try:
        with jax.log_compiles():
            fn()
    finally:
        logger.removeHandler(grab)
        logger.setLevel(old_level)
    return [m for m in grab.messages if m.startswith("Compiling ")]


def test_warmup_zero_compiles_in_timed_window(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    kw = dict(max_batch=2, max_seq=64)
    lens = [5, 9, 17, 26]

    # positive control: a COLD engine's drain must compile (same capture
    # machinery, fresh jit wrappers) — otherwise the zero assertion below
    # would be vacuous
    cold = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM, **kw
    )
    assert _compiles_during(lambda: _drain(cold, cfg, lens)), \
        "log capture saw no compiles from a cold engine"

    warm = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM, warmup=True,
        **kw
    )
    assert warm.warm_s > 0  # construction paid the grid, outside any stage
    grid = dict.fromkeys(warm.handoff_extent_grid())
    assert {(m, r, p) for (m, r, p) in warm._xfer_warm} == {
        (warm.transfer_mode, r, p) for (r, p) in grid
    }
    warmed, nshapes = set(warm._xfer_warm), warm.prefill_compile_count
    compiles = _compiles_during(lambda: _drain(warm, cfg, lens))
    assert compiles == [], f"compiled inside timed window: {compiles}"
    assert warm._xfer_warm == warmed  # no new handoff extent
    assert warm.prefill_compile_count == nshapes  # no new prefill bucket


def test_warmup_fused_engine_and_bucket_grid(model_bank):
    """ServingEngine(warmup=True): the pow2 bucket grid is pre-traced at
    construction and a drain adds no prefill shapes; bucket_grid covers
    min_bucket..max_seq."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    eng = ServingEngine(model, params, max_batch=2, max_seq=64, warmup=True)
    assert eng.bucket_grid() == [16, 32, 64]
    assert eng.prefill_compile_count == 3
    base, _ = _drain(ServingEngine(model, params, max_batch=2, max_seq=64),
                     cfg, [5, 40])
    out, _ = _drain(eng, cfg, [5, 40])
    assert [r.generated for r in out] == [r.generated for r in base]
    assert eng.prefill_compile_count == 3  # drain compiled nothing new


def test_warmup_noop_on_legacy(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    eng = ServingEngine(model, params, max_batch=2, max_seq=32, legacy=True,
                        warmup=True)
    assert eng.warm_s == 0.0
    assert eng.prefill_compile_count == 0


def test_pool_reset_state_guard(model_bank):
    """reset_state refuses to wipe an occupied pool (it exists for the
    post-warmup re-zero only)."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    eng = ServingEngine(model, params, max_batch=1, max_seq=32)
    req = _requests(cfg, [4], max_new=8)[0]
    eng.submit(req, time.perf_counter())
    eng.step()  # admits -> slot occupied
    with pytest.raises(RuntimeError, match="occupied"):
        eng.pool.reset_state()
