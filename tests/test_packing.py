"""Token-packed + chunked prefill: the segment-masking equivalence suite.

Covers, bottom-up:

* the segment-masking law at the attention level — a packed sequence's
  per-segment rows are BITWISE the rows of each segment prefilled alone
  (NEG_INF masking contributes exact 0.0 terms to the softmax), as a
  hypothesis property (seeded-sweep fallback) plus a poison-token canary;
* the Pallas flash kernel's segment-id masking vs per-segment reference;
* engine-level token identity: packed vs bucketed, chunked vs unchunked,
  packed+paged, packed through the disaggregated handoff — and the
  cross-architecture matrix (attention-only archs identical; SSM/hybrid
  archs ASSERTED to auto-route to the exact prefill path);
* the prefill-FLOPs proxy win (``prefill_padded_tokens``) on a ragged
  admission, and chunked prefill's decode interleaving;
* warmup: packed/chunk jits pre-trace at construction (zero serve-time
  compiles), and the knob-validation errors.
"""

import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import nodrop

from repro.configs import ARCHITECTURES, get_config
from repro.kernels import ops
from repro.models.attention import chunked_attention
from repro.serving import ServingEngine
from repro.serving.request import Request


# --------------------------------------------------------------------------- #
# helpers
# --------------------------------------------------------------------------- #
def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


def _drain(eng, reqs):
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained(max_steps=100_000)
    assert len(out) == len(reqs), (len(out), len(reqs))
    return [tuple(r.generated) for r in reqs]


def _pack(parts, pad_to=None):
    """Concatenate per-segment [1, s, ...] arrays along seq; return the
    packed array, its [1, T] segment ids (-1 on pad), and seg offsets."""
    T = sum(p.shape[1] for p in parts)
    Tp = max(pad_to or T, T)
    packed = np.zeros((1, Tp) + parts[0].shape[2:], parts[0].dtype)
    seg = np.full((1, Tp), -1, np.int32)
    starts, off = [], 0
    for j, p in enumerate(parts):
        s = p.shape[1]
        packed[0, off:off + s] = p[0]
        seg[0, off:off + s] = j
        starts.append(off)
        off += s
    return jnp.asarray(packed), jnp.asarray(seg), starts


# --------------------------------------------------------------------------- #
# Attention-level law: packed rows == lone-segment rows, bitwise
# --------------------------------------------------------------------------- #
def _check_packed_attention_law(seed, seg_lens, window=0):
    """Two faces of the segment-masking law, per segment j:

    * BITWISE isolation: replacing every OTHER segment's tokens with pads
      (id -1, zero qkv) moves not one bit of j's packed rows — masked
      scores hit -1e30, exp underflows to exact 0.0, and zero terms
      change no fp32 sum, so j's rows are a pure function of j's tokens.
      (Comparing at the SAME packed width pins XLA's reduction tree;
      comparing against the lone [1, s_j] run instead would measure
      shape-dependent fp summation order, not masking.)
    * reduction to the lone run: j's packed rows match segment j
      prefilled alone to fp32 accumulation-order tolerance.
    """
    H, hd = 2, 8
    rng = np.random.default_rng(seed)
    parts = [
        (rng.standard_normal((1, s, H, hd)).astype(np.float32),
         rng.standard_normal((1, s, H, hd)).astype(np.float32),
         rng.standard_normal((1, s, H, hd)).astype(np.float32))
        for s in seg_lens
    ]
    qp, seg, starts = _pack([p[0] for p in parts])
    kp, _, _ = _pack([p[1] for p in parts])
    vp, _, _ = _pack([p[2] for p in parts])
    packed = chunked_attention(qp, kp, vp, causal=True, window=window,
                               segment_ids=seg)
    T = qp.shape[1]
    for j, (q, k, v) in enumerate(parts):
        s = q.shape[1]
        got = np.asarray(packed[:, starts[j]:starts[j] + s])

        # bitwise: segment j alone IN PLACE (same width, same offset)
        def isolate(x):
            iso = np.zeros((1, T) + x.shape[2:], np.float32)
            iso[0, starts[j]:starts[j] + s] = x[0]
            return jnp.asarray(iso)

        seg_iso = np.full((1, T), -1, np.int32)
        seg_iso[0, starts[j]:starts[j] + s] = j
        alone_in_place = chunked_attention(
            isolate(q), isolate(k), isolate(v), causal=True, window=window,
            segment_ids=jnp.asarray(seg_iso),
        )
        np.testing.assert_array_equal(
            got, np.asarray(alone_in_place[:, starts[j]:starts[j] + s])
        )

        # reduction: the true lone run (different kv width reassociates
        # the fp32 softmax/output sums; masking itself is exact)
        alone = chunked_attention(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v), causal=True, window=window)
        np.testing.assert_allclose(got, np.asarray(alone), atol=3e-6, rtol=0)


try:
    from hypothesis import given, settings, strategies as st

    @given(
        seg_lens=st.lists(st.integers(1, 9), min_size=1, max_size=4),
        seed=st.integers(0, 2**31 - 1),
        window=st.sampled_from([0, 4]),
    )
    @settings(max_examples=50, deadline=None)
    def test_packed_attention_bitwise_law(seg_lens, seed, window):
        _check_packed_attention_law(seed, seg_lens, window=window)

except ImportError:

    @pytest.mark.parametrize("seed", range(50))
    def test_packed_attention_bitwise_law(seed):
        rng = np.random.default_rng(seed)
        seg_lens = [int(s) for s in rng.integers(1, 10, rng.integers(1, 5))]
        _check_packed_attention_law(seed, seg_lens,
                                    window=int(rng.choice([0, 4])))


def test_packed_poison_canary():
    """Corrupting every value of segment A must not move ONE BIT of
    segment B's packed output — the direct no-cross-attention witness."""
    rng = np.random.default_rng(7)
    H, hd, sa, sb = 2, 8, 6, 5
    mk = lambda s: rng.standard_normal((1, s, H, hd)).astype(np.float32)
    a = (mk(sa), mk(sa), mk(sa))
    b = (mk(sb), mk(sb), mk(sb))
    poison = tuple(np.full_like(x, 1e4) for x in a)  # not NaN: NaN*0 = NaN

    def run(a_parts):
        qp, seg, starts = _pack([a_parts[0], b[0]])
        kp, _, _ = _pack([a_parts[1], b[1]])
        vp, _, _ = _pack([a_parts[2], b[2]])
        out = chunked_attention(qp, kp, vp, causal=True, segment_ids=seg)
        return np.asarray(out[:, starts[1]:starts[1] + sb])

    np.testing.assert_array_equal(run(a), run(poison))


def test_segment_ids_exclusive_with_prior():
    q = jnp.zeros((1, 4, 2, 8), jnp.float32)
    seg = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="prior"):
        chunked_attention(q, q, q, segment_ids=seg, prior_k=q, prior_v=q,
                          prior_valid=jnp.ones((1,), jnp.int32))
    with pytest.raises(ValueError, match="segment_ids"):
        chunked_attention(q, q, q, segment_ids=jnp.zeros((1, 3), jnp.int32))


def test_flash_kernel_segment_mask_matches_per_segment():
    """The Pallas kernel's segment-id refs mask exactly like running each
    segment through the kernel alone (interpret mode on CPU)."""
    rng = np.random.default_rng(11)
    H, hd = 2, 16
    seg_lens = [7, 12, 5]
    parts = [
        tuple(rng.standard_normal((1, s, H, hd)).astype(np.float32)
              for _ in range(3))
        for s in seg_lens
    ]
    qp, seg, starts = _pack([p[0] for p in parts])
    kp, _, _ = _pack([p[1] for p in parts])
    vp, _, _ = _pack([p[2] for p in parts])
    packed = ops.flash_attention(qp, kp, vp, causal=True, block_q=8,
                                 block_k=8, segment_ids=seg)
    for j, (q, k, v) in enumerate(parts):
        alone = ops.flash_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True,
            block_q=8, block_k=8,
        )
        s = q.shape[1]
        got = np.asarray(packed[:, starts[j]:starts[j] + s])
        np.testing.assert_allclose(got, np.asarray(alone), atol=1e-6, rtol=0)


def test_flash_kernel_segment_ids_both_or_neither():
    from repro.kernels.flash_attention import flash_attention_bhsd

    x = jnp.zeros((1, 2, 16, 16), jnp.float32)
    with pytest.raises(ValueError, match="both or neither"):
        flash_attention_bhsd(x, x, x, q_segment_ids=jnp.zeros((1, 16),
                                                              jnp.int32))


# --------------------------------------------------------------------------- #
# Engine: packed vs bucketed token identity + the FLOPs-proxy win
# --------------------------------------------------------------------------- #
_RAGGED = [5, 17, 33, 50]


def test_packed_vs_bucketed_token_identity(engine_bank):
    cfg = get_config("llama3-8b").reduced()
    kw = dict(max_batch=4, max_seq=128, temperature=0.0)
    base = _drain(engine_bank(cfg, **kw), _requests(cfg, _RAGGED))
    eng = engine_bank(cfg, packed=True, **kw)
    assert eng.packed
    assert _drain(eng, _requests(cfg, _RAGGED)) == base


def test_packed_padded_token_win(engine_bank):
    """On a ragged admission the packed path dispatches strictly fewer
    padded token-rows (the deterministic prefill-FLOPs proxy) than the
    bucketed path — while the true-token counters agree exactly."""
    cfg = get_config("llama3-8b").reduced()
    kw = dict(max_batch=4, max_seq=128, temperature=0.0)
    bucketed = engine_bank(cfg, **kw)
    packed = engine_bank(cfg, packed=True, **kw)
    _drain(bucketed, _requests(cfg, _RAGGED))
    _drain(packed, _requests(cfg, _RAGGED))
    assert bucketed.prefill_tokens_total == packed.prefill_tokens_total
    assert packed.prefill_padded_tokens < bucketed.prefill_padded_tokens, (
        packed.prefill_padded_tokens, bucketed.prefill_padded_tokens,
    )
    # the packed width is the pow2 roof of the admission's TRUE tokens
    assert packed.prefill_padded_tokens >= packed.prefill_tokens_total


def test_chunked_vs_bucketed_token_identity(engine_bank):
    cfg = get_config("llama3-8b").reduced()
    kw = dict(max_batch=4, max_seq=128, temperature=0.0)
    base = _drain(engine_bank(cfg, **kw), _requests(cfg, _RAGGED))
    eng = engine_bank(cfg, prefill_chunk=16, **kw)
    assert eng._chunk_enabled
    assert _drain(eng, _requests(cfg, _RAGGED)) == base
    # every chunk dispatches exactly chunk-width token rows
    assert eng.prefill_padded_tokens % 16 == 0
    # packed + chunked compose: short prompts pack, long prompts chunk
    both = engine_bank(cfg, packed=True, prefill_chunk=16, **kw)
    assert _drain(both, _requests(cfg, _RAGGED)) == base


def test_chunked_interleaves_decode(engine_bank):
    """While a long admission is mid-chunk, an already-running request
    keeps producing tokens — the structural head-of-line property (the
    TPOT bound itself is asserted in benchmarks/serving.py --quick)."""
    cfg = get_config("llama3-8b").reduced()
    eng = engine_bank(cfg, max_batch=2, max_seq=128, temperature=0.0,
                      prefill_chunk=16)
    victim = _requests(cfg, [8], max_new=48, seed=1)[0]
    eng.submit(victim, time.perf_counter())
    while len(victim.generated) < 4:  # victim decoding before the burst
        eng.step()
    big = _requests(cfg, [100], max_new=4, seed=2)[0]
    eng.submit(big, time.perf_counter())
    progressed = []
    while eng._chunk_jobs or not big.generated:
        mid_chunk = bool(eng._chunk_jobs)
        before = len(victim.generated)
        eng.step()
        if mid_chunk:
            progressed.append(len(victim.generated) > before)
        assert len(progressed) < 10_000
    assert any(progressed), "no decode progress during the chunked admission"
    eng.run_until_drained(max_steps=100_000)
    assert len(victim.generated) == 48


def test_chunk_knob_validation(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    with pytest.raises(ValueError, match="ring"):
        ServingEngine(model, params, max_batch=2, max_seq=64, paged=True,
                      prefill_chunk=16)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        ServingEngine(model, params, max_batch=2, max_seq=64,
                      prefill_chunk=128)
    with pytest.raises(ValueError, match=">= 0"):
        ServingEngine(model, params, max_batch=2, max_seq=64,
                      prefill_chunk=-1)


def test_packed_paged_token_identity(engine_bank):
    """Packing rides the paged pool too (prefix reuse auto-off: packed
    pages interleave segments, so they never align with the index)."""
    cfg = get_config("llama3-8b").reduced()
    kw = dict(max_batch=4, max_seq=128, temperature=0.0)
    base = _drain(engine_bank(cfg, **kw), _requests(cfg, _RAGGED))
    eng = engine_bank(cfg, paged=True, packed=True, **kw)
    assert eng.packed and eng.paged and not eng.prefix_reuse
    assert _drain(eng, _requests(cfg, _RAGGED)) == base


@pytest.mark.slow
def test_packed_disagg_token_identity(model_bank):
    from repro.serving.disagg import DisaggregatedEngine, TransferMode

    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    kw = dict(max_batch=4, max_seq=128, temperature=0.0)
    base = _drain(ServingEngine(model, params, **kw),
                  _requests(cfg, _RAGGED))
    for dkw in (dict(packed=True), dict(prefill_chunk=16)):
        eng = DisaggregatedEngine(
            model, params, transfer_mode=TransferMode.DIRECT_HBM, **kw,
            **dkw,
        )
        assert _drain(eng, _requests(cfg, _RAGGED)) == base, dkw


# --------------------------------------------------------------------------- #
# Cross-architecture matrix: identity on attention-only, auto-route on SSM
# --------------------------------------------------------------------------- #
_PACKABLE_ARCHS = [
    "llama3-8b",
    "starcoder2-3b",
    pytest.param("qwen3-32b", marks=pytest.mark.slow),
]
_UNPACKABLE_ARCHS = ["mamba2-130m", "jamba-v0.1-52b"]


@pytest.mark.parametrize("name", _PACKABLE_ARCHS)
def test_cross_arch_packed_chunked_identity(name, engine_bank):
    cfg = nodrop(ARCHITECTURES[name].reduced())
    kw = dict(max_batch=2, max_seq=128, temperature=0.0)
    lens = [9, 40]
    base = _drain(engine_bank(cfg, **kw), _requests(cfg, lens))
    eng = engine_bank(cfg, packed=True, prefill_chunk=32, **kw)
    assert eng.packed and eng._chunk_enabled
    assert _drain(eng, _requests(cfg, lens)) == base


@pytest.mark.parametrize("name", _UNPACKABLE_ARCHS)
def test_cross_arch_unpackable_auto_routes_exact(name, engine_bank):
    """SSM/hybrid recurrences integrate pad AND neighbor tokens into
    state, so packing is unsound there — the knobs must auto-downgrade
    to the exact prefill path (same silent gate as bucketed_prefill),
    and tokens must match the default engine exactly."""
    cfg = nodrop(ARCHITECTURES[name].reduced())
    kw = dict(max_batch=2, max_seq=128, temperature=0.0)
    lens = [9, 40]
    base = _drain(engine_bank(cfg, **kw), _requests(cfg, lens))
    eng = engine_bank(cfg, packed=True, prefill_chunk=32, **kw)
    assert not eng.bucketed_prefill  # the shared soundness gate
    assert not eng.packed and not eng._chunk_enabled
    assert _drain(eng, _requests(cfg, lens)) == base


def test_mla_auto_downgrades(model_bank):
    """MLA stacks bucket fine but can't pack (latent cache; segment
    masking rides plain attention) — packed/chunk silently downgrade."""
    cfg = nodrop(ARCHITECTURES["deepseek-v2-236b"].reduced())
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        packed=True, prefill_chunk=32)
    assert eng.bucketed_prefill
    assert not eng.packed and not eng._chunk_enabled


# --------------------------------------------------------------------------- #
# Warmup: packed/chunk grids pre-trace; zero compiles while serving
# --------------------------------------------------------------------------- #
class _LogGrab(logging.Handler):
    def __init__(self):
        super().__init__()
        self.messages = []

    def emit(self, record):
        self.messages.append(record.getMessage())


def _compiles_during(fn):
    grab = _LogGrab()
    logger = logging.getLogger("jax")
    old_level = logger.level
    logger.addHandler(grab)
    logger.setLevel(logging.DEBUG)
    try:
        with jax.log_compiles():
            fn()
    finally:
        logger.removeHandler(grab)
        logger.setLevel(old_level)
    return [m for m in grab.messages if m.startswith("Compiling ")]


def test_warmup_packed_chunk_zero_compiles(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    kw = dict(max_batch=2, max_seq=64, prefill_chunk=16, packed=True)

    # positive control: the cold engine must visibly compile
    cold = ServingEngine(model, params, **kw)
    assert _compiles_during(
        lambda: _drain(cold, _requests(cfg, [5, 40]))
    ), "log capture saw no compiles from a cold engine"

    warm = ServingEngine(model, params, warmup=True, **kw)
    assert warm.warm_s > 0
    # packed grid covers min_bucket .. pow2(max_batch * max_seq)
    assert warm.packed_grid() == [16, 32, 64, 128]
    shapes = warm.prefill_compile_count
    compiles = _compiles_during(
        lambda: _drain(warm, _requests(cfg, [5, 40]))
    )
    assert compiles == [], f"compiled inside the serving window: {compiles}"
    assert warm.prefill_compile_count == shapes
