"""Cluster-tier invariants: router policies, SLO telemetry math, queue
stage accounting, adaptive in-flight window, device-side sampling,
loadgen determinism, and 2-replica token identity vs independent
engines."""

import json
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.metrics import jain_index, percentile, slo_summary
from repro.serving import (
    Gateway,
    Router,
    ServingCluster,
    ServingEngine,
    load_trace,
    poisson_schedule,
    run_open_loop,
    save_trace,
    trace_schedule,
)
from repro.serving.cluster import replica_pod_slices
from repro.serving.request import Request, Response


def _cfg():
    return get_config("llama3-8b").reduced()


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


def _drain(engine, reqs, max_steps=50_000):
    for r in reqs:
        engine.submit(r, time.perf_counter())
    out = engine.run_until_drained(max_steps=max_steps)
    assert len(out) == len(reqs)
    return out


# --------------------------------------------------------------------------- #
# Telemetry math: golden percentiles, Jain index, warmup-aware SLO summary.
# --------------------------------------------------------------------------- #
def test_percentile_golden():
    xs = list(range(1, 101))  # 1..100
    assert percentile(xs, 0.50) == pytest.approx(50.5)
    assert percentile(xs, 0.95) == pytest.approx(95.05)
    assert percentile(xs, 0.99) == pytest.approx(99.01)
    assert percentile([7.0], 0.99) == 7.0
    assert percentile([], 0.5) == 0.0


def test_jain_index_golden():
    assert jain_index([5, 5, 5, 5]) == pytest.approx(1.0)
    assert jain_index([12, 0, 0, 0]) == pytest.approx(0.25)  # one-hot: 1/n
    assert jain_index([1, 3]) == pytest.approx(16 / 20)
    assert jain_index([]) == 1.0
    assert jain_index([0, 0]) == 1.0  # nothing routed: vacuous balance


def test_slo_summary_warmup_and_tpot():
    def rsp(ttft, total, n_tokens, queue=0.0):
        return Response(request_id=0, tokens=list(range(n_tokens)),
                        ttft_s=ttft, total_s=total,
                        stage_s={"queue": queue})

    # one cold outlier + four steady completions
    rs = [rsp(10.0, 20.0, 2, queue=9.0)] + [
        rsp(0.1 * i, 0.1 * i + 0.9, 10, queue=0.01 * i) for i in (1, 2, 3, 4)
    ]
    warm = slo_summary(rs, warmup=1)
    assert warm["n"] == 4 and warm["warmup_dropped"] == 1
    assert warm["ttft_s"]["p50"] == pytest.approx(0.25)
    # tpot = (total - ttft) / (tokens - 1) = 0.9 / 9 for every warm response
    assert warm["tpot_s"]["p99"] == pytest.approx(0.1)
    assert warm["queue_s"]["p50"] == pytest.approx(0.025)
    # without warmup the outlier dominates the tail
    cold = slo_summary(rs, warmup=0)
    assert cold["ttft_s"]["p99"] > 5.0
    with pytest.raises(ValueError):
        slo_summary(rs, warmup=-1)


# --------------------------------------------------------------------------- #
# Queue stage: the submit -> admission gap is charged on every path.
# --------------------------------------------------------------------------- #
def test_queue_stage_charged_single_engine(model_bank):
    cfg = _cfg()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=1, max_seq=64)
    out = _drain(eng, _requests(cfg, [8, 9, 10], max_new=3))
    recs = {r.request_id: r for r in eng.store.records}
    for rsp in out:
        rec = recs[rsp.request_id]
        assert rec.stage_s["queue"] >= 0.0
        # the stage reaches the response breakdown and stays inside total
        assert rsp.stage_s["queue"] == rec.stage_s["queue"]
        assert rsp.total_s + 1e-9 >= sum(rsp.stage_s.values())
    # max_batch=1: later admissions waited on earlier requests' service,
    # so their queue charge dominates the first request's
    by_arrival = sorted(recs.values(), key=lambda r: r.t_issue)
    assert by_arrival[-1].stage_s["queue"] > by_arrival[0].stage_s["queue"]


def test_queue_stage_charged_legacy_loop(model_bank):
    cfg = _cfg()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=1, max_seq=64, legacy=True)
    _drain(eng, _requests(cfg, [8, 9], max_new=2))
    assert all("queue" in r.stage_s for r in eng.store.records)


# --------------------------------------------------------------------------- #
# Adaptive in-flight window: no overshoot past the live token budget.
# --------------------------------------------------------------------------- #
def test_adaptive_window_saves_dispatches(model_bank):
    cfg = _cfg()
    model, params = model_bank(cfg)

    def run(adaptive):
        eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                            inflight=4, adaptive_window=adaptive)
        reqs = _requests(cfg, [5, 8, 13, 21], max_new=3, seed=7)
        _drain(eng, reqs)
        return [tuple(r.generated) for r in reqs], eng

    toks_a, eng_a = run(True)
    toks_f, eng_f = run(False)
    assert toks_a == toks_f  # the cap only removes provably-dead steps
    assert eng_a.useful_steps == eng_f.useful_steps
    # fixed window: up to inflight-1 overshoot per finishing request;
    # adaptive: the window never exceeds the live outstanding budget
    assert eng_a.decode_steps < eng_f.decode_steps
    assert eng_a.decode_steps - eng_a.useful_steps < \
        eng_f.decode_steps - eng_f.useful_steps


# --------------------------------------------------------------------------- #
# Device-side sampling: greedy default, top_k=1 degeneracy, seeded streams.
# --------------------------------------------------------------------------- #
def test_sampling_top_k_one_is_greedy(model_bank):
    cfg = _cfg()
    model, params = model_bank(cfg)

    def run(**kw):
        eng = ServingEngine(model, params, max_batch=2, max_seq=64, **kw)
        reqs = _requests(cfg, [5, 9, 14], max_new=5, seed=2)
        _drain(eng, reqs)
        return [tuple(r.generated) for r in reqs]

    greedy = run()
    assert run(temperature=3.0, top_k=1, sample_seed=11) == greedy


def test_sampling_seeded_and_distinct(model_bank):
    cfg = _cfg()
    model, params = model_bank(cfg)

    def run(**kw):
        eng = ServingEngine(model, params, max_batch=2, max_seq=64, **kw)
        reqs = _requests(cfg, [5, 9, 14, 20], max_new=6, seed=2)
        _drain(eng, reqs)
        return [tuple(r.generated) for r in reqs]

    greedy = run()
    s3a = run(temperature=5.0, sample_seed=3)
    s3b = run(temperature=5.0, sample_seed=3)
    s4 = run(temperature=5.0, sample_seed=4)
    assert s3a == s3b  # the threaded PRNG key is the only entropy source
    assert s3a != greedy
    assert s3a != s4


def test_sampling_rejects_legacy_and_bad_args(model_bank):
    cfg = _cfg()
    model, params = model_bank(cfg)
    with pytest.raises(ValueError, match="legacy"):
        ServingEngine(model, params, max_batch=1, max_seq=64, legacy=True,
                      temperature=1.0)
    with pytest.raises(ValueError, match="temperature"):
        ServingEngine(model, params, max_batch=1, max_seq=64,
                      temperature=-1.0)


# --------------------------------------------------------------------------- #
# Router policies.
# --------------------------------------------------------------------------- #
def test_router_validates_policy():
    with pytest.raises(ValueError, match="unknown policy"):
        Router("random")


def test_replica_pod_slices():
    # enough pods: disjoint slices
    assert replica_pod_slices(4, 2, 2) == [(0, 1), (2, 3)]
    assert replica_pod_slices(2, 2, 1) == [(0,), (1,)]
    # degenerate single-device backend: slices overlap modulo the axis
    assert replica_pod_slices(1, 2, 2) == [(0,), (0,)]


def test_least_loaded_beats_round_robin_on_skewed_trace(model_bank):
    """Deterministic skew: one long-budget request, then a burst of
    1-token requests. Round-robin blindly parks half the lights behind
    the heavy decode (head-of-line blocking: each waits a full heavy
    service in 'queue'); least_loaded reads outstanding token budgets and
    routes every light around the busy replica, so the tail queue wait
    collapses from ~one heavy service to a few light services.

    Replicas are warmed so the queue waits measure steady-state service,
    not first-touch compiles (which would drown the policy effect). Note
    the shape of the skew: on a time-shared test CPU, balanced replicas
    run each other's steps slower (service stretch cancels backlog
    splitting), so head-of-line avoidance — not heavy-splitting — is the
    effect a single host can honestly measure in wall clock."""
    cfg = _cfg()
    model, params = model_bank(cfg)
    heavy, light = 24, 1
    n_light = 6

    def run(policy):
        cl = ServingCluster.build(model, params, n_replicas=2,
                                  engine="fused", policy=policy,
                                  max_batch=1, max_seq=32, warmup=True)
        reqs = _requests(cfg, [8] * (1 + n_light), max_new=light, seed=5)
        reqs[0].max_new_tokens = heavy
        for r in reqs:
            cl.submit(r)
        cl.run_until_drained(max_steps=100_000)
        routed = [rep.routed for rep in cl.replicas]
        slo = cl.telemetry()["slo"]
        return routed, slo

    rr_routed, rr_slo = run("round_robin")
    ll_routed, ll_slo = run("least_loaded")
    # deterministic routing: RR alternates blindly (3 lights land behind
    # the heavy on replica 0); least_loaded sends every light around it
    assert rr_routed == [4, 3]
    assert ll_routed == [1, n_light]
    # the latency claim: tail queue wait (and with it tail TTFT) drops by
    # ~one heavy service time, and the queue stage IS the difference —
    # prefill/decode costs are policy-independent
    assert ll_slo["queue_s"]["p99"] < rr_slo["queue_s"]["p99"]
    assert ll_slo["ttft_s"]["p99"] < rr_slo["ttft_s"]["p99"]
    ttft_gain = rr_slo["ttft_s"]["p99"] - ll_slo["ttft_s"]["p99"]
    queue_gain = rr_slo["queue_s"]["p99"] - ll_slo["queue_s"]["p99"]
    assert queue_gain == pytest.approx(ttft_gain, rel=0.35)


def test_jsq_beats_round_robin_on_skewed_trace(model_bank):
    """JSQ reads queue feedback (counts, not budgets), so it needs
    temporal spacing to act: with one long decode holding replica 0's
    slot and lights arriving slowly enough for replica 1 to drain, jsq
    routes every light around the busy replica while round-robin blindly
    parks half of them behind it. The arrival gap is calibrated to the
    measured light service time, so the load ratios (heavy spans many
    gaps; the light stream stays far below one replica's capacity) hold
    on any machine speed."""
    cfg = _cfg()
    model, params = model_bank(cfg)
    # calibrate: lights are prefill-only (max_new=1) on a warmed engine
    eng = ServingEngine(model, params, max_batch=1, max_seq=128,
                        warmup=True)
    t0 = time.perf_counter()
    _drain(eng, _requests(cfg, [8] * 4, max_new=1, seed=11))
    light_s = (time.perf_counter() - t0) / 4
    gap = max(0.02, 6.0 * light_s)
    entries = [{"t": 0.0, "prompt_len": 8, "max_new": 96}] + [
        {"t": round(i * gap, 6), "prompt_len": 8, "max_new": 1}
        for i in range(1, 9)
    ]

    def run(policy):
        cl = ServingCluster.build(model, params, n_replicas=2,
                                  engine="fused", policy=policy,
                                  max_batch=1, max_seq=128, warmup=True)
        sched = trace_schedule(entries, vocab=cfg.vocab_size, seed=13)
        assert len(run_open_loop(cl, sched)) == len(entries)
        return cl.telemetry()["slo"]

    rr, jq = run("round_robin"), run("jsq")
    assert jq["ttft_s"]["p99"] < rr["ttft_s"]["p99"]
    # the win is pre-admission queueing, nothing else
    assert jq["queue_s"]["p99"] < rr["queue_s"]["p99"]


def test_jsq_spreads_a_queue_buildup(model_bank):
    """With replica 0 pre-loaded, jsq must send new work to the empty
    replica while round-robin would alternate blindly."""
    cfg = _cfg()
    model, params = model_bank(cfg)
    cl = ServingCluster.build(model, params, n_replicas=2, engine="fused",
                              policy="jsq", max_batch=1, max_seq=64)
    pre = _requests(cfg, [8, 8, 8], max_new=4, seed=1)
    for r in pre:  # jsq walks the backlog: 0, 1, 0 (ties -> lowest index)
        cl.submit(r)
    assert [r.routed for r in cl.replicas] == [2, 1]
    late = _requests(cfg, [8], max_new=4, seed=2)[0]
    assert cl.submit(late) == 1  # shorter queue wins
    cl.run_until_drained(max_steps=100_000)


def test_affinity_reduces_prefill_compiles(model_bank):
    """Bucket-sticky routing: each replica compiles only its buckets,
    round-robin scatters every bucket onto every replica."""
    cfg = _cfg()
    model, params = model_bank(cfg)
    # 4 distinct pow2 buckets (16/32/64/128 for min_bucket=16), adjacent
    # same-bucket pairs so round-robin's parity splits every pair
    lens = [10, 12, 20, 24, 40, 48, 80, 96]

    def compiles(policy):
        cl = ServingCluster.build(model, params, n_replicas=2,
                                  engine="fused", policy=policy,
                                  max_batch=2, max_seq=128)
        _drain(cl, _requests(cfg, lens, max_new=2, seed=3),
               max_steps=100_000)
        return sum(r.engine.prefill_compile_count for r in cl.replicas)

    assert compiles("affinity") == 4  # each bucket compiled exactly once
    assert compiles("round_robin") == 8  # every bucket on both replicas


# --------------------------------------------------------------------------- #
# Token identity: a 2-replica cluster is numerically invisible.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("mode_name", ["direct_hbm", "direct_dma"])
def test_cluster_tokens_match_independent_engines(mode_name, model_bank):
    from repro.core.transfer import TransferMode
    from repro.serving import DisaggregatedEngine

    cfg = _cfg()
    model, params = model_bank(cfg)
    mode = TransferMode(mode_name)
    lens = [5, 9, 14, 20, 26, 33]
    kw = dict(max_batch=2, max_seq=64)

    cl = ServingCluster.build(model, params, n_replicas=2, engine="disagg",
                              policy="round_robin", transfer_mode=mode, **kw)
    cl_reqs = _requests(cfg, lens, max_new=4, seed=9)
    _drain(cl, cl_reqs, max_steps=100_000)

    # the same requests on two standalone engines, split the way
    # round-robin routed them (even indices -> engine 0, odd -> engine 1)
    solo_reqs = _requests(cfg, lens, max_new=4, seed=9)
    for k in range(2):
        eng = DisaggregatedEngine(model, params, transfer_mode=mode, **kw)
        _drain(eng, solo_reqs[k::2], max_steps=100_000)

    assert [tuple(r.generated) for r in cl_reqs] == \
        [tuple(r.generated) for r in solo_reqs]


# --------------------------------------------------------------------------- #
# Cluster surface: Gateway composition and merged records/store.
# --------------------------------------------------------------------------- #
def test_gateway_over_cluster(model_bank):
    from repro.core.transport import Transport

    cfg = _cfg()
    model, params = model_bank(cfg)
    cl = ServingCluster.build(model, params, n_replicas=2, engine="fused",
                              policy="round_robin", max_batch=1, max_seq=64)
    gw = Gateway(cl, first_hop=Transport.TCP)
    reqs = _requests(cfg, [8, 9], max_new=2, seed=4)
    out = _drain(gw, reqs)
    assert len(cl.store.records) == 2
    for rsp in out:
        rec = cl._records[rsp.request_id]
        # the gateway charged BOTH hops onto the stored record through the
        # cluster's merged-records view
        assert rec.stage_s["response"] == pytest.approx(
            rsp.stage_s["response"], rel=1e-12
        )
        assert rec.cpu_s > 0  # TCP keeps the CPU on the data path
    assert cl._records.get(-1) is None
    with pytest.raises(KeyError):
        cl._records[-1]


def test_cluster_build_validates():
    with pytest.raises(ValueError, match="at least one replica"):
        ServingCluster([])


# --------------------------------------------------------------------------- #
# Load generation: seeded determinism, trace round-trip, open-loop drive.
# --------------------------------------------------------------------------- #
def test_poisson_schedule_deterministic():
    a = poisson_schedule(256, rate_rps=100, n_requests=6, seed=42)
    b = poisson_schedule(256, rate_rps=100, n_requests=6, seed=42)
    c = poisson_schedule(256, rate_rps=100, n_requests=6, seed=43)
    assert [x.t for x in a] == [x.t for x in b]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.request.prompt_tokens,
                                      y.request.prompt_tokens)
    assert [x.t for x in a] != [x.t for x in c]
    assert all(a[i].t <= a[i + 1].t for i in range(len(a) - 1))
    with pytest.raises(ValueError, match="rate_rps"):
        poisson_schedule(256, rate_rps=0, n_requests=1)


def test_trace_schedule_roundtrip(tmp_path):
    entries = [
        {"t": 0.0, "prompt_len": 8, "max_new": 2},
        {"t": 0.5, "prompt_len": 16, "max_new": 4, "priority": 1},
    ]
    path = tmp_path / "trace.jsonl"
    save_trace(path, entries)
    assert load_trace(path) == entries
    sched = trace_schedule(load_trace(path), vocab=256, seed=7)
    again = trace_schedule(entries, vocab=256, seed=7)
    assert [a.t for a in sched] == [0.0, 0.5]
    np.testing.assert_array_equal(sched[1].request.prompt_tokens,
                                  again[1].request.prompt_tokens)
    assert sched[1].request.priority == 1
    with pytest.raises(ValueError, match="non-decreasing"):
        trace_schedule([{"t": 1.0, "prompt_len": 4},
                        {"t": 0.5, "prompt_len": 4}], vocab=256)


def test_open_loop_drives_engine_and_charges_queue(model_bank):
    cfg = _cfg()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=1, max_seq=64)
    sched = trace_schedule(
        [{"t": 0.0, "prompt_len": 8, "max_new": 3},
         {"t": 0.0, "prompt_len": 9, "max_new": 3},
         {"t": 0.01, "prompt_len": 10, "max_new": 3}],
        vocab=cfg.vocab_size, seed=0,
    )
    out = run_open_loop(eng, sched)
    assert len(out) == 3
    assert all("queue" in r.stage_s for r in out)
    # arrival stamps follow the schedule: every request was submitted, and
    # the max_batch=1 engine serialized them, so someone waited
    assert max(r.stage_s["queue"] for r in out) > 0.0


def test_closed_loop_baseline_on_cluster(model_bank):
    from repro.serving import run_closed_loop_baseline

    cfg = _cfg()
    model, params = model_bank(cfg)
    cl = ServingCluster.build(model, params, n_replicas=2, engine="fused",
                              policy="least_loaded", max_batch=2, max_seq=64)
    done = run_closed_loop_baseline(cl, cfg.vocab_size, n_clients=3,
                                    requests_per_client=2, prompt_len=12,
                                    max_new_tokens=3)
    assert len(done) == 6
    assert sum(r.routed for r in cl.replicas) == 6
