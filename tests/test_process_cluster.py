"""Process-per-replica cluster: token identity vs the in-process
baseline, request/wire byte conservation, graceful shutdown, and clear
failure surfacing (a dead or raising worker must error, never hang)."""

import time

import pytest

from benchmarks.serving import micro_config


def _trace(cfg, seed=11, n=8):
    from repro.serving import loadgen

    return loadgen.poisson_schedule(
        cfg.vocab_size, rate_rps=300.0, n_requests=n,
        prompt_lens=(8, 16, 24), max_new=4, seed=seed,
    )


KW = dict(max_batch=2, max_seq=64)


def test_process_cluster_token_identity_and_conservation():
    """A seeded trace through backend='process' (2 worker processes, each
    its own XLA client, params rebuilt from the shared seed) must produce
    byte-identical token streams to the in-process Router baseline, with
    request/record counts and payload bytes conserved across the RPC
    boundary."""
    import jax

    from benchmarks.serving import micro_config
    from repro.models.model import Model
    from repro.serving import loadgen
    from repro.serving.cluster import ServingCluster

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # in-process baseline (round_robin: routing independent of timing, so
    # the request->replica map is identical across backends)
    cl = ServingCluster.build(model, params, n_replicas=2,
                              policy="round_robin", **KW)
    out_a = loadgen.run_open_loop(cl, _trace(cfg))
    toks_a = {r.request_id: r.tokens for r in out_a}
    assert cl.parallelism == "sequential-in-process"

    with ServingCluster.build(model, params, n_replicas=2,
                              policy="round_robin", backend="process",
                              param_seed=0, **KW) as pc:
        assert pc.parallelism == "process-per-replica"
        assert pc.async_draining
        out_b = loadgen.run_open_loop(pc, _trace(cfg))
        toks_b = {r.request_id: r.tokens for r in out_b}

        # token identity, aligned by submission order (ids are fresh)
        a = [toks_a[i] for i in sorted(toks_a)]
        b = [toks_b[i] for i in sorted(toks_b)]
        assert a == b

        # conservation across the wire: every submit acknowledged, every
        # request emitted exactly once, payload bytes matching
        tel = pc.telemetry()
        assert tel["parallelism"] == "process-per-replica"
        assert sum(r["submitted"] for r in tel["ipc"]) == len(out_b)
        for row in tel["ipc"]:
            assert row["submitted"] == row["emitted"]
            assert row["request_payload_bytes"] == row["submitted_bytes"]
            assert row["rpc_bytes_sent"] > 0 and row["rpc_bytes_recv"] > 0

        # merged store: one rebased record per request, completion-sorted,
        # with the parent-clock issue stamp preceding the done stamp
        recs = pc.store.records
        assert len(recs) == len(out_b)
        assert all(recs[i].t_done <= recs[i + 1].t_done
                   for i in range(len(recs) - 1))
        assert all(r.t_done > r.t_issue for r in recs)
        procs = [rep.client.proc for rep in pc.replicas]
    # context-manager exit reaps every worker process
    for p in procs:
        assert p.poll() is not None


def test_dead_and_raising_workers_surface_errors():
    """A replica process that dies mid-service must surface a
    ReplicaError naming the exit (not hang the Router); a worker-side
    exception must cross the wire as a ReplicaError with the child's
    traceback; close() must stay safe afterwards."""
    from repro.serving.ipc import ReplicaClient, ReplicaError

    cfg = micro_config()
    client = ReplicaClient(devices=1, label="doomed", call_timeout_s=60.0)
    try:
        client.init({
            "cfg": cfg, "dtype": "float32", "param_seed": 0,
            "engine": "fused", "engine_kw": dict(KW), "backlog": 2,
        })
        # worker-side exception: an op before any crash — unknown ops
        # come back as error frames with the child traceback
        with pytest.raises(ReplicaError, match="unknown op"):
            client._call("definitely_not_an_op", None)
        # hard-kill the worker; the next RPC must error promptly
        client.proc.kill()
        client.proc.wait(timeout=10.0)
        t0 = time.perf_counter()
        with pytest.raises(ReplicaError, match="exited|unresponsive"):
            client.load()
        assert time.perf_counter() - t0 < 30.0  # surfaced, not hung
    finally:
        client.close()
        client.close()  # idempotent
    assert client.proc.poll() is not None


def test_worker_init_failure_reports_traceback():
    """A spec the worker cannot build (bogus engine kwargs) must fail
    init with the child's traceback, and the spawn path must clean the
    process up."""
    from repro.serving.ipc import ReplicaClient, ReplicaError

    cfg = micro_config()
    client = ReplicaClient(devices=1, label="misbuilt")
    try:
        with pytest.raises(ReplicaError, match="failed to initialize"):
            client.init({
                "cfg": cfg, "dtype": "float32", "param_seed": 0,
                "engine": "fused",
                "engine_kw": {"max_batch": 2, "max_seq": 64,
                              "no_such_kwarg": True},
                "backlog": 2,
            })
    finally:
        client.close()
    assert client.proc.poll() is not None


@pytest.mark.slow
def test_process_cluster_policy_sweep_drains():
    """Fuller multiprocess sweep (slow tier): jsq routing over 2 process
    replicas drains a longer trace with conservation intact."""
    import jax

    from repro.models.model import Model
    from repro.serving import loadgen
    from repro.serving.cluster import ServingCluster

    cfg = micro_config()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    with ServingCluster.build(model, params, n_replicas=2, policy="jsq",
                              backend="process", param_seed=0,
                              **KW) as pc:
        out = loadgen.run_open_loop(pc, _trace(cfg, seed=5, n=24))
        assert len(out) == 24
        tel = pc.telemetry()
        assert sum(r["emitted"] for r in tel["ipc"]) == 24
        assert all(r["submitted"] == r["emitted"] for r in tel["ipc"])
