"""Observability: span buffer semantics, cross-process rebasing under
adversarial clock skew, Chrome export determinism, span/charge
reconciliation against a real engine drain, stamp validation, the
metrics registry/sampler, and the slo_summary stage breakdown."""

import json
import threading
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import trace
from repro.core.metrics import merge_record_streams, slo_summary
from repro.core.obs import Counter, Gauge, Histogram, Registry, Sampler
from repro.core.profiler import RequestRecord
from repro.core.trace import Span, Trace, TraceBuffer
from repro.serving import ServingEngine
from repro.serving.request import Request, Response


@pytest.fixture(autouse=True)
def _global_tracer_off():
    """Never leak an enabled global tracer into other tests."""
    yield
    trace.disable_tracing()


def _requests(cfg, lens, max_new=4, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


# --------------------------------------------------------------------------- #
# TraceBuffer semantics
# --------------------------------------------------------------------------- #
def test_buffer_disabled_emit_is_noop():
    buf = TraceBuffer(capacity=8)
    buf.emit("x", 0.0, 1.0)
    assert buf.snapshot() == []
    assert buf.stats() == {
        "enabled": False, "capacity": 8, "buffered": 0,
        "emitted": 0, "dropped": 0,
    }


def test_buffer_ring_counts_drops_never_raises():
    buf = TraceBuffer(capacity=4)
    buf.enable(process="p")
    for i in range(10):
        buf.emit(f"s{i}", float(i), float(i) + 0.5)
    st = buf.stats()
    assert st["emitted"] == 10 and st["buffered"] == 4 and st["dropped"] == 6
    # the ring keeps the newest spans
    assert [s.name for s in buf.snapshot()] == ["s6", "s7", "s8", "s9"]


def test_buffer_enable_reset_and_drain():
    buf = TraceBuffer(capacity=8)
    buf.enable(process="p")
    buf.emit("a", 0.0, 1.0, request_id=7, tag="t")
    got = buf.drain()
    assert [s.name for s in got] == ["a"] and buf.snapshot() == []
    assert got[0].request_id == 7 and got[0].attrs["tag"] == "t"
    assert got[0].process == "p"
    assert got[0].thread == threading.current_thread().name
    buf.emit("b", 0.0, 1.0)
    buf.enable(process="p")  # reset=True clears the ring and counters
    assert buf.snapshot() == [] and buf.stats()["emitted"] == 0


# --------------------------------------------------------------------------- #
# wire round-trip + adversarial skew rebasing (the IPC span ferry)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("offset", [-12345.678, -1e-4, 0.0, 1e-4, 98765.4321])
def test_wire_roundtrip_rebases_onto_parent_clock(offset):
    """A worker whose perf_counter epoch differs by `offset` from the
    parent ships spans over the wire; after ingest, absolute placement
    is rebased while every duration survives untouched."""
    worker = TraceBuffer(capacity=16, process="worker")
    worker.enable()
    t0 = 1000.0 + offset  # worker-clock stamp of a parent-clock t=1000
    worker.emit("prefill.bucket", t0, t0 + 0.25, request_id=1)
    worker.emit("decode.window", t0 + 0.25, t0 + 0.75, request_id=1)

    parent = TraceBuffer(capacity=16, process="router")
    parent.ingest_wire(worker.drain_wire(), offset=offset, process="replica0")
    got = sorted(parent.snapshot(), key=lambda s: s.t_start)
    assert [s.process for s in got] == ["replica0", "replica0"]
    assert got[0].t_start == pytest.approx(1000.0, abs=1e-9)
    assert got[0].wall == pytest.approx(0.25, abs=1e-12)
    assert got[1].wall == pytest.approx(0.50, abs=1e-12)
    # ingest bypasses the enabled gate: relaying must not require the
    # parent buffer to be actively emitting
    assert not parent.enabled and len(parent.snapshot()) == 2


def test_wire_interleaves_with_parent_spans_on_one_timeline():
    """Two workers with opposite-sign skews plus local parent spans all
    sort into true parent-clock order after ingest."""
    parent = TraceBuffer(capacity=32, process="router")
    parent.enable()
    parent.emit("router.pick", 10.0, 10.1)
    for label, off, start in (("replica0", 500.0, 10.2),
                              ("replica1", -500.0, 10.4)):
        w = TraceBuffer(capacity=8, process="w")
        w.enable()
        w.emit("request", start + off, start + off + 0.1)
        parent.ingest_wire(w.drain_wire(), offset=off, process=label)
    order = [s.process for s in
             sorted(parent.snapshot(), key=lambda s: s.t_start)]
    assert order == ["router", "replica0", "replica1"]


def test_merge_record_streams_adversarial_skew():
    def rec(rid, t_issue, t_done):
        return RequestRecord(request_id=rid, client_id=0, t_issue=t_issue,
                             t_done=t_done, stage_s={"inference": 0.5})

    # stream epochs differ by +/- hours; true completion order interleaves
    a = [rec(0, 7200.0, 7201.0), rec(2, 7204.0, 7205.0)]   # skew +7200
    b = [rec(1, -3598.0, -3597.0), rec(3, -3594.0, -3593.0)]  # skew -3600
    merged = merge_record_streams([a, b], offsets=[7200.0, -3600.0])
    assert [r.request_id for r in merged] == [0, 1, 2, 3]
    # durations are skew-invariant; sources are never mutated
    assert all(r.total == pytest.approx(1.0) for r in merged)
    assert all(r.stage_s["inference"] == 0.5 for r in merged)
    assert a[0].t_issue == 7200.0
    with pytest.raises(ValueError, match="offsets length"):
        merge_record_streams([a, b], offsets=[0.0])


# --------------------------------------------------------------------------- #
# stamp validation
# --------------------------------------------------------------------------- #
def test_validate_stamps():
    trace.validate_stamps(1.0, 2.0, 3.0)
    trace.validate_stamps(1.0, 0.0, 3.0)  # zero stamp: not yet set, skipped
    trace.validate_stamps(0.0, 0.0, 0.0)
    with pytest.raises(ValueError, match="t_first_token"):
        trace.validate_stamps(2.0, 1.0, 3.0)
    with pytest.raises(ValueError, match="replica9"):
        trace.validate_stamps(1.0, 2.5, 2.0, where="replica9 rebase")
    # tolerance absorbs clock-estimate error (the IPC rebase case)
    trace.validate_stamps(1.0, 1.0 - 0.01, 2.0, tol=0.05)


# --------------------------------------------------------------------------- #
# real engine drain: reconciliation, determinism, export, debug stamps
# --------------------------------------------------------------------------- #
def _traced_drain(model_bank, seed=0, debug_stamps=False):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        warmup=True, debug_stamps=debug_stamps)
    trace.enable_tracing(process="main")
    for req in _requests(cfg, [5, 11, 23, 37], seed=seed):
        eng.submit(req, time.perf_counter())
    out = eng.run_until_drained(max_steps=10_000)
    assert len(out) == 4
    tr = Trace.from_buffer()
    trace.disable_tracing()
    return eng, out, tr


def test_engine_drain_reconciles_and_trees_are_wellformed(model_bank):
    eng, _out, tr = _traced_drain(model_bank)
    assert len(tr) > 0
    by_req = tr.by_request()
    assert len(by_req) == 4
    # every request grew a full tree: root + queue + a prefill span
    for rid, spans in by_req.items():
        names = {s.name for s in spans}
        assert "request" in names and "queue" in names
        assert any(n.startswith("prefill.") for n in names), names
    assert tr.tree_problems() == []
    assert tr.reconcile(eng.store.records) == []
    # the text stage summary mentions every span name
    summary = tr.stage_summary()
    for name in {s.name for s in tr.spans}:
        assert name in summary


def test_trace_shape_deterministic_across_seeded_runs(model_bank):
    """Same seed, fresh engine -> same span tree SHAPE (request ids and
    stamps differ run to run; the structure must not)."""

    def shape(tr):
        per_req = sorted(
            tuple(sorted(s.name for s in spans))
            for spans in tr.by_request().values()
        )
        return per_req, sorted({s.name for s in tr.spans})

    _e1, _o1, tr1 = _traced_drain(model_bank, seed=3)
    _e2, _o2, tr2 = _traced_drain(model_bank, seed=3)
    assert shape(tr1) == shape(tr2)


def test_chrome_export_roundtrip(tmp_path, model_bank):
    _eng, _out, tr = _traced_drain(model_bank)
    path = tmp_path / "trace.json"
    obj = tr.export_chrome(path)
    reloaded = json.loads(path.read_text())
    assert reloaded == json.loads(json.dumps(obj))
    events = reloaded["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == len(tr)
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
    # metadata events name the process/thread lanes Perfetto displays
    assert any(e["ph"] == "M" and e["name"] == "process_name"
               for e in events)
    assert len({e["pid"] for e in xs}) == 1  # single-process drain


def test_engine_debug_stamps_accepts_clean_drain(model_bank):
    """debug_stamps=True validates every finished record's monotonicity
    inline — a clean drain must pass, and the knob must not change
    results."""
    _eng, out, _tr = _traced_drain(model_bank, debug_stamps=True)
    assert all(r.tokens for r in out)


def test_reconcile_flags_uncovered_charge_and_malformed_trees():
    def span(name, a, b, rid=1, thread="t"):
        return Span(name=name, request_id=rid, t_start=a, t_end=b,
                    process="main", thread=thread)

    # charge exceeds total span wall -> uncovered
    rec = RequestRecord(request_id=1, client_id=0, t_issue=0.0, t_done=1.0,
                        stage_s={"inference": 5.0})
    tr = Trace([span("request", 0.0, 1.0), span("prefill.bucket", 0.0, 0.4)])
    problems = tr.reconcile([rec])
    assert problems and any("inference" in p for p in problems)

    # two roots for one request -> malformed tree
    tr2 = Trace([span("request", 0.0, 1.0), span("request", 2.0, 3.0)])
    assert tr2.tree_problems()

    # overlapping spans on one process-level lane -> malformed
    tr3 = Trace([span("transfer", 0.0, 1.0, rid=None),
                 span("transfer", 0.5, 1.5, rid=None)])
    assert tr3.tree_problems()
    # same intervals on distinct lanes (tag attr) are fine
    s1 = span("transfer", 0.0, 1.0, rid=None)
    s1.attrs["tag"] = "replica0"
    s2 = span("transfer", 0.5, 1.5, rid=None)
    s2.attrs["tag"] = "replica1"
    assert Trace([s1, s2]).tree_problems() == []

    # no records with spans to check against -> loudly inconclusive
    assert any("no record had any spans" in p
               for p in Trace([]).reconcile([rec]))


# --------------------------------------------------------------------------- #
# metrics registry + sampler
# --------------------------------------------------------------------------- #
def test_counter_monotonic_gauge_histogram():
    c = Counter("n")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    g = Gauge("depth")
    g.set(3.5)
    assert g.value == 3.5
    h = Histogram("lat", window=4)
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["total"] == 15.0
    assert snap["min"] == 1.0 and snap["max"] == 5.0
    assert snap["p50"] == pytest.approx(3.5)  # window kept the last 4


def test_registry_get_or_create_ingest_snapshot_delta():
    reg = Registry()
    assert reg.counter("a") is reg.counter("a")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("a")
    reg.ingest_counters({"steps": 10, "tokens": 40}, prefix="engine.")
    reg.ingest_counters({"steps": 15, "tokens": 40}, prefix="engine.")
    reg.gauge("depth").set(2)
    prev = reg.snapshot()
    reg.ingest_counters({"steps": 21}, prefix="engine.")
    cur = reg.snapshot()
    assert cur["counters"]["engine.steps"] == 21
    assert cur["gauges"]["depth"] == 2
    assert Registry.delta(prev, cur)["engine.steps"] == 6
    # ingest is monotonic: a source that resets cannot rewind the counter
    reg.ingest_counters({"steps": 0}, prefix="engine.")
    assert reg.snapshot()["counters"]["engine.steps"] == 21


def test_sampler_observes_and_surfaces_source_failures():
    reg = Registry()
    with Sampler(reg, {"depth": lambda: 7.0}, interval_s=0.001):
        time.sleep(0.05)
    snap = reg.snapshot()["histograms"]["depth"]
    assert snap["count"] >= 1 and snap["p50"] == 7.0

    def boom():
        raise RuntimeError("dead source")

    s = Sampler(reg, {"bad": boom, "ok": lambda: 1.0},
                interval_s=0.001).start()
    time.sleep(0.02)
    with pytest.raises(RuntimeError, match="dead source"):
        s.stop()
    # the healthy source kept sampling despite the dead one
    assert reg.snapshot()["histograms"]["ok"]["count"] >= 1
    with pytest.raises(RuntimeError, match="already started"):
        Sampler(reg, {}).start().start()


def test_engine_counters_and_metrics_snapshot(model_bank):
    eng, _out, _tr = _traced_drain(model_bank)
    counters = eng.counters()
    assert counters["decode_steps"] > 0
    snap = eng.metrics_snapshot()
    assert snap["counters"]["engine.decode_steps"] == counters["decode_steps"]
    assert "engine.queue_depth" in snap["gauges"]


# --------------------------------------------------------------------------- #
# slo_summary stage breakdown (satellite)
# --------------------------------------------------------------------------- #
def test_slo_summary_stage_breakdown():
    def resp(rid, queue, inference):
        return Response(
            request_id=rid, tokens=[1, 2, 3], ttft_s=0.2, total_s=1.0,
            stage_s={"queue": queue, "inference": inference},
        )

    rs = [resp(0, 0.1, 0.5), resp(1, 0.3, 0.7),
          Response(request_id=2, tokens=[1, 2], ttft_s=0.1, total_s=0.5,
                   stage_s={"transfer": 0.05})]
    out = slo_summary(rs)
    assert set(out["stages"]) == {"queue", "inference", "transfer"}
    # a response missing a stage contributes 0.0, so every n matches
    for stage in out["stages"].values():
        assert stage["n"] == 3
    assert out["stages"]["queue"]["mean"] == pytest.approx((0.1 + 0.3) / 3)
    assert out["stages"]["transfer"]["mean"] == pytest.approx(0.05 / 3)
    # warmup drop applies to stages too
    assert slo_summary(rs, warmup=1)["stages"]["queue"]["n"] == 2
