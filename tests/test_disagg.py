"""Disaggregated prefill->decode tier: the pod-boundary handoff must
preserve decode tokens (DIRECT_HBM / DIRECT_DMA bit-exact; HOST_STAGED
within the documented int8 tolerance) and charge the 'transfer' stage into
each request's TTFT.

Runs on the 1-pod degenerate mesh (one CPU device): the full tier —
tiling, collective permute, quantization, metadata round-trip, splice —
executes; CI's 8-forced-host-device smoke covers the real 2-pod
collective."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.transfer import MODE_TRANSPORT, TransferMode
from repro.serving import DisaggregatedEngine, ServingEngine
from repro.serving.request import Request


def _requests(cfg, lens, max_new=5, seed=7):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


def _drain(eng, cfg, lens, max_new=5, seed=7):
    reqs = _requests(cfg, lens, max_new, seed)
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained()
    assert len(out) == len(reqs)
    return reqs, out


@pytest.mark.parametrize(
    "mode", [TransferMode.DIRECT_HBM, TransferMode.DIRECT_DMA]
)
def test_disagg_tokens_identical_to_single_engine(mode, model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 9, 17, 26]
    kw = dict(max_batch=2, max_seq=64)
    base, _ = _drain(ServingEngine(model, params, **kw), cfg, lens)
    dis, _ = _drain(
        DisaggregatedEngine(model, params, transfer_mode=mode, **kw),
        cfg, lens,
    )
    assert [r.generated for r in dis] == [r.generated for r in base]


def test_disagg_host_staged_within_quantization_tolerance(model_bank):
    """HOST_STAGED requantizes the KV payload to int8, so later tokens may
    drift — but every request must complete with a full budget, and the
    FIRST token (computed pre-handoff, carried as int metadata) must be
    bit-exact."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 9, 17, 26]
    kw = dict(max_batch=2, max_seq=64)
    base, _ = _drain(ServingEngine(model, params, **kw), cfg, lens)
    dis, out = _drain(
        DisaggregatedEngine(
            model, params, transfer_mode=TransferMode.HOST_STAGED, **kw
        ),
        cfg, lens,
    )
    for b, d in zip(base, dis):
        assert len(d.generated) == len(b.generated)
        assert d.generated[0] == b.generated[0]  # metadata crosses exact
        assert all(0 <= t < cfg.vocab_size for t in d.generated)


def test_disagg_exact_path_feature_request(model_bank):
    """vlm (feature-frontend) requests route to exact prefill and their
    cache's true length is feature_frames + prompt_tokens: the prefix
    slice must come from the MODEL-returned length — slicing to the
    prompt length alone would cut live KV off the wire (frames 12 +
    prompt 6 = 18 > the 16-slot block a 6-token prefix would round to)
    and silently break token identity."""
    from conftest import nodrop

    from repro.models import FRONTEND_DIM

    cfg = nodrop(get_config("pixtral-12b").reduced())
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    kw = dict(max_batch=2, max_seq=32)

    def mk(seed=11):
        rng = np.random.default_rng(seed)
        return [Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, 6, dtype=np.int32),
            features=rng.normal(size=(1, 12, FRONTEND_DIM)).astype(
                np.float32),
            max_new_tokens=4,
        )]

    def drain(eng, reqs):
        for r in reqs:
            eng.submit(r, time.perf_counter())
        out = eng.run_until_drained()
        assert len(out) == len(reqs)
        return reqs

    base = drain(ServingEngine(model, params, **kw), mk())
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM, **kw
    )
    dis = drain(eng, mk())
    assert [r.generated for r in dis] == [r.generated for r in base]
    # the handoff accounted the full frames+prompt prefix, not prompt-only
    assert eng.handoff_request_bytes > eng.request_handoff_bytes(6)


def test_disagg_exact_path_ssm_arch(model_bank):
    """SSM stacks route to exact prefill; their static conv/state leaves
    must survive the handoff too (DIRECT_HBM is bit-exact)."""
    from conftest import nodrop

    cfg = nodrop(get_config("mamba2-130m").reduced())
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 9, 14]
    kw = dict(max_batch=2, max_seq=32)
    base, _ = _drain(ServingEngine(model, params, **kw), cfg, lens,
                     max_new=4)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM, **kw
    )
    assert not eng.bucketed_prefill
    dis, _ = _drain(eng, cfg, lens, max_new=4)
    assert [r.generated for r in dis] == [r.generated for r in base]


def test_disagg_charges_transfer_stage_and_ttft(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.HOST_STAGED,
        max_batch=1, max_seq=32,
    )
    reqs, out = _drain(eng, cfg, [8], max_new=3)
    rec = eng.store.records[0]
    assert rec.stage_s["transfer"] > 0
    assert rec.cpu_s > 0  # TCP keeps the CPU on the handoff data path
    assert rec.transfer_wall_s > 0  # the collective really ran
    # on host-device runs the charge is the profile-modeled hop on this
    # request's share of the moved wire bytes — the sole rider of the one
    # handoff owns all of handoff_wire_bytes
    hop = MODE_TRANSPORT[TransferMode.HOST_STAGED]
    want = eng.profile.handoff_time(hop, eng.handoff_wire_bytes)
    assert rec.stage_s["transfer"] == pytest.approx(want, rel=1e-9)
    # ...and it is folded into the reported ttft in place of the measured
    # (non-representative) collective wall, alongside the modeled ingress
    ingress = rec.stage_s["request"] + rec.stage_s.get("copy_in", 0.0)
    raw = reqs[0].t_first_token - reqs[0].t_arrival
    assert out[0].ttft_s == pytest.approx(
        raw + ingress - rec.transfer_wall_s + want, abs=1e-9
    )
    assert eng.handoffs == 1
    assert eng.handoff_wire_bytes > 0
    assert eng.handoff_request_bytes > 0


def test_disagg_batched_admission_swaps_full_handoff_wall(model_bank):
    """Two requests co-admitted in ONE bucket both wait the FULL collective
    wall before their first token — the modeled-charge ttft swap must
    remove all of it, not a 1/N share, and fold in each request's own
    modeled hop."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM,
        max_batch=2, max_seq=32,
    )
    reqs, out = _drain(eng, cfg, [8, 9], max_new=2)  # same pow2 bucket
    assert eng.handoffs == 1  # one collective carried both requests
    by_id = {r.request_id: r for r in out}
    tot = sum(eng.request_handoff_bytes(len(r.prompt_tokens)) for r in reqs)
    for req in reqs:
        rec = next(r for r in eng.store.records
                   if r.request_id == req.request_id)
        assert rec.transfer_wall_s == pytest.approx(eng.handoff_wall_s)
        # modeled hop on this request's prefix-proportional share of the
        # bytes the collective moved
        share = (eng.handoff_wire_bytes
                 * eng.request_handoff_bytes(len(req.prompt_tokens)) / tot)
        want = eng.profile.handoff_time(
            MODE_TRANSPORT[TransferMode.DIRECT_HBM], share,
        )
        ingress = rec.stage_s["request"] + rec.stage_s.get("copy_in", 0.0)
        raw = req.t_first_token - req.t_arrival
        assert by_id[req.request_id].ttft_s == pytest.approx(
            raw + ingress - eng.handoff_wall_s + want, abs=1e-9
        )


def test_disagg_modeled_hop_ordering(model_bank):
    """Per-request handoff charge must reproduce the paper's ordering:
    last-hop hardware acceleration is cheapest (GDR <= RDMA <= TCP)."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    charge = {}
    for mode in TransferMode:
        eng = DisaggregatedEngine(
            model, params, transfer_mode=mode, max_batch=2, max_seq=64,
        )
        _drain(eng, cfg, [9, 21, 30], max_new=2)
        recs = eng.store.records
        charge[mode] = sum(r.stage_s["transfer"] for r in recs) / len(recs)
    assert (charge[TransferMode.DIRECT_HBM]
            <= charge[TransferMode.DIRECT_DMA]
            <= charge[TransferMode.HOST_STAGED])


@pytest.mark.parametrize(
    "mode", [TransferMode.DIRECT_HBM, TransferMode.DIRECT_DMA]
)
def test_prefix_only_handoff_scales_with_occupancy(mode, model_bank):
    """The collective must move the admitted rows' KV prefix, not the
    max_batch x max_seq pool tree: one admitted short request costs exactly
    the per-row share of a full-pool admission and a small fraction of the
    padded admission tree (the pre-fix payload), with decode tokens
    unchanged."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    kw = dict(max_batch=4, max_seq=64)
    lens = [5, 5, 5, 5]  # one pow2 bucket: a single full-pool admission

    base, _ = _drain(ServingEngine(model, params, **kw), cfg, lens,
                     max_new=3)
    eng1 = DisaggregatedEngine(model, params, transfer_mode=mode, **kw)
    _drain(eng1, cfg, [5], max_new=3)
    assert eng1.handoffs == 1
    engN = DisaggregatedEngine(model, params, transfer_mode=mode, **kw)
    disN, _ = _drain(engN, cfg, lens, max_new=3)
    assert engN.handoffs == 1  # all four rode one collective
    assert [r.generated for r in disN] == [r.generated for r in base]

    # per-row scaling: 4 co-admitted rows cost exactly 4x one row (same
    # rounded prefix, per-row metadata)
    assert engN.handoff_wire_bytes == 4 * eng1.handoff_wire_bytes

    # acceptance: a single short-prompt handoff moves well under 1/4 of
    # the padded max_batch x max_seq tree the collective used to permute
    assert eng1.handoff_wire_bytes < eng1.padded_tree_wire_bytes() / 4

    # useful-prefix accounting never exceeds what the wire moved (equal up
    # to the handoff_block rounding)
    assert eng1.handoff_request_bytes <= eng1.handoff_wire_bytes
    assert engN.handoff_request_bytes <= engN.handoff_wire_bytes


def test_handoff_wire_bytes_equals_moved_payload(model_bank):
    """``handoff_wire_bytes`` must equal ``payload_wire_bytes`` of exactly
    what the collective permutes — the [rows, prefix_blocks] cache slice
    plus those rows' slot metadata — under every mechanism."""
    from repro.core.transfer import payload_wire_bytes
    from repro.models import kvcache as kvc

    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 21]  # distinct pow2 buckets: one single-row handoff each
    for mode in TransferMode:
        eng = DisaggregatedEngine(
            model, params, transfer_mode=mode, max_batch=2, max_seq=64,
        )
        _drain(eng, cfg, lens, max_new=2)
        assert eng.handoffs == 2
        expected = 0
        for true_len in lens:
            sliced = kvc.slice_cache(
                eng.pool.caches, 1, eng.handoff_prefix(true_len)
            )
            meta = {k: jnp.zeros((1,), jnp.int32)
                    for k in ("lengths", "next_tokens", "slot_idx",
                              "max_new")}
            expected += payload_wire_bytes(
                {"caches": sliced, "meta": meta}, mode
            )
        assert eng.handoff_wire_bytes == expected


def test_host_staged_cpu_pinned_to_wire_bytes(model_bank):
    """TCP keeps the CPU on the handoff data path: the per-request cpu_s
    shares must sum to EXACTLY the bytes the collective moved — pre-fix,
    cpu_s was charged on per-request prefix bytes while the measured wall
    (and wire counter) reflected the padded admission tree."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.HOST_STAGED,
        max_batch=2, max_seq=64,
    )
    _drain(eng, cfg, [5, 9, 17], max_new=2)
    assert eng.handoffs >= 2  # co-admitted bucket + trailing admission
    total_cpu = sum(r.cpu_s for r in eng.store.records)
    assert total_cpu == pytest.approx(
        eng.handoff_wire_bytes * eng.profile.tcp_cpu_per_byte, rel=1e-9
    )


def test_handoff_block_granularity_knob(model_bank):
    """The moved prefix rounds up to a power of two floored at
    handoff_block: block=max_seq degenerates to a full-ring transfer,
    block=1 moves the next-pow2 prefix."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    wire = {}
    for blk in (1, 16, 64):
        eng = DisaggregatedEngine(
            model, params, transfer_mode=TransferMode.DIRECT_HBM,
            max_batch=2, max_seq=64, handoff_block=blk,
        )
        _drain(eng, cfg, [5], max_new=2)
        wire[blk] = eng.handoff_wire_bytes
    assert wire[1] < wire[16] < wire[64]
    with pytest.raises(ValueError, match="handoff_block"):
        DisaggregatedEngine(model, params, handoff_block=0)


def test_disagg_rejects_legacy(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    with pytest.raises(ValueError, match="legacy"):
        DisaggregatedEngine(model, params, legacy=True)
