"""Disaggregated prefill->decode tier: the pod-boundary handoff must
preserve decode tokens (DIRECT_HBM / DIRECT_DMA bit-exact; HOST_STAGED
within the documented int8 tolerance) and charge the 'transfer' stage into
each request's TTFT.

Runs on the 1-pod degenerate mesh (one CPU device): the full tier —
tiling, collective permute, quantization, metadata round-trip, splice —
executes; CI's 8-forced-host-device smoke covers the real 2-pod
collective."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.transfer import MODE_TRANSPORT, TransferMode
from repro.serving import DisaggregatedEngine, ServingEngine
from repro.serving.request import Request


def _requests(cfg, lens, max_new=5, seed=7):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt_tokens=rng.integers(0, cfg.vocab_size, s, dtype=np.int32),
            max_new_tokens=max_new,
        )
        for s in lens
    ]


def _drain(eng, cfg, lens, max_new=5, seed=7):
    reqs = _requests(cfg, lens, max_new, seed)
    for r in reqs:
        eng.submit(r, time.perf_counter())
    out = eng.run_until_drained()
    assert len(out) == len(reqs)
    return reqs, out


@pytest.mark.parametrize(
    "mode", [TransferMode.DIRECT_HBM, TransferMode.DIRECT_DMA]
)
def test_disagg_tokens_identical_to_single_engine(mode, model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 9, 17, 26]
    kw = dict(max_batch=2, max_seq=64)
    base, _ = _drain(ServingEngine(model, params, **kw), cfg, lens)
    dis, _ = _drain(
        DisaggregatedEngine(model, params, transfer_mode=mode, **kw),
        cfg, lens,
    )
    assert [r.generated for r in dis] == [r.generated for r in base]


def test_disagg_host_staged_within_quantization_tolerance(model_bank):
    """HOST_STAGED requantizes the KV payload to int8, so later tokens may
    drift — but every request must complete with a full budget, and the
    FIRST token (computed pre-handoff, carried as int metadata) must be
    bit-exact."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 9, 17, 26]
    kw = dict(max_batch=2, max_seq=64)
    base, _ = _drain(ServingEngine(model, params, **kw), cfg, lens)
    dis, out = _drain(
        DisaggregatedEngine(
            model, params, transfer_mode=TransferMode.HOST_STAGED, **kw
        ),
        cfg, lens,
    )
    for b, d in zip(base, dis):
        assert len(d.generated) == len(b.generated)
        assert d.generated[0] == b.generated[0]  # metadata crosses exact
        assert all(0 <= t < cfg.vocab_size for t in d.generated)


def test_disagg_exact_path_ssm_arch(model_bank):
    """SSM stacks route to exact prefill; their static conv/state leaves
    must survive the handoff too (DIRECT_HBM is bit-exact)."""
    from conftest import nodrop

    cfg = nodrop(get_config("mamba2-130m").reduced())
    model, params = model_bank(cfg, dtype=jnp.float32, seed=1)
    lens = [5, 9, 14]
    kw = dict(max_batch=2, max_seq=32)
    base, _ = _drain(ServingEngine(model, params, **kw), cfg, lens,
                     max_new=4)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM, **kw
    )
    assert not eng.bucketed_prefill
    dis, _ = _drain(eng, cfg, lens, max_new=4)
    assert [r.generated for r in dis] == [r.generated for r in base]


def test_disagg_charges_transfer_stage_and_ttft(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.HOST_STAGED,
        max_batch=1, max_seq=32,
    )
    reqs, out = _drain(eng, cfg, [8], max_new=3)
    rec = eng.store.records[0]
    assert rec.stage_s["transfer"] > 0
    assert rec.cpu_s > 0  # TCP keeps the CPU on the handoff data path
    assert rec.transfer_wall_s > 0  # the collective really ran
    # on host-device runs the charge is the profile-modeled hop on this
    # request's wire bytes (true KV prefix + slot metadata)
    hop = MODE_TRANSPORT[TransferMode.HOST_STAGED]
    want = eng.profile.handoff_time(
        hop, eng.request_handoff_bytes(len(reqs[0].prompt_tokens))
    )
    assert rec.stage_s["transfer"] == pytest.approx(want, rel=1e-9)
    # ...and it is folded into the reported ttft in place of the measured
    # (non-representative) collective wall
    raw = reqs[0].t_first_token - reqs[0].t_arrival
    assert out[0].ttft_s == pytest.approx(
        raw - rec.transfer_wall_s + want, abs=1e-9
    )
    assert eng.handoffs == 1
    assert eng.handoff_wire_bytes > 0
    assert eng.handoff_request_bytes > 0


def test_disagg_batched_admission_swaps_full_handoff_wall(model_bank):
    """Two requests co-admitted in ONE bucket both wait the FULL collective
    wall before their first token — the modeled-charge ttft swap must
    remove all of it, not a 1/N share, and fold in each request's own
    modeled hop."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = DisaggregatedEngine(
        model, params, transfer_mode=TransferMode.DIRECT_HBM,
        max_batch=2, max_seq=32,
    )
    reqs, out = _drain(eng, cfg, [8, 9], max_new=2)  # same pow2 bucket
    assert eng.handoffs == 1  # one collective carried both requests
    by_id = {r.request_id: r for r in out}
    for req in reqs:
        rec = next(r for r in eng.store.records
                   if r.request_id == req.request_id)
        assert rec.transfer_wall_s == pytest.approx(eng.handoff_wall_s)
        want = eng.profile.handoff_time(
            MODE_TRANSPORT[TransferMode.DIRECT_HBM],
            eng.request_handoff_bytes(len(req.prompt_tokens)),
        )
        raw = req.t_first_token - req.t_arrival
        assert by_id[req.request_id].ttft_s == pytest.approx(
            raw - eng.handoff_wall_s + want, abs=1e-9
        )


def test_disagg_modeled_hop_ordering(model_bank):
    """Per-request handoff charge must reproduce the paper's ordering:
    last-hop hardware acceleration is cheapest (GDR <= RDMA <= TCP)."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    charge = {}
    for mode in TransferMode:
        eng = DisaggregatedEngine(
            model, params, transfer_mode=mode, max_batch=2, max_seq=64,
        )
        _drain(eng, cfg, [9, 21, 30], max_new=2)
        recs = eng.store.records
        charge[mode] = sum(r.stage_s["transfer"] for r in recs) / len(recs)
    assert (charge[TransferMode.DIRECT_HBM]
            <= charge[TransferMode.DIRECT_DMA]
            <= charge[TransferMode.HOST_STAGED])


def test_disagg_rejects_legacy(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    with pytest.raises(ValueError, match="legacy"):
        DisaggregatedEngine(model, params, legacy=True)
