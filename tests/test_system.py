"""End-to-end system behaviour: real serving + gateway + training loop."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.transport import Transport
from repro.serving import ClosedLoopClient, Gateway, ServingEngine, run_closed_loop
from repro.training import AdamWConfig, DataConfig, TrainConfig, train


def test_serving_end_to_end_continuous_batching(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=2, max_seq=64,
                        transport=Transport.GDR)
    clients = [ClosedLoopClient(i, cfg.vocab_size, prompt_len=8, max_new_tokens=4)
               for i in range(3)]  # 3 clients > 2 slots: forces slot reuse
    run_closed_loop(eng, clients, requests_per_client=2)
    responses = [r for c in clients for r in c.completed]
    assert len(responses) == 6
    assert all(len(r.tokens) == 4 for r in responses)
    assert all(0 <= t for r in responses for t in r.tokens)
    assert all(r.total_s > 0 and r.ttft_s >= 0 for r in responses)
    # profiler recorded every request with modeled wires + real compute
    assert len(eng.store.records) == 6
    means = eng.store.stage_means()
    assert means["request"] > 0 and means["inference"] > 0
    assert means["copy_in"] == 0  # GDR skips the copy engine


def test_serving_transport_changes_modeled_stages(model_bank):
    cfg = get_config("starcoder2-3b").reduced()
    model, params = model_bank(cfg)
    stage = {}
    for t in (Transport.GDR, Transport.RDMA):
        eng = ServingEngine(model, params, max_batch=2, max_seq=48, transport=t)
        clients = [ClosedLoopClient(0, cfg.vocab_size, prompt_len=8,
                                    max_new_tokens=2)]
        run_closed_loop(eng, clients, requests_per_client=2)
        stage[t] = eng.store.stage_means()
    assert stage[Transport.RDMA]["copy_in"] > 0
    assert stage[Transport.GDR]["copy_in"] == 0


def test_gateway_adds_first_hop(model_bank):
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=2, max_seq=48,
                        transport=Transport.GDR)
    gw = Gateway(eng, first_hop=Transport.TCP)
    clients = [ClosedLoopClient(0, cfg.vocab_size, prompt_len=8, max_new_tokens=2)]
    run_closed_loop(gw, clients, requests_per_client=1)
    rec = eng.store.records[0]
    assert rec.cpu_s > 0  # TCP hop consumed gateway CPU
    assert rec.stage_s["request"] > 0


def test_gateway_charges_response_cpu_symmetrically():
    """TCP keeps the CPU on the data path on BOTH hops (paper Fig. 9): the
    response hop must charge tcp_cpu_per_byte exactly like ``submit``'s
    request hop — the pre-fix gateway silently dropped response-side CPU."""
    from repro.core.profiler import RequestRecord
    from repro.core.transport import PAPER_A2
    from repro.serving.request import Request, Response

    class _FakeEngine:
        def __init__(self):
            self._records = {}
            self.queue = []
            self.store = None

        def submit(self, req, now):
            self._records[req.request_id] = RequestRecord(
                request_id=req.request_id, client_id=0,
                bytes_in=req.payload_bytes, bytes_out=0,
            )

        def step(self):
            rid = next(iter(self._records))
            return [Response(request_id=rid, tokens=[1, 2, 3], ttft_s=0.0,
                             total_s=0.0, stage_s={})]

    gw = Gateway(_FakeEngine(), first_hop=Transport.TCP)
    req = Request(prompt_tokens=np.zeros(10, np.int32))
    gw.submit(req, 0.0)
    done = gw.step()
    rec = gw._records[req.request_id]
    want = (req.payload_bytes + 4 * len(done[0].tokens)) * PAPER_A2.tcp_cpu_per_byte
    assert rec.cpu_s == pytest.approx(want, rel=1e-12)
    # the STORED record must see the response hop exactly like the
    # returned Response does — the pre-fix gateway updated only rsp, so
    # ProfileStore under-reported deployments by one hop per request
    # (stage_s["response"] short, t_done stale)
    assert rec.stage_s["response"] == pytest.approx(
        done[0].stage_s["response"], rel=1e-12
    )
    assert rec.t_done - rec.t_issue == pytest.approx(
        done[0].total_s, rel=1e-12
    )


def test_gateway_store_matches_response_on_real_engine(model_bank):
    """End to end: after a gateway drain, each stored record's response
    stage and total agree with the Response the client received."""
    cfg = get_config("llama3-8b").reduced()
    model, params = model_bank(cfg)
    eng = ServingEngine(model, params, max_batch=2, max_seq=48,
                        transport=Transport.GDR)
    gw = Gateway(eng, first_hop=Transport.TCP)
    clients = [ClosedLoopClient(0, cfg.vocab_size, prompt_len=8,
                                max_new_tokens=2)]
    run_closed_loop(gw, clients, requests_per_client=2)
    responses = {r.request_id: r for c in clients for r in c.completed}
    assert responses
    for rec in eng.store.records:
        rsp = responses[rec.request_id]
        assert rec.stage_s["response"] == pytest.approx(
            rsp.stage_s["response"], rel=1e-12
        )
        assert rec.total == pytest.approx(rsp.total_s, rel=1e-9)


@pytest.mark.slow
def test_training_loss_decreases_and_checkpoints():
    from repro.models import Model
    import tempfile

    cfg = get_config("starcoder2-3b").reduced()
    model = Model(cfg)
    with tempfile.TemporaryDirectory() as d:
        _, _, hist = train(
            model,
            DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                       zipf_a=1.5, seed=0),
            TrainConfig(steps=60, log_every=10, ckpt_every=30, ckpt_dir=d),
            AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=60),
            log_fn=lambda s: None,
        )
        import os
        assert any(f.startswith("ckpt_") for f in os.listdir(d))
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.2, hist


def test_checkpoint_roundtrip(model_bank):
    import tempfile

    from repro.training.checkpoint import restore_checkpoint, save_checkpoint
    from repro.training.optimizer import adamw_init

    cfg = get_config("mamba2-130m").reduced()
    model, params = model_bank(cfg, seed=3)
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 7, params, opt)
        p2, o2, step = restore_checkpoint(path, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
