#!/usr/bin/env python
"""Unified static-check entry point: ``python -m tools.checks`` runs the
docs link/anchor check, the BENCH-JSON schema check, and reprolint in
one pass with one output contract:

* one line per finding, ``[checker] finding`` — greppable, CI-annotable
* exit 0 when every checker passes, 1 on any finding, 2 on usage error

``--only docs,bench,lint`` restricts the run; reprolint runs in strict
mode (unbaselined findings AND stale baseline entries fail), matching
the CI lint job. Individual checkers remain runnable on their own
(``python tools/check_docs.py`` etc.); this module only orchestrates.
"""

from __future__ import annotations

import argparse
import sys

from tools import check_bench_schema, check_docs
from tools.reprolint import lint_paths, load_baseline
from tools.reprolint.core import DEFAULT_BASELINE, ROOT


def run_docs() -> list:
    return check_docs.check()


def run_bench() -> list:
    return check_bench_schema.check()


def run_lint() -> list:
    """reprolint over src/repro in strict mode, findings as strings."""
    findings = lint_paths([ROOT / "src" / "repro"])
    baseline = load_baseline(DEFAULT_BASELINE)
    out = [f.format() for f in findings if f.fingerprint not in baseline]
    seen = {f.fingerprint for f in findings}
    out.extend(
        f"baseline.json: stale entry {fp} (finding fixed — remove it or "
        f"rerun --update-baseline)"
        for fp in sorted(baseline - seen)
    )
    return out


CHECKERS = {
    "docs": run_docs,
    "bench": run_bench,
    "lint": run_lint,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.checks",
        description="run all repo static checks (docs links/anchors, "
                    "BENCH schemas, reprolint --strict)",
    )
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                         + ",".join(CHECKERS))
    args = ap.parse_args(argv)

    names = list(CHECKERS)
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in CHECKERS]
        if unknown:
            print(f"checks: unknown checker(s): {', '.join(unknown)} "
                  f"(have: {', '.join(CHECKERS)})", file=sys.stderr)
            return 2

    total = 0
    for name in names:
        findings = CHECKERS[name]()
        for f in findings:
            print(f"[{name}] {f}")
        total += len(findings)
        print(f"[{name}] {'ok' if not findings else f'{len(findings)} finding(s)'}")
    return 1 if total else 0


if __name__ == "__main__":
    sys.exit(main())
