#!/usr/bin/env python
"""Benchmark-JSON schema check: every committed ``BENCH_*.json`` must
carry the fields docs/benchmarks.md documents, and every required leaf
field must actually be MENTIONED in docs/benchmarks.md — so the JSON the
repo ships, the docs that explain it, and the benchmark code that writes
it cannot drift apart silently.

Schemas are dotted key paths; a ``*`` segment means "every child" (e.g.
``disagg.disaggregated.*.handoff_wire_bytes`` requires the field in every
transfer mode's row). A path's last segment is the leaf checked against
the docs text. Run from anywhere: paths resolve against the repo root.

Usage: python tools/check_bench_schema.py  (exit 1 + a listing on drift)
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = ROOT / "docs" / "benchmarks.md"

# file -> required dotted paths ('*' = every child of a dict)
SCHEMAS = {
    "BENCH_serving.json": [
        "benchmark",
        "serving.workload.model",
        "serving.seed_sync_loop.wall_s",
        "serving.fast_path.wall_s",
        "serving.fast_path.decode_steps",
        "serving.fast_path.decode_steps_dispatched",
        "serving.fast_path.tokens_per_s",
        "serving.fast_path.prefill_compiles",
        "serving.speedup.tokens_per_s",
        "packed_prefill.footprint.bucketed.prefill_padded_tokens",
        "packed_prefill.footprint.packed.prefill_padded_tokens",
        "packed_prefill.footprint.packed.pad_overhead",
        "packed_prefill.footprint.token_identical",
        "packed_prefill.head_of_line.unchunked.worst_step_ms",
        "packed_prefill.head_of_line.chunked.worst_step_ms",
        "packed_prefill.head_of_line.chunked.head_of_line_ratio",
        "packed_prefill.head_of_line.chunked.decode_step_ms",
        "packed_prefill.head_of_line.tpot_bound_ok",
        "ragged_decode_kernel.ragged_lens_us",
        "ragged_decode_kernel.dense_lens_us",
        "tracing.overhead.off_wall_s",
        "tracing.overhead.on_wall_s",
        "tracing.overhead.overhead_frac",
        "tracing.overhead.overhead_ok",
        "tracing.reconcile.n_requests",
        "tracing.reconcile.n_spans",
        "tracing.reconcile.reconcile_ok",
    ],
    "BENCH_disagg.json": [
        "benchmark",
        "disagg.workload.placement",
        "disagg.single_engine.ttft_s_mean",
        "disagg.disaggregated.*.handoffs",
        "disagg.disaggregated.*.handoff_wire_bytes",
        "disagg.disaggregated.*.request_prefix_bytes_mean",
        "disagg.disaggregated.*.handoff_charge_s_mean",
        "disagg.disaggregated.*.ttft_s_mean",
        "disagg.disaggregated.*.token_match_vs_single_engine",
        "disagg.disaggregated.*.stage_walls_s",
        "disagg.ordering_ok.handoff_charge",
        "disagg.occupancy_sweep.*.padded_tree_wire_bytes",
        "disagg.occupancy_sweep.*.occ1_short_vs_padded_tree",
        "disagg.warmup_sweep.warm_construction_s",
        "disagg.warmup_sweep.extents_pretraced",
        "disagg.warmup_sweep.prefill_buckets_pretraced",
    ],
    "BENCH_cluster.json": [
        "benchmark",
        "cluster.workload.warmup_dropped_from_percentiles",
        # regime tag: "sequential-in-process" for the policy/rate sweeps
        # vs "process-per-replica" for the process_cluster section — the
        # two must never be conflated when reading throughput numbers
        "cluster.workload.parallelism",
        "cluster.skewed_trace.trace",
        "cluster.skewed_trace.fused.gap_s",
        "cluster.skewed_trace.fused.round_robin.slo",
        "cluster.skewed_trace.fused.round_robin.per_replica",
        "cluster.skewed_trace.fused.round_robin.balance_index_busy",
        "cluster.skewed_trace.fused.round_robin.balance_index_routed",
        "cluster.rate_sweep",
        "cluster.token_identity.direct_hbm",
        "cluster.token_identity.direct_dma",
        "cluster.process_cluster.parallelism",
        "cluster.process_cluster.cpus",
        "cluster.process_cluster.sequential_drain_sum_s",
        "cluster.process_cluster.concurrent_drain_s",
        "cluster.process_cluster.concurrent_vs_sequential_ratio",
        "cluster.process_cluster.parallel_capacity_asserted",
        "cluster.process_cluster.token_identical_vs_inprocess",
        "cluster.process_cluster.request_bytes_conserved",
        "cluster.process_cluster.records_conserved",
        "cluster.process_cluster.trace.path",
        "cluster.process_cluster.trace.processes",
        "cluster.process_cluster.trace.spans",
        "cluster.process_cluster.trace.events",
        "cluster.process_cluster.trace.export_ok",
    ],
    "BENCH_prefix.json": [
        "benchmark",
        "prefix.workload.prompt_len",
        "prefix.workload.page_size",
        "prefix.workload.n_prefixes",
        "prefix.workload.zipf_a",
        "prefix.workload.transfer_mode",
        "prefix.hit_rate_sweep.*.hit_rate",
        "prefix.hit_rate_sweep.*.prefix_len",
        "prefix.hit_rate_sweep.*.suffix_len",
        "prefix.hit_rate_sweep.*.prefill_tokens_total",
        "prefix.hit_rate_sweep.*.prefill_tokens_uncached",
        "prefix.hit_rate_sweep.*.uncached_fraction",
        "prefix.hit_rate_sweep.*.prefix_hits",
        "prefix.hit_rate_sweep.*.handoff_wire_bytes",
        "prefix.hit_rate_sweep.*.wire_reconciled_exact",
        "prefix.hit_rate_sweep.*.ttft_p99_s",
        "prefix.hit_rate_sweep.*.ttft_mean_s",
        "prefix.token_identity.*.token_match_vs_ring",
        "prefix.token_identity.*.prefix_hits",
    ],
}


def _resolve(node, parts, path_so_far=""):
    """Yield (full_path, found) for one dotted path against ``node``."""
    if not parts:
        yield path_so_far, True
        return
    head, rest = parts[0], parts[1:]
    if not isinstance(node, dict):
        yield f"{path_so_far}.{head}".lstrip("."), False
        return
    if head == "*":
        if not node:
            yield f"{path_so_far}.*".lstrip("."), False
            return
        for k, v in node.items():
            yield from _resolve(v, rest, f"{path_so_far}.{k}".lstrip("."))
        return
    if head not in node:
        yield f"{path_so_far}.{head}".lstrip("."), False
        return
    yield from _resolve(node[head], rest, f"{path_so_far}.{head}".lstrip("."))


def check_chrome_trace(path: Path) -> list:
    """BENCH_trace.json is a Chrome trace-event file, not a keyed BENCH
    dict, so it gets its own shape check: parseable JSON, a non-empty
    ``traceEvents`` list, and spans from at least two processes (the
    merged-clock claim — router plus one worker on one timeline)."""
    if not path.exists():
        return [f"{path.name}: missing (run benchmarks.cluster)"]
    try:
        data = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        return [f"{path.name}: does not parse: {e}"]
    events = data.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path.name}: traceEvents missing or empty"]
    pids = {e.get("pid") for e in events if e.get("ph") == "X"}
    if len(pids) < 2:
        return [f"{path.name}: spans from {len(pids)} process(es) — "
                f"need >= 2 (router + worker) on the merged clock"]
    return []


def check() -> list:
    """Return problem strings (missing fields / undocumented leaves /
    missing files)."""
    problems = []
    docs_text = DOCS.read_text()
    problems.extend(check_chrome_trace(ROOT / "BENCH_trace.json"))
    for fname, paths in SCHEMAS.items():
        f = ROOT / fname
        if not f.exists():
            problems.append(f"{fname}: missing (run its benchmark)")
            continue
        data = json.loads(f.read_text())
        for path in paths:
            parts = path.split(".")
            for full, found in _resolve(data, parts):
                if not found:
                    problems.append(f"{fname}: missing field {full}")
            leaf = parts[-1]
            # case-insensitive: docs write enum leaves as DIRECT_DMA etc.
            if leaf != "*" and leaf.lower() not in docs_text.lower():
                problems.append(
                    f"docs/benchmarks.md: field `{leaf}` ({fname}) "
                    f"undocumented"
                )
    return problems


def main() -> int:
    problems = check()
    if problems:
        print("benchmark schema drift:")
        for p in problems:
            print(f"  {p}")
        return 1
    n = sum(len(v) for v in SCHEMAS.values())
    print(f"bench schemas ok: {n} required paths across "
          f"{len(SCHEMAS)} BENCH files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
