"""reprolint rules RL001–RL007: the repo's serving-path invariants.

Each rule protects a specific BENCH claim (see docs/lint.md for the full
mapping). The common theme: the paper's GDR-vs-TCP deltas are latency
*accounting* claims, so anything that silently moves host work, XLA
compiles, or blocking waits into (or out of) a timed stage is a
measurement bug even when the tokens come out right.

All rules are AST-only (no imports of the scanned code) and resolve
names through each module's import aliases, so ``import jax.numpy as
jnp`` / ``from jax import jit as J`` can't dodge them. Cross-module
resolution is deliberately out of scope: a callable imported from
another file is not analyzed (documented limitation — keep hot-path
helpers local to their module or suppress with a justification).
"""

from __future__ import annotations

import ast
from typing import Optional

from .core import Context, Finding, Module, rule

# stage names a RequestRecord charges; a function that both reads the
# perf_counter clock and charges one of these is a "timed-stage function"
STAGE_NAMES = {
    "queue", "preprocess", "inference", "transfer",
    "request", "response", "copy_in", "copy_out",
}

# files whose timed stages feed BENCH latency claims (RL001's scope)
HOT_PATH_FILES = (
    "serving/engine.py", "serving/disagg.py", "serving/cluster.py",
)

# expressions that force a device->host sync (or an eager device
# round-trip) when applied to device values
_NP_MATERIALIZE = {"numpy.asarray", "numpy.array"}


def _in_hot_file(mod: Module) -> bool:
    return mod.rel.endswith(HOT_PATH_FILES)


def _in_serving(mod: Module) -> bool:
    return "serving/" in mod.rel


def _walk_local(node: ast.AST):
    """Walk a function body without descending into nested function or
    class definitions (their lines belong to their own scope)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if not isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(child))


def _is_perf_counter(mod: Module, node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and mod.call_name(node) == "time.perf_counter")


def _charges_stage(mod: Module, call: ast.Call) -> bool:
    """``<rec>.add("preprocess", dt)``-shaped stage charge."""
    return (isinstance(call.func, ast.Attribute)
            and call.func.attr == "add"
            and call.args
            and isinstance(call.args[0], ast.Constant)
            and call.args[0].value in STAGE_NAMES)


def _is_timed_stage_function(mod: Module, fn: ast.AST) -> bool:
    """Timed-stage function: reads the stage clock AND charges a request
    stage. (The designated blockers — the sync ``_harvest`` and the
    pipeline's harvest thread — read the clock but charge nothing, so
    they fall outside this definition by construction.)"""
    reads_clock = charges = False
    for node in _walk_local(fn):
        if isinstance(node, ast.Call):
            if mod.call_name(node) == "time.perf_counter":
                reads_clock = True
            if _charges_stage(mod, node):
                charges = True
        if reads_clock and charges:
            return True
    return False


def _contains_device_expr(mod: Module, node: ast.AST) -> bool:
    """Heuristic: the subtree eagerly touches device values (a ``jax.*``
    / ``jax.numpy.*`` call or a ``.block_until_ready()``)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = mod.call_name(sub)
            if name and (name == "jax" or name.startswith(("jax.",))):
                return True
            if (isinstance(sub.func, ast.Attribute)
                    and sub.func.attr == "block_until_ready"):
                return True
    return False


def _first_arg(call: ast.Call) -> Optional[ast.AST]:
    return call.args[0] if call.args else None


# --------------------------------------------------------------------------- #
@rule(
    "RL001", "host-sync-in-hot-path",
    "no device->host sync inside a timed-stage function (only the "
    "pipeline's designated harvest thread may block)",
    interested=_in_hot_file,
)
def rl001(mod: Module, ctx: Context) -> list:
    findings = []
    for qual, fn in mod.functions():
        if not _is_timed_stage_function(mod, fn):
            continue
        for node in _walk_local(fn):
            if not isinstance(node, ast.Call):
                continue
            name = mod.call_name(node)
            hit = None
            if name in ("jax.device_get", "jax.block_until_ready"):
                hit = name
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "block_until_ready"):
                hit = ".block_until_ready()"
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                hit = ".item()"
            elif name in _NP_MATERIALIZE:
                arg = _first_arg(node)
                # host literals / fresh numpy results never sync a device
                host_only = isinstance(arg, (
                    ast.List, ast.Tuple, ast.Dict, ast.Constant,
                    ast.ListComp, ast.GeneratorExp,
                )) or (isinstance(arg, ast.Call)
                       and (mod.call_name(arg) or "").startswith("numpy."))
                if arg is not None and not host_only:
                    hit = name
            elif name in ("float", "int"):
                arg = _first_arg(node)
                if arg is not None and _contains_device_expr(mod, arg):
                    hit = f"{name}() over a device expression"
            if hit:
                findings.append(Finding(
                    "RL001", mod.rel, node.lineno, qual,
                    f"host sync `{hit}` inside timed-stage function "
                    f"`{qual}` — stage clocks are running; only the "
                    f"designated harvest thread may block",
                ))
    return findings


# --------------------------------------------------------------------------- #
# RL002: impure jit
# --------------------------------------------------------------------------- #
_JIT_WRAPPERS = {
    "jax.jit", "jax.pmap", "jax.shard_map",
    "jax.experimental.shard_map.shard_map",
    "jax.experimental.pallas.pallas_call",
}


def _jit_wrapper_name(mod: Module, call: ast.Call) -> Optional[str]:
    name = mod.call_name(call)
    if name in _JIT_WRAPPERS:
        return name
    # functools.partial(jax.jit, ...) — the decorator idiom
    if name == "functools.partial" and call.args:
        inner = mod.resolve(call.args[0])
        if inner in _JIT_WRAPPERS:
            return inner
    return None


def _local_defs(mod: Module) -> dict:
    """name -> [function nodes] for every def in the module (methods and
    nested defs included; bare-name keyed — good enough for resolution
    inside one file)."""
    out: dict[str, list] = {}
    for qual, fn in mod.functions():
        out.setdefault(fn.name, []).append((qual, fn))
    return out


def _jit_roots(mod: Module):
    """Yield (reason, func_node_or_lambda, qualname) for every function
    this module passes into a jit/shard_map/pallas_call wrapper."""
    defs = _local_defs(mod)

    def resolve_target(node):
        """A function-valued argument -> matching local defs/lambdas."""
        if isinstance(node, ast.Lambda):
            enc = mod.enclosing_function(node.lineno)
            yield (f"{enc[0]}.<lambda>" if enc else "<lambda>"), node
        elif isinstance(node, ast.Name):
            for qual, fn in defs.get(node.id, []):
                yield qual, fn
        elif (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            for qual, fn in defs.get(node.attr, []):
                yield qual, fn
        elif (isinstance(node, ast.Call)
                and mod.call_name(node) == "functools.partial"
                and node.args):
            yield from resolve_target(node.args[0])

    for node in ast.walk(mod.tree):
        # decorators: @jax.jit / @functools.partial(jax.jit, ...)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                name = (mod.resolve(dec) if not isinstance(dec, ast.Call)
                        else _jit_wrapper_name(mod, dec))
                if name in _JIT_WRAPPERS:
                    for qual, fn in defs.get(node.name, []):
                        if fn is node:
                            yield name, fn, qual
        # call form: jax.jit(f), pl.pallas_call(kernel, ...), shard_map(f)
        if isinstance(node, ast.Call):
            wrapper = _jit_wrapper_name(mod, node)
            if wrapper and node.args:
                for qual, fn in resolve_target(node.args[0]):
                    yield wrapper, fn, qual


def _reachable_jitted(mod: Module, roots):
    """Transitive closure of jit roots through same-module calls (plain
    names and ``self.<method>``)."""
    defs = _local_defs(mod)
    seen: dict[int, tuple] = {}
    work = list(roots)
    while work:
        wrapper, fn, qual = work.pop()
        if id(fn) in seen:
            continue
        seen[id(fn)] = (wrapper, fn, qual)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = None
            if isinstance(node.func, ast.Name):
                callee = node.func.id
            elif (isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"):
                callee = node.func.attr
            if callee:
                for q2, fn2 in defs.get(callee, []):
                    if id(fn2) not in seen:
                        work.append((wrapper, fn2, q2))
    return seen.values()


@rule(
    "RL002", "impure-jit",
    "no host clocks, host RNG, printing, or closed-over-state mutation "
    "inside a function traced by jit/shard_map/pallas_call",
)
def rl002(mod: Module, ctx: Context) -> list:
    findings = []
    for wrapper, fn, qual in _reachable_jitted(mod, _jit_roots(mod)):
        for node in _walk_local(fn):
            msg = None
            if isinstance(node, ast.Call):
                name = mod.call_name(node)
                if name and name.startswith("time."):
                    msg = f"host clock `{name}`"
                elif name and name.startswith("numpy.random"):
                    msg = f"host RNG `{name}`"
                elif name and (name == "random"
                               or name.startswith("random.")):
                    msg = f"host RNG `{name}`"
                elif name == "print":
                    msg = "`print` (host side effect, runs at trace time)"
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                msg = (f"`{'global' if isinstance(node, ast.Global) else 'nonlocal'}"
                       f" {', '.join(node.names)}` (mutates closed-over state)")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        msg = (f"assignment to `self.{t.attr}` (traced "
                               f"functions must not mutate Python state — "
                               f"the write happens once, at trace time)")
            if msg:
                findings.append(Finding(
                    "RL002", mod.rel, node.lineno, qual,
                    f"impure jit: {msg} inside `{qual}`, traced via "
                    f"`{wrapper.rsplit('.', 1)[-1]}`",
                ))
    return findings


# --------------------------------------------------------------------------- #
# RL003: lock discipline
# --------------------------------------------------------------------------- #
_BLOCKING_SIMPLE = {"jax.device_get", "time.sleep"}
_BLOCKING_ATTRS = {
    "block_until_ready", "sendall", "recv", "accept", "connect", "join",
}
_QUEUE_CTORS = {"queue.Queue", "queue.LifoQueue", "queue.PriorityQueue"}


def _guarded_decl(mod: Module, cls: ast.ClassDef):
    """(guarded_attrs, lock_attr) from a class-level
    ``_REPROLINT_GUARDED = ("attr", ...)`` declaration (None, None when
    the class opts out). Lock attr defaults to ``_lock``; override with
    ``_REPROLINT_LOCK = "name"``."""
    guarded, lock = None, "_lock"
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            if stmt.targets[0].id == "_REPROLINT_GUARDED":
                from .core import _string_elements
                guarded = _string_elements(stmt.value)
            elif stmt.targets[0].id == "_REPROLINT_LOCK" \
                    and isinstance(stmt.value, ast.Constant):
                lock = str(stmt.value.value)
    return guarded, lock


def _queue_attrs(mod: Module, cls: ast.ClassDef) -> set:
    """self-attributes assigned from a queue.Queue(...) constructor."""
    out = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            name = mod.call_name(node.value)
            if name in _QUEUE_CTORS:
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        out.add(t.attr)
    return out


def _lock_spans(mod: Module, fn: ast.AST, lock_attr: str) -> list:
    """(start, end) line spans of ``with self.<lock>:`` bodies."""
    spans = []
    for node in _walk_local(fn):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if mod.resolve(item.context_expr) == f"self.{lock_attr}":
                    spans.append((node.lineno, node.end_lineno or node.lineno))
    return spans


def _in_spans(line: int, spans: list) -> bool:
    return any(s <= line <= e for s, e in spans)


def _direct_blocking(mod: Module, fn: ast.AST, queue_attrs: set) -> list:
    """(line, description) for blocking primitives in a function body:
    device syncs, sleeps, socket ops, joins, and bounded-queue put/get on
    a known queue attribute."""
    out = []
    for node in _walk_local(fn):
        if not isinstance(node, ast.Call):
            continue
        name = mod.call_name(node)
        if name in _BLOCKING_SIMPLE:
            out.append((node.lineno, f"`{name}`"))
        elif isinstance(node.func, ast.Attribute):
            attr = node.func.attr
            if attr in _BLOCKING_ATTRS:
                out.append((node.lineno, f"`.{attr}()`"))
            elif attr in ("put", "get"):
                recv = mod.resolve(node.func.value)
                if recv and recv.startswith("self.") \
                        and recv[len("self."):] in queue_attrs:
                    out.append((node.lineno, f"`{recv}.{attr}()`"))
    return out


@rule(
    "RL003", "lock-discipline",
    "declared lock-guarded attributes only touched under the lock, and "
    "no blocking call while the lock is held",
)
def rl003(mod: Module, ctx: Context) -> list:
    findings = []
    for cls in mod.classes():
        guarded, lock_attr = _guarded_decl(mod, cls)
        if guarded is None:
            continue
        queue_attrs = _queue_attrs(mod, cls)
        # methods whose body blocks (for the helper-under-lock check):
        # name -> description of the first blocking primitive inside
        blockers: dict[str, str] = {}
        methods = [n for n in cls.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for m in methods:
            hits = _direct_blocking(mod, m, queue_attrs)
            # a helper that takes a queue as a parameter and puts/gets on
            # it blocks too — detect by bare put/get with a timeout kwarg
            for node in _walk_local(m):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in ("put", "get")
                        and isinstance(node.func.value, ast.Name)
                        and any(kw.arg == "timeout" for kw in node.keywords)):
                    hits.append(
                        (node.lineno,
                         f"`{node.func.value.id}.{node.func.attr}(timeout=)`")
                    )
            if hits:
                blockers[m.name] = hits[0][1]
        for m in methods:
            spans = _lock_spans(mod, m, lock_attr)
            qual = f"{cls.name}.{m.name}"
            if m.name != "__init__":
                # guarded attributes touched outside the lock
                for node in _walk_local(m):
                    if (isinstance(node, ast.Attribute)
                            and isinstance(node.value, ast.Name)
                            and node.value.id == "self"
                            and node.attr in guarded
                            and not _in_spans(node.lineno, spans)):
                        findings.append(Finding(
                            "RL003", mod.rel, node.lineno, qual,
                            f"lock-guarded attribute `self.{node.attr}` "
                            f"accessed outside `with self.{lock_attr}` "
                            f"in `{qual}`",
                        ))
            # blocking calls while the lock is held
            for line, desc in _direct_blocking(mod, m, queue_attrs):
                if _in_spans(line, spans):
                    findings.append(Finding(
                        "RL003", mod.rel, line, qual,
                        f"blocking call {desc} while holding "
                        f"`self.{lock_attr}` in `{qual}` — the deadlock "
                        f"shape: a full queue parks every thread that "
                        f"needs the lock",
                    ))
            for node in _walk_local(m):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and isinstance(node.func.value, ast.Name)
                        and node.func.value.id == "self"
                        and node.func.attr in blockers
                        and _in_spans(node.lineno, spans)):
                    findings.append(Finding(
                        "RL003", mod.rel, node.lineno,
                        f"{cls.name}.{m.name}",
                        f"call to blocking helper `self.{node.func.attr}` "
                        f"(contains {blockers[node.func.attr]}) while "
                        f"holding `self.{lock_attr}` in "
                        f"`{cls.name}.{m.name}`",
                    ))
    return findings


# --------------------------------------------------------------------------- #
# RL004: IPC frame safety
# --------------------------------------------------------------------------- #
# terminal names that hold device arrays / param pytrees in this repo
_DEVICE_STATE_NAMES = {
    "params", "prefill_params", "decode_params", "caches", "blocks",
    "page_table", "_prefix_store_blocks",
}
_FRAME_FUNCS = {"send_msg", "dumps", "_call", "start_init"}
# jax introspection that returns host scalars, not arrays — safe to ship
_JAX_SCALAR_CALLS = {
    "jax.device_count", "jax.local_device_count", "jax.process_index",
    "jax.process_count",
}


def _device_leak(mod: Module, node: ast.AST, defs: dict,
                 depth: int = 1) -> Optional[str]:
    """First device-state reference reachable from a payload expression:
    a banned terminal name, a jax/jnp call, or (one level deep) a local
    function whose returns leak."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in _DEVICE_STATE_NAMES:
            return f"`{sub.id}`"
        if isinstance(sub, ast.Attribute) and sub.attr in _DEVICE_STATE_NAMES:
            return f"`.{sub.attr}`"
        if isinstance(sub, ast.Call):
            name = mod.call_name(sub)
            if name and name.startswith(("jax.", "jax.numpy.")) \
                    and name not in _JAX_SCALAR_CALLS:
                return f"`{name}(...)`"
            if depth > 0 and isinstance(sub.func, ast.Name):
                for _q, fn in defs.get(sub.func.id, []):
                    for ret in ast.walk(fn):
                        if isinstance(ret, ast.Return) and ret.value:
                            leak = _device_leak(mod, ret.value, defs,
                                                depth - 1)
                            if leak:
                                return (f"{leak} via local "
                                        f"`{sub.func.id}()`")
    return None


@rule(
    "RL004", "ipc-frame-safety",
    "no jax.Array / param pytree reachable from an object pickled into "
    "an IPC frame — params never cross the wire",
)
def rl004(mod: Module, ctx: Context) -> list:
    findings = []
    defs = _local_defs(mod)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        name = mod.call_name(node) or ""
        terminal = name.rsplit(".", 1)[-1]
        if terminal not in _FRAME_FUNCS:
            continue
        if terminal == "dumps" and not name.startswith("pickle."):
            continue
        enc = mod.enclosing_function(node.lineno)
        qual = enc[0] if enc else "<module>"
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            # the socket / op-string positions can't carry pytrees;
            # scanning them too is harmless (their names aren't banned)
            leak = _device_leak(mod, arg, defs)
            if leak:
                findings.append(Finding(
                    "RL004", mod.rel, node.lineno, qual,
                    f"device state {leak} reachable from the payload of "
                    f"IPC frame call `{terminal}` in `{qual}` — params "
                    f"and KV never cross the wire (workers rebuild from "
                    f"the seed)",
                ))
                break
    return findings


# --------------------------------------------------------------------------- #
# RL005: warmup coverage
# --------------------------------------------------------------------------- #
def _jit_register_candidates(mod: Module, call: ast.Call) -> tuple:
    """(candidates, line, qual) naming a ``jax.jit(...)`` creation site:
    the assignment target's terminal name (attribute / name / subscript
    base), falling back to the enclosing function's name."""
    node: ast.AST = call
    names: set[str] = set()
    while node is not None:
        parent = getattr(node, "_reprolint_parent", None)
        if isinstance(parent, ast.Assign):
            for t in parent.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
                elif isinstance(t, ast.Attribute):
                    names.add(t.attr)
                elif isinstance(t, ast.Subscript):
                    base = t.value
                    if isinstance(base, ast.Attribute):
                        names.add(base.attr)
                    elif isinstance(base, ast.Name):
                        names.add(base.id)
            break
        if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Module)) or parent is None:
            break
        node = parent
    enc = mod.enclosing_function(call.lineno)
    qual = enc[0] if enc else "<module>"
    if not names:
        names.add(qual.rsplit(".", 1)[-1])
    # allow Class.attr-qualified table entries too
    for n in list(names):
        if "." in qual:
            names.add(f"{qual.split('.')[0]}.{n}")
    return names, call.lineno, qual


@rule(
    "RL005", "warmup-coverage",
    "every jax.jit created in serving/ is registered in the "
    "WARM_PRETRACE_TABLE (pre-traced at construction) or suppressed "
    "with a reason",
    interested=_in_serving,
)
def rl005(mod: Module, ctx: Context) -> list:
    findings = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and mod.call_name(node) == "jax.jit":
            names, line, qual = _jit_register_candidates(mod, node)
            if not ctx.in_warm_table(names):
                pretty = sorted(n for n in names if "." not in n) or \
                    sorted(names)
                findings.append(Finding(
                    "RL005", mod.rel, line, qual,
                    f"jit `{pretty[0]}` is not in WARM_PRETRACE_TABLE — "
                    f"an unwarmed jit compiles inside a timed stage on "
                    f"first use (register it in the table once warm() "
                    f"pre-traces it, or suppress with the reason it "
                    f"cannot be pre-traced)",
                ))
    return findings


# --------------------------------------------------------------------------- #
# RL006: swallowed-failure hygiene
# --------------------------------------------------------------------------- #
def _routes_failures(fn: ast.AST) -> bool:
    """True when the function body contains a try/except whose handler
    does real capture work (not just pass/continue) — the minimum for a
    daemon thread whose exceptions would otherwise vanish."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Try):
            for h in node.handlers:
                if any(not isinstance(stmt, (ast.Pass, ast.Continue))
                       for stmt in h.body):
                    return True
    return False


@rule(
    "RL006", "swallowed-failure-hygiene",
    "no bare `except:`; every daemon-thread target routes its "
    "exceptions to a failure-capture path",
)
def rl006(mod: Module, ctx: Context) -> list:
    findings = []
    defs = _local_defs(mod)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None:
            enc = mod.enclosing_function(node.lineno)
            qual = enc[0] if enc else "<module>"
            findings.append(Finding(
                "RL006", mod.rel, node.lineno, qual,
                f"bare `except:` in `{qual}` swallows every failure "
                f"(KeyboardInterrupt and SystemExit included) — catch "
                f"something and route it",
            ))
        if isinstance(node, ast.Call) \
                and mod.call_name(node) == "threading.Thread":
            kwargs = {kw.arg: kw.value for kw in node.keywords}
            daemon = kwargs.get("daemon")
            if not (isinstance(daemon, ast.Constant) and daemon.value):
                continue
            target = kwargs.get("target")
            target_defs = []
            if isinstance(target, ast.Name):
                target_defs = defs.get(target.id, [])
                tname = target.id
            elif (isinstance(target, ast.Attribute)
                    and isinstance(target.value, ast.Name)
                    and target.value.id == "self"):
                target_defs = defs.get(target.attr, [])
                tname = target.attr
            else:
                continue  # unresolvable target: out of scope
            enc = mod.enclosing_function(node.lineno)
            qual = enc[0] if enc else "<module>"
            if target_defs and not any(_routes_failures(fn)
                                       for _q, fn in target_defs):
                findings.append(Finding(
                    "RL006", mod.rel, node.lineno, qual,
                    f"daemon thread target `{tname}` has no "
                    f"failure-capture: an exception kills the thread "
                    f"silently and the pipeline wedges (wrap the body "
                    f"and surface the traceback like "
                    f"EnginePipeline._run_guarded)",
                ))
    return findings


# --------------------------------------------------------------------------- #
# RL007: trace coverage
# --------------------------------------------------------------------------- #
def _emits_trace(mod: Module, fn: ast.AST) -> bool:
    """True when the function body reaches a span emitter: a ``.emit()``
    call (``trace.tracer().emit(...)``) or a call to a ``_trace*`` /
    ``trace_flush`` helper (the engine's admission/window emitters)."""
    for node in _walk_local(fn):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute):
            attr = node.func.attr
            if attr == "emit" or attr == "trace_flush" \
                    or attr.startswith("_trace"):
                return True
        elif isinstance(node.func, ast.Name) \
                and node.func.id.startswith("_trace"):
            return True
    return False


@rule(
    "RL007", "trace-coverage",
    "every timed-stage function in a hot-path file also emits a span "
    "(directly via .emit() or through a _trace* helper) so charged "
    "stages stay reconcilable against the trace",
    interested=_in_hot_file,
)
def rl007(mod: Module, ctx: Context) -> list:
    findings = []
    for qual, fn in mod.functions():
        if not _is_timed_stage_function(mod, fn):
            continue
        if _emits_trace(mod, fn):
            continue
        findings.append(Finding(
            "RL007", mod.rel, fn.lineno, qual,
            f"timed-stage function `{qual}` charges a stage but emits no "
            f"span — Trace.reconcile() cannot cross-check its charge "
            f"(call trace.tracer().emit(...) or a _trace* helper, or "
            f"suppress with the reason the stage is trace-exempt)",
        ))
    return findings
