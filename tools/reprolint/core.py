"""reprolint framework: scoped AST analysis with import-alias resolution,
inline suppressions, and a committed baseline.

The linter exists because the repo's headline BENCH numbers are only as
honest as its stage accounting: a hidden ``device_get`` inside a timed
stage, an XLA compile landing in a timed window, or a lock held across a
blocking queue put silently corrupts every measurement. Runtime tests
catch these after the fact; reprolint catches the *shape* of the bug at
PR time, the way ``tools/check_bench_schema.py`` freezes the BENCH/docs
contract.

Building blocks (used by every rule in ``rules.py``):

* :class:`Module` — one parsed file: AST with parent links, import-alias
  map (``jnp`` -> ``jax.numpy``, ``from time import perf_counter`` ->
  ``time.perf_counter``), dotted-name resolution for attribute chains,
  an enclosing-function index, and per-line suppressions.
* Suppressions — ``# reprolint: disable=RL001`` on a finding's line (or,
  on a ``def`` line, for the whole function) silences those rules; the
  text after the code list is the justification and is REQUIRED — a
  suppression with no reason is itself reported (RL000).
* Baseline — a committed JSON list of finding fingerprints
  (line-number-free, so baselines survive unrelated edits). Findings in
  the baseline are grandfathered; ``--strict`` additionally fails on
  STALE baseline entries so the file can only shrink.
* :class:`Context` — cross-file facts gathered in a first pass (today:
  the union of ``WARM_PRETRACE_TABLE`` declarations, for RL005).

Rules register themselves via :func:`rule`; the runner applies each rule
to every module it declares interest in (``Rule.interested``).
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Callable, Iterable, Optional

ROOT = Path(__file__).resolve().parents[2]
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"

_SUPPRESS = re.compile(
    r"#\s*reprolint:\s*disable=((?:RL\d{3})(?:\s*,\s*RL\d{3})*)\s*(.*)"
)


# --------------------------------------------------------------------------- #
# findings
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding. ``fingerprint`` is line-number-free so a
    baseline entry survives edits elsewhere in the file."""

    rule: str  # "RL001"
    path: str  # repo-relative posix path
    line: int  # 1-based
    scope: str  # enclosing qualname ("Class.method") or "<module>"
    message: str

    @property
    def fingerprint(self) -> str:
        raw = f"{self.rule}|{self.path}|{self.scope}|{self.message}"
        return hashlib.sha256(raw.encode()).hexdigest()[:16]

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# --------------------------------------------------------------------------- #
# per-module model
# --------------------------------------------------------------------------- #
class Module:
    """One parsed source file plus the resolution/suppression machinery
    every rule shares."""

    def __init__(self, path: Path, source: str, rel: Optional[str] = None):
        self.path = path
        if rel is not None:
            self.rel = rel
        else:
            try:
                self.rel = path.resolve().relative_to(ROOT).as_posix()
            except ValueError:
                self.rel = path.as_posix()
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        # parent links: rules walk up for enclosing Assign / FunctionDef
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                child._reprolint_parent = parent  # type: ignore[attr-defined]
        self.aliases = self._collect_aliases()
        self._functions = self._collect_functions()
        self._suppress_lines, self.bad_suppressions = self._collect_suppress()

    # -------------------------- imports / names ------------------------ #
    def _collect_aliases(self) -> dict:
        """Local name -> fully qualified dotted path, from every import
        statement in the file (any nesting level)."""
        out: dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
        return out

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Dotted name of an expression, with import aliases expanded:
        ``jnp.asarray`` -> ``jax.numpy.asarray``; ``self.x.f`` ->
        ``self.x.f``. None for non-name expressions (calls, literals)."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(self.aliases.get(node.id, node.id))
            return ".".join(reversed(parts))
        return None

    def call_name(self, call: ast.Call) -> Optional[str]:
        return self.resolve(call.func)

    # ------------------------- function index -------------------------- #
    def _collect_functions(self) -> list:
        """(start, end, def_line, qualname, node) for every function,
        innermost-last, with Class.method qualnames."""
        out: list[tuple] = []

        def visit(node, prefix):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    out.append((child.lineno, child.end_lineno or child.lineno,
                                child.lineno, q, child))
                    visit(child, f"{q}.")
                elif isinstance(child, ast.ClassDef):
                    visit(child, f"{prefix}{child.name}.")
                else:
                    visit(child, prefix)

        visit(self.tree, "")
        return out

    def enclosing_function(self, line: int) -> Optional[tuple]:
        """(qualname, node, def_line) of the innermost function
        containing ``line``, or None at module level."""
        best = None
        for start, end, def_line, q, node in self._functions:
            if start <= line <= end:
                if best is None or (start >= best[3]):
                    best = (q, node, def_line, start)
        return None if best is None else best[:3]

    def functions(self) -> Iterable[tuple]:
        """Yield (qualname, node) for every function in the file."""
        for _s, _e, _d, q, node in self._functions:
            yield q, node

    def classes(self) -> Iterable[ast.ClassDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ClassDef):
                yield node

    # -------------------------- suppressions --------------------------- #
    def _collect_suppress(self):
        """line -> set of rule codes; plus Findings for suppressions with
        no justification text (they'd otherwise silence rules for free)."""
        per_line: dict[int, set] = {}
        bad: list[Finding] = []
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS.search(text)
            if not m:
                continue
            codes = {c.strip() for c in m.group(1).split(",")}
            per_line[i] = codes
            justification = m.group(2).strip(" -—:\t")
            if not justification:
                enc = self.enclosing_function(i)
                bad.append(Finding(
                    "RL000", self.rel, i, enc[0] if enc else "<module>",
                    f"suppression of {','.join(sorted(codes))} carries no "
                    f"justification (add one after the code list)",
                ))
        return per_line, bad

    def suppressed(self, rule: str, line: int) -> bool:
        """True if ``rule`` is disabled on ``line`` — by a comment on the
        line itself or on the enclosing function's ``def`` line (which
        scopes the suppression to the whole function)."""
        if rule in self._suppress_lines.get(line, ()):
            return True
        enc = self.enclosing_function(line)
        if enc is not None:
            _q, node, def_line = enc
            # the comment may sit on any line of the (possibly wrapped)
            # def signature
            sig_end = node.body[0].lineno - 1 if node.body else def_line
            for ln in range(def_line, sig_end + 1):
                if rule in self._suppress_lines.get(ln, ()):
                    return True
        return False


# --------------------------------------------------------------------------- #
# cross-file context
# --------------------------------------------------------------------------- #
class Context:
    """Facts gathered from ALL modules before any rule runs."""

    def __init__(self, modules: list):
        self.modules = modules
        # union of WARM_PRETRACE_TABLE declarations (RL005): names of jit
        # targets the construction-time warm pass pre-traces
        self.warm_table: set[str] = set()
        for mod in modules:
            for node in ast.walk(mod.tree):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == "WARM_PRETRACE_TABLE"):
                    self.warm_table |= _string_elements(node.value)

    def in_warm_table(self, candidates: set) -> bool:
        return bool(candidates & self.warm_table)


def _string_elements(node: ast.AST) -> set:
    """String constants inside a (frozen)set/tuple/list literal, possibly
    wrapped in a frozenset()/set() call."""
    if isinstance(node, ast.Call) and node.args:
        return _string_elements(node.args[0])
    out = set()
    if isinstance(node, (ast.Set, ast.Tuple, ast.List)):
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.add(el.value)
    return out


# --------------------------------------------------------------------------- #
# rule registry
# --------------------------------------------------------------------------- #
RULES: list = []


@dataclasses.dataclass
class Rule:
    code: str
    name: str
    doc: str
    interested: Callable[[Module], bool]
    run: Callable[[Module, Context], list]


def rule(code: str, name: str, doc: str,
         interested: Callable[[Module], bool] = lambda mod: True):
    """Decorator: register ``fn(module, context) -> [Finding]``."""

    def deco(fn):
        RULES.append(Rule(code, name, doc, interested, fn))
        return fn

    return deco


# --------------------------------------------------------------------------- #
# runner
# --------------------------------------------------------------------------- #
def iter_py_files(paths: Iterable[Path]) -> list:
    out = []
    for p in paths:
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    return out


def parse_modules(paths: Iterable[Path]) -> tuple:
    """Parse every file; unparseable files become findings (RL000), not
    crashes — a linter that dies on a syntax error hides every other
    finding in the run."""
    modules, errors = [], []
    for f in iter_py_files(paths):
        try:
            modules.append(Module(f, f.read_text()))
        except (SyntaxError, UnicodeDecodeError) as e:
            try:
                rel = f.resolve().relative_to(ROOT).as_posix()
            except ValueError:
                rel = f.as_posix()
            errors.append(Finding(
                "RL000", rel, getattr(e, "lineno", 1) or 1, "<module>",
                f"file does not parse: {e.__class__.__name__}: {e}",
            ))
    return modules, errors


def lint_paths(paths: Iterable[Path]) -> list:
    """Run every registered rule over ``paths`` (files or directories).
    Suppressed findings are dropped here; baselining happens in the CLI."""
    modules, findings = parse_modules(paths)
    ctx = Context(modules)
    for mod in modules:
        findings.extend(mod.bad_suppressions)
        for r in RULES:
            if not r.interested(mod):
                continue
            for f in r.run(mod, ctx):
                if not mod.suppressed(f.rule, f.line):
                    findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_source(source: str, filename: str = "snippet.py") -> list:
    """Lint one in-memory snippet (the test harness entry point). The
    ``filename`` controls path-scoped rules: name it e.g.
    ``src/repro/serving/engine.py`` to exercise the hot-path rules."""
    mod = Module(Path(filename), source, rel=Path(filename).as_posix())
    ctx = Context([mod])
    findings = list(mod.bad_suppressions)
    for r in RULES:
        if not r.interested(mod):
            continue
        for f in r.run(mod, ctx):
            if not mod.suppressed(f.rule, f.line):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# --------------------------------------------------------------------------- #
# baseline
# --------------------------------------------------------------------------- #
def load_baseline(path: Path) -> set:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("findings", []))


def save_baseline(path: Path, findings: Iterable[Finding]) -> None:
    path.write_text(json.dumps({
        "comment": ("grandfathered reprolint findings (fingerprints); "
                    "regenerate with --update-baseline, shrink whenever "
                    "a finding is fixed"),
        "findings": sorted({f.fingerprint for f in findings}),
    }, indent=2) + "\n")
