"""reprolint — AST invariant linter for this repo's serving hot paths.

Run as ``python -m tools.reprolint [--strict] [paths...]``; see
``docs/lint.md`` for the rules and the invariants they protect.
"""

from . import rules  # noqa: F401  (importing registers RL001–RL006)
from .core import (  # noqa: F401
    DEFAULT_BASELINE,
    Context,
    Finding,
    Module,
    RULES,
    lint_paths,
    lint_source,
    load_baseline,
    save_baseline,
)

__all__ = [
    "Context", "Finding", "Module", "RULES", "DEFAULT_BASELINE",
    "lint_paths", "lint_source", "load_baseline", "save_baseline",
]
