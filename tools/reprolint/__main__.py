"""CLI for reprolint.

Exit codes (shared with ``tools.checks``):
  0  clean (or every finding baselined)
  1  findings (unbaselined; with --strict also stale baseline entries)
  2  usage / internal error
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import RULES, lint_paths, load_baseline, save_baseline
from .core import DEFAULT_BASELINE, ROOT

DEFAULT_PATHS = [ROOT / "src" / "repro"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="AST invariant linter for the serving hot paths "
                    "(rules RL001-RL006; see docs/lint.md)",
    )
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files or directories (default: src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale baseline entries "
                         "(the baseline may only shrink)")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                    help="baseline file (default: tools/reprolint/"
                         "baseline.json)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current findings as the new baseline "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in RULES:
            print(f"{r.code} {r.name}: {r.doc}")
        return 0

    paths = args.paths or DEFAULT_PATHS
    for p in paths:
        if not p.exists():
            print(f"reprolint: no such path: {p}", file=sys.stderr)
            return 2

    findings = lint_paths(paths)

    if args.update_baseline:
        save_baseline(args.baseline, findings)
        print(f"reprolint: baselined {len(findings)} finding(s) -> "
              f"{args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    fresh = [f for f in findings if f.fingerprint not in baseline]
    seen = {f.fingerprint for f in findings}
    stale = sorted(baseline - seen)

    for f in fresh:
        print(f.format())
    n_base = len(findings) - len(fresh)
    status = (f"reprolint: {len(fresh)} finding(s)"
              + (f", {n_base} baselined" if n_base else ""))
    if args.strict and stale:
        status += (f", {len(stale)} STALE baseline entr"
                   f"{'y' if len(stale) == 1 else 'ies'} "
                   f"(fixed findings — remove them or rerun "
                   f"--update-baseline)")
    print(status)

    if fresh or (args.strict and stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
