#!/usr/bin/env python
"""Docs link checker: every relative markdown link in README.md and
docs/*.md must resolve to a file (or directory) in the repo.

External links (http/https/mailto) and pure in-page anchors (#...) are
skipped; a link's #fragment is stripped before resolution. Run from
anywhere: paths resolve against the repo root (this file's parent's
parent). Used by the CI docs job and by tests/test_docs.py.

Usage: python tools/check_docs.py  (exit 1 + a listing on broken links)
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# [text](target) — excluding images' ! is unnecessary: image paths must
# resolve too
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:")


def doc_files() -> list[Path]:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def check(paths=None) -> list[str]:
    """Return 'file: broken-target' strings for every unresolvable link."""
    broken = []
    for md in paths or doc_files():
        for target in _LINK.findall(md.read_text()):
            if target.startswith(_SKIP) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists():
                broken.append(f"{md.relative_to(ROOT)}: {target}")
    return broken


def main() -> int:
    files = doc_files()
    broken = check(files)
    if broken:
        print("broken doc links:")
        for b in broken:
            print(f"  {b}")
        return 1
    n = sum(len(_LINK.findall(p.read_text())) for p in files)
    print(f"docs links ok: {n} links across {len(files)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
