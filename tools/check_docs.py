#!/usr/bin/env python
"""Docs link checker: every relative markdown link in README.md and
docs/*.md must resolve to a file (or directory) in the repo, and every
``#fragment`` — in-page (``#section``) or cross-file
(``file.md#section``) — must match a heading in the target markdown
file (GitHub slug rules: lowercase, punctuation stripped, spaces to
hyphens, ``-N`` suffixes on duplicates).

External links (http/https/mailto) are skipped; fragments pointing at
non-markdown targets are ignored (no headings to check). Run from
anywhere: paths resolve against the repo root (this file's parent's
parent). Used by the CI docs job (via ``python -m tools.checks``) and by
tests/test_docs.py.

Usage: python tools/check_docs.py  (exit 1 + a listing on broken links)
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# [text](target) — excluding images' ! is unnecessary: image paths must
# resolve too
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:")
_HEADING = re.compile(r"^(#{1,6})\s+(.*)$")
# markdown decoration GitHub drops before slugifying heading text
_INLINE_LINK = re.compile(r"\[([^\]]*)\]\([^)]*\)")


def doc_files() -> list[Path]:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def _slugify(text: str) -> str:
    """GitHub's anchor slug: strip inline markup, lowercase, drop
    punctuation, spaces -> hyphens."""
    text = _INLINE_LINK.sub(r"\1", text).replace("`", "")
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_slugs(md: Path) -> set:
    """Every anchor the rendered page exposes, ``-N``-suffixed dups
    included. Fenced code blocks are skipped (a ``# comment`` inside one
    is not a heading)."""
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in md.read_text().splitlines():
        if line.lstrip().startswith(("```", "~~~")):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if not m:
            continue
        base = _slugify(m.group(2))
        n = counts.get(base, 0)
        counts[base] = n + 1
        slugs.add(base if n == 0 else f"{base}-{n}")
    return slugs


def check(paths=None) -> list[str]:
    """Return 'file: broken-target' strings for every unresolvable link
    or dangling #fragment anchor."""
    broken = []
    slug_cache: dict[Path, set] = {}

    def slugs_of(md: Path) -> set:
        if md not in slug_cache:
            slug_cache[md] = heading_slugs(md)
        return slug_cache[md]

    def label(md: Path) -> str:
        try:
            return md.relative_to(ROOT).as_posix()
        except ValueError:  # out-of-tree file (tests)
            return md.name

    for md in paths or doc_files():
        for target in _LINK.findall(md.read_text()):
            if target.startswith(_SKIP):
                continue
            rel, frag = (target.split("#", 1) + [""])[:2]
            dest = md if not rel else (md.parent / rel)
            if rel and not dest.exists():
                broken.append(f"{label(md)}: {target}")
                continue
            if frag and dest.suffix == ".md" and dest.is_file():
                if frag.lower() not in slugs_of(dest):
                    broken.append(
                        f"{label(md)}: {target} "
                        f"(no heading for anchor #{frag})"
                    )
    return broken


def main() -> int:
    files = doc_files()
    broken = check(files)
    if broken:
        print("broken doc links:")
        for b in broken:
            print(f"  {b}")
        return 1
    n = sum(len(_LINK.findall(p.read_text())) for p in files)
    print(f"docs links ok: {n} links across {len(files)} files "
          f"(targets + anchors)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
