"""Accelerator engine models (paper §II-D, §VI).

ExecutionEngines — priority-aware processor sharing with bounded effective
parallelism. One inference alone runs at rate 1; concurrent work shares an
aggregate capacity ``C_eff`` (the workload's measured concurrency headroom on
the device — small kernels leave more SM slack than dense ones). Priority
streams are allocated capacity FIRST at fine granularity (the paper's
"priority-accommodating round-robin" at kernel-block level); normal streams
split the remainder. In-flight host<->device copies steal a fraction of
capacity (paper finding 3: issuing copies interferes with execution).

CopyEngines — ``n`` DMA engines serving whole requests FCFS, non-preemptive,
priority-BLIND: the coarse request-granularity interleave that strips
priority clients of their advantage under RDMA (paper Fig. 16) and that GDR
sidesteps entirely.

Stage times are recorded QUEUE-INCLUSIVE (submission -> completion), matching
how the paper measures with CUDA events.

Sharing modes (paper §VI-C):
  multi-stream : all clients' streams share one context (default).
  multi-context: contexts time-slice the engines (only the active context
                 runs); a context switch costs capacity.
  mps          : stream-like packing; copies issue from separate processes,
                 hiding most of the copy<->exec interference.
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque


class Sim:
    """Minimal discrete-event loop."""

    def __init__(self):
        self.now = 0.0
        self._heap = []
        self._ctr = itertools.count()

    def schedule(self, delay: float, fn, *args):
        heapq.heappush(self._heap, (self.now + delay, next(self._ctr), fn, args))

    def run(self, until: float = float("inf")):
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            if t > until:
                break
            self.now = max(self.now, t)
            fn(*args)


class ExecutionEngines:
    def __init__(
        self,
        sim: Sim,
        capacity: float = 4.0,  # workload C_eff (aggregate speedup bound)
        mode: str = "multi-stream",
        max_streams: int = 0,  # 0 = one stream per client (unlimited)
        ctx_slice_s: float = 2e-3,
        ctx_switch_penalty: float = 0.85,  # multi-context capacity factor
    ):
        self.sim = sim
        self.capacity = float(capacity)
        self.mode = mode
        self.max_streams = max_streams
        self.ctx_slice_s = ctx_slice_s
        self.ctx_switch_penalty = ctx_switch_penalty
        self.interference = 0.0  # capacity stolen by in-flight copies

        self.active: dict = {}  # job -> remaining solo-seconds
        self._rates: dict = {}
        self._last = 0.0
        self._version = 0
        self._admitted = 0
        self._admit_q: deque = deque()
        # multi-context rotation
        self._contexts: set = set()
        self._active_ctx = None
        self._rotating = False

    # -- public API --------------------------------------------------------- #
    def submit(self, job, work_s: float, cb, *, preprocess_s: float = 0.0):
        job._exec_phases = [
            (n, d) for n, d in (("preprocess", preprocess_s), ("inference", work_s))
            if d > 0
        ]
        job._exec_cb = cb
        if self.max_streams and self._admitted >= self.max_streams:
            self._admit_q.append(job)
        else:
            self._admit(job)

    # -- admission ----------------------------------------------------------- #
    def _admit(self, job):
        self._admitted += 1
        self._contexts.add(job.client_id)
        if self.mode == "multi-context" and not self._rotating:
            self._rotating = True
            self._active_ctx = job.client_id
            self.sim.schedule(self.ctx_slice_s, self._rotate_ctx)
        self._next_phase(job)

    def _next_phase(self, job):
        if not job._exec_phases:
            self._admitted -= 1
            if self._admit_q:
                self._admit(self._admit_q.popleft())
            job._exec_cb()
            return
        stage, dur = job._exec_phases.pop(0)
        job._phase = stage
        job._phase_t0 = self.sim.now
        self._sync()
        self.active[job] = dur
        self._reallocate()

    # -- processor sharing --------------------------------------------------- #
    def _eff_capacity(self) -> float:
        c = self.capacity - self.interference
        if self.mode == "multi-context":
            c *= self.ctx_switch_penalty
        return max(c, 0.05)

    def _runnable(self, job) -> bool:
        if self.mode != "multi-context" or self._active_ctx is None:
            return True
        return job.client_id == self._active_ctx

    def _sync(self):
        dt = self.sim.now - self._last
        if dt > 0:
            for j, r in self._rates.items():
                if j in self.active:
                    self.active[j] = max(0.0, self.active[j] - r * dt)
        self._last = self.sim.now

    def _compute_rates(self) -> dict:
        cap = self._eff_capacity()
        rates = {j: 0.0 for j in self.active}
        for prio in (1, 0):
            jobs = [j for j in self.active if j.priority == prio and self._runnable(j)]
            if not jobs or cap <= 0:
                continue
            # equal split capped at solo rate 1
            share = cap / len(jobs)
            for j in jobs:
                rates[j] = min(1.0, share)
            cap -= sum(rates[j] for j in jobs)
            cap = max(cap, 0.0)
        return rates

    def _reallocate(self):
        self._sync()
        self._rates = self._compute_rates()
        self._version += 1
        nxt = None
        for j, rem in self.active.items():
            r = self._rates.get(j, 0.0)
            if r > 0:
                t = rem / r
                if nxt is None or t < nxt[0]:
                    nxt = (t, j)
        if nxt is not None:
            self.sim.schedule(max(nxt[0], 0.0), self._maybe_finish, self._version)

    def _maybe_finish(self, version):
        if version != self._version:
            return  # stale event
        self._sync()
        done = [j for j, rem in self.active.items() if rem <= 1e-12]
        if not done:
            self._reallocate()
            return
        for j in done:
            del self.active[j]
            self._rates.pop(j, None)
            j.record.add(j._phase, self.sim.now - j._phase_t0)
        self._reallocate()
        for j in done:
            self._next_phase(j)

    def _rotate_ctx(self):
        if not self.active and not self._admit_q:
            self._rotating = False
            self._active_ctx = None
            return
        live = sorted({j.client_id for j in self.active}) or sorted(self._contexts)
        if live:
            if self._active_ctx not in live:
                self._active_ctx = live[0]
            else:
                self._active_ctx = live[(live.index(self._active_ctx) + 1) % len(live)]
        self._reallocate()
        self.sim.schedule(self.ctx_slice_s, self._rotate_ctx)

    def set_interference(self, value: float):
        self.interference = value
        self._reallocate()


class CopyEngines:
    """H2D/D2H DMA FIFO queues with HEAD-OF-LINE blocking (paper §VI).

    CUDA apps enqueue a request's H2D *and* D2H in stream-issue order; the
    copy engines pop strictly FIFO and are non-preemptive, so a D2H whose
    stream's kernels haven't finished BLOCKS the engine — and every copy
    queued behind it, priority or not. This request-granularity interleave is
    exactly what erodes priority clients under RDMA (paper Fig. 16) and what
    GDR sidesteps.

    MPS mode: each client/process gets its own queue (engines round-robin
    across queues), so cross-client head-of-line blocking disappears — the
    paper's hypothesis for why MPS beats multi-stream under RDMA (Fig. 17).

    Recorded copy time is queue-inclusive. In-flight copies steal
    ``interference`` execution capacity each (paper finding 3).
    """

    def __init__(self, sim: Sim, n: int = 2, exec_engines=None,
                 interference: float = 0.35, per_client_queues: bool = False):
        self.sim = sim
        self.n = n
        self.exec = exec_engines
        self.interference = interference
        self.per_client = per_client_queues
        self.busy = 0
        self._queues: dict = {}  # key -> deque of items
        self._rr: deque = deque()  # round-robin order of queue keys
        self._idle_engines = n
        self._waiting: dict = {}  # job -> (engine resume) for blocked D2H

    # -- enqueue ------------------------------------------------------------- #
    def _key(self, job):
        return job.client_id if self.per_client else 0

    def _push(self, item, job):
        k = self._key(job)
        if k not in self._queues:
            self._queues[k] = deque()
            self._rr.append(k)
        self._queues[k].append(item)
        self._drain()

    def enqueue_h2d(self, job, dur: float, cb):
        job._h2d_cb = cb
        if dur <= 0:
            cb()
            return
        self._push(("h2d", job, dur, self.sim.now), job)

    def enqueue_d2h(self, job, dur: float, cb):
        """Issued at submit time (stream order); runs once job._exec_done."""
        job._d2h_cb = cb
        job._d2h_dur = dur
        job._exec_done = False
        self._push(("d2h", job, dur, self.sim.now), job)

    def notify_exec_done(self, job):
        job._exec_done = True
        job._exec_done_t = self.sim.now
        resume = self._waiting.pop(job, None)
        if resume is not None:
            resume()

    # -- engine loop ---------------------------------------------------------- #
    def _next_item(self):
        for _ in range(len(self._rr)):
            k = self._rr[0]
            self._rr.rotate(-1)
            q = self._queues.get(k)
            if q:
                return q.popleft()
        return None

    def _drain(self):
        while self._idle_engines > 0:
            item = self._next_item()
            if item is None:
                return
            self._idle_engines -= 1
            self._start(item)

    def _start(self, item):
        kind, job, dur, t0 = item
        if kind == "d2h" and not job._exec_done:
            # head-of-line block: this engine sits on the copy until the
            # stream's kernels complete
            self._waiting[job] = lambda: self._run(item)
            return
        self._run(item)

    def _run(self, item):
        kind, job, dur, t0 = item
        self.busy += 1
        self._set_interference()
        self.sim.schedule(dur, self._done, item)

    def _done(self, item):
        kind, job, dur, t0 = item
        self.busy -= 1
        self._idle_engines += 1
        self._set_interference()
        # queue-inclusive, but D2H measures from exec completion (the paper's
        # synchronous cudaMemcpy starts there) — not from stream issue time
        if kind == "d2h":
            t0 = max(t0, getattr(job, "_exec_done_t", t0))
        job.record.add("copy_in" if kind == "h2d" else "copy_out",
                       self.sim.now - t0)
        self._drain()
        (job._h2d_cb if kind == "h2d" else job._d2h_cb)()

    def _set_interference(self):
        if self.exec is not None:
            self.exec.set_interference(self.busy * self.interference)
