"""End-to-end model-serving cluster simulator (paper §III).

Deterministic discrete-event reproduction of the paper's testbed: closed-loop
clients -> (optional gateway) -> GPU server, with the transport mechanism,
copy engines, execution engines, sharing mode, stream limits and priorities
all pluggable. Service times come from calibrated workloads
(core/workloads.py) or from roofline-derived LLM serve steps.

The real-compute twin of this simulator (serving/engine.py) runs the same
pipeline with actual JAX models on CPU; this module is what sweeps the
paper's 10+ scenario grids in milliseconds.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.engines import CopyEngines, ExecutionEngines, Sim
from repro.core.profiler import ProfileStore, RequestRecord
from repro.core.transport import PAPER_A2, Transport, TransportProfile
from repro.core.workloads import Workload


@dataclasses.dataclass(eq=False)  # identity-hashable: jobs key the PS tables
class Job:
    request_id: int
    client_id: int
    priority: int
    record: RequestRecord


@dataclasses.dataclass
class ScenarioConfig:
    workload: Workload
    transport: Transport = Transport.GDR
    # proxied connection: client->gateway transport (None = direct connection)
    first_hop: Optional[Transport] = None
    n_clients: int = 1
    n_priority_clients: int = 0
    requests_per_client: int = 200
    preprocessed: bool = False  # client sends model-ready tensors
    profile: TransportProfile = PAPER_A2
    sharing: str = "multi-stream"  # multi-stream | multi-context | mps
    max_streams: int = 0  # 0 = one stream per client
    exec_capacity: int = 10  # A2: 10 execution engines
    gateway_overhead_s: float = 40e-6
    client_think_s: float = 0.0
    # service-time jitter (fraction). Real GPUs convoy: without jitter a
    # deterministic closed loop spreads work perfectly and copy queues never
    # form (paper Figs. 12-13 show they do).
    jitter: float = 0.20
    seed: int = 0


class Server:
    def __init__(self, sim: Sim, cfg: ScenarioConfig, store: ProfileStore):
        import random

        self.sim = sim
        self.cfg = cfg
        self.store = store
        self._rng = random.Random(cfg.seed)
        self.exec = ExecutionEngines(
            sim,
            capacity=cfg.workload.concurrency,
            mode=cfg.sharing if cfg.sharing != "mps" else "multi-stream",
            max_streams=cfg.max_streams,
        )
        # MPS: copies issue from separate processes -> per-process queues, no
        # cross-client head-of-line blocking, and less copy<->exec
        # interference (paper §VI-C hypothesis).
        interference = cfg.profile.copy_exec_interference
        if cfg.sharing == "mps":
            interference *= 0.4
        self.copy = CopyEngines(
            sim,
            n=cfg.profile.n_copy_engines,
            exec_engines=self.exec,
            interference=interference,
            per_client_queues=(cfg.sharing == "mps"),
        )

    def _jit(self, dur: float) -> float:
        j = self.cfg.jitter
        return dur * self._rng.uniform(1 - j, 1 + j) if j else dur

    # pipeline: [copy_in] -> preprocess -> inference -> [copy_out] -> respond.
    # For staged transports BOTH copies are enqueued up front (stream issue
    # order) — the D2H head-of-line blocks its copy engine until exec is done.
    def handle(self, job: Job, done_cb):
        cfg = self.cfg
        w = cfg.workload
        nbytes_in = w.in_bytes(cfg.preprocessed)
        t = cfg.transport
        pre = 0.0 if cfg.preprocessed else self._jit(w.t_pre_s)
        work = self._jit(w.t_inf_s)

        if not t.uses_copy_engine:
            self.exec.submit(job, work, done_cb, preprocess_s=pre)
            return

        def after_h2d():
            self.exec.submit(job, work, after_exec, preprocess_s=pre)

        def after_exec():
            self.copy.notify_exec_done(job)

        self.copy.enqueue_h2d(job, self._jit(cfg.profile.copy_time(nbytes_in)),
                              after_h2d)
        self.copy.enqueue_d2h(job, self._jit(cfg.profile.copy_time(w.out_bytes)),
                              done_cb)


class Cluster:
    """Clients (+gateway) + server wiring for one scenario."""

    def __init__(self, cfg: ScenarioConfig):
        self.cfg = cfg
        self.sim = Sim()
        self.store = ProfileStore()
        self.server = Server(self.sim, cfg, self.store)
        self._next_id = 0

    # ------------------------------------------------------------------ #
    def _wire_times(self, nbytes_in: int, nbytes_out: int):
        """(request_s, response_s, cpu_s) across the 1 or 2 hops."""
        cfg = self.cfg
        p = cfg.profile
        req = p.wire_time(cfg.transport, nbytes_in)
        rsp = p.wire_time(cfg.transport, nbytes_out)
        cpu = 0.0
        if cfg.transport is Transport.TCP:
            cpu += (nbytes_in + nbytes_out) * p.tcp_cpu_per_byte
        if cfg.first_hop is not None:  # proxied: client->gateway hop
            req += p.wire_time(cfg.first_hop, nbytes_in) + cfg.gateway_overhead_s
            rsp += p.wire_time(cfg.first_hop, nbytes_out) + cfg.gateway_overhead_s
            if cfg.first_hop is Transport.TCP:
                cpu += (nbytes_in + nbytes_out) * p.tcp_cpu_per_byte
        return req, rsp, cpu

    def _issue(self, client_id: int, priority: int, remaining: int):
        if remaining <= 0:
            return
        cfg = self.cfg
        w = cfg.workload
        rec = RequestRecord(
            request_id=self._next_id, client_id=client_id, priority=priority,
            t_issue=self.sim.now,
            bytes_in=w.in_bytes(cfg.preprocessed), bytes_out=w.out_bytes,
        )
        self._next_id += 1
        job = Job(rec.request_id, client_id, priority, rec)
        req_s, rsp_s, cpu_s = self._wire_times(rec.bytes_in, rec.bytes_out)
        rec.cpu_s = cpu_s
        rec.add("request", req_s)

        def at_server():
            self.server.handle(job, served)

        def served():
            rec.add("response", rsp_s)
            self.sim.schedule(rsp_s, completed)

        def completed():
            rec.t_done = self.sim.now
            self.store.add(rec)
            self.sim.schedule(
                cfg.client_think_s, self._issue, client_id, priority, remaining - 1
            )

        self.sim.schedule(req_s, at_server)

    def run(self) -> ProfileStore:
        cfg = self.cfg
        for c in range(cfg.n_clients):
            prio = 1 if c < cfg.n_priority_clients else 0
            # tiny deterministic stagger so clients don't tie on every event
            self.sim.schedule(c * 1e-5, self._issue, c, prio, cfg.requests_per_client)
        self.sim.run()
        return self.store


def run_scenario(cfg: ScenarioConfig) -> ProfileStore:
    return Cluster(cfg).run()


def local_reference(cfg: ScenarioConfig) -> float:
    """Local-processing latency (paper's lower bound): pre + inference only."""
    w = cfg.workload
    pre = 0.0 if cfg.preprocessed else w.t_pre_s
    return pre + w.t_inf_s
