"""Transport mechanisms and their calibrated latency models (paper §II-III).

Four mechanisms, mirroring the paper exactly:

  LOCAL : no network — client shares the accelerator (lower bound).
  TCP   : ZeroMQ-style stream over the host stack. CPU is on the data path:
          per-message syscall/stack overhead + low effective bandwidth
          (stack traversal + staging copies), then an H2D/D2H copy through
          the accelerator's copy engine.
  RDMA  : RNIC DMAs into pinned HOST memory (CPU bypassed), but the payload
          still crosses the copy engine to reach device HBM.
  GDR   : GPUDirect RDMA — RNIC DMAs straight into device HBM. No copy
          engine, no CPU.

Calibration (``PAPER_A2`` profile) reproduces the paper's testbed numbers:
ConnectX-5 25 GbE, NVIDIA A2 (2 copy engines, PCIe gen4 x8), TensorRT.
Checks (paper §IV): ResNet50 preprocessed 602 KB -> TCP is ~0.61 ms slower
than RDMA; GDR saves a further ~0.2 ms by skipping H2D/D2H; GDR adds only
0.27-0.53 ms over local processing.

``TPU_V5E`` is the hardware-adapted profile (DESIGN.md §2): the same
mechanism taxonomy mapped onto a TPU host — DCN ingress, host-staged vs
direct-HBM DMA — used by the serving examples and the LLM workloads.
"""

from __future__ import annotations

import dataclasses
import enum


class Transport(enum.Enum):
    LOCAL = "local"
    TCP = "tcp"
    RDMA = "rdma"
    GDR = "gdr"

    @property
    def uses_copy_engine(self) -> bool:
        return self in (Transport.TCP, Transport.RDMA)

    @property
    def uses_network(self) -> bool:
        return self is not Transport.LOCAL

    @property
    def handoff_copies(self) -> int:
        """Copy-engine hops on an inter-stage (prefill->decode) handoff:
        TCP pays stack staging + H2D, RDMA one pinned-host bounce, GDR
        lands straight in destination HBM (paper §II)."""
        return {Transport.TCP: 2, Transport.RDMA: 1}.get(self, 0)


@dataclasses.dataclass(frozen=True)
class TransportProfile:
    """Latency/bandwidth constants for one deployment."""

    name: str
    # network wire
    tcp_base_s: float  # per-message stack + serialization-free zmq overhead
    tcp_bw: float  # effective B/s through the host stack
    rdma_base_s: float  # RDMA_WRITE posting + WC latency
    rdma_bw: float  # RNIC line rate B/s
    gdr_base_s: float
    gdr_bw: float  # GDR effective B/s (slightly below line rate)
    # host <-> device copy engine
    copy_base_s: float  # cudaMemcpy launch + completion overhead
    copy_bw: float  # PCIe effective B/s
    n_copy_engines: int
    # fraction of an execution-engine slot consumed while a copy is in
    # flight (paper finding 3: issuing copies interferes with execution)
    copy_exec_interference: float
    # TCP keeps the CPU on the data path (paper Fig. 9)
    tcp_cpu_per_byte: float = 0.0

    def tcp_eff_bw(self, nbytes: int) -> float:
        """TCP/ZeroMQ throughput collapses for large payloads (socket-buffer
        and staging-copy pressure): ~tcp_bw below 1 MB, asymptoting to
        ~0.55*tcp_bw. RDMA/GDR stay linear — hardware offload (paper §II)."""
        mb = 1e6
        if nbytes <= mb:
            return self.tcp_bw
        return self.tcp_bw * (0.55 + 0.45 * (mb / nbytes))

    def wire_time(self, transport: Transport, nbytes: int) -> float:
        if transport is Transport.LOCAL or nbytes == 0:
            return 0.0
        if transport is Transport.TCP:
            return self.tcp_base_s + nbytes / self.tcp_eff_bw(nbytes)
        if transport is Transport.RDMA:
            return self.rdma_base_s + nbytes / self.rdma_bw
        return self.gdr_base_s + nbytes / self.gdr_bw

    def copy_time(self, nbytes: int) -> float:
        if nbytes == 0:
            return 0.0
        return self.copy_base_s + nbytes / self.copy_bw

    def handoff_time(self, transport: Transport, nbytes: int) -> float:
        """Inter-stage (prefill->decode) handoff latency: wire time plus the
        staging copy-engine hops the mechanism cannot skip. ``nbytes`` must
        already be the on-wire count (int8-requantized for the TCP/staged
        mechanism — see ``transfer.transfer_bytes``)."""
        return (self.wire_time(transport, nbytes)
                + transport.handoff_copies * self.copy_time(nbytes))


# Calibrated against the paper's reported deltas (see module docstring).
PAPER_A2 = TransportProfile(
    name="paper_a2",
    tcp_base_s=150e-6,
    tcp_bw=1.0e9,
    rdma_base_s=5e-6,
    rdma_bw=3.0e9,
    gdr_base_s=6e-6,
    gdr_bw=2.9e9,
    # A2 is a low-profile PCIe card: effective H2D/D2H ~3.75 GB/s (fits the
    # paper's Fig. 8 RDMA data-movement fractions on DeepLabV3).
    copy_base_s=30e-6,
    copy_bw=2.5e9,
    n_copy_engines=2,
    copy_exec_interference=0.35,
    tcp_cpu_per_byte=1.0 / 2.0e9,
)

# TPU v5e host adaptation: DCN NIC ~ 4x25GbE bonded, host staging via
# pinned host memory, direct-HBM DMA for the GDR analogue.
TPU_V5E = TransportProfile(
    name="tpu_v5e",
    tcp_base_s=80e-6,
    tcp_bw=5.0e9,
    rdma_base_s=4e-6,
    rdma_bw=12.0e9,
    gdr_base_s=5e-6,
    gdr_bw=11.0e9,
    copy_base_s=20e-6,
    copy_bw=32.0e9,  # host->HBM DMA
    n_copy_engines=4,
    copy_exec_interference=0.15,
    tcp_cpu_per_byte=1.0 / 4.0e9,
)

PROFILES = {p.name: p for p in (PAPER_A2, TPU_V5E)}
