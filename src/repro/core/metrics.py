"""Aggregation helpers for the profiler (Table I metrics) and the serving
SLO telemetry the cluster tier reports (TTFT/TPOT/E2E percentiles,
per-replica balance)."""

from __future__ import annotations

import math


def mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def std(xs):
    xs = list(xs)
    if len(xs) < 2:
        return 0.0
    m = mean(xs)
    return math.sqrt(sum((x - m) ** 2 for x in xs) / (len(xs) - 1))


def cov(xs):
    """Coefficient of variation sigma/mu (paper Fig. 15c)."""
    m = mean(xs)
    return std(xs) / m if m else 0.0


def percentile(xs, p: float):
    xs = sorted(xs)
    if not xs:
        return 0.0
    k = (len(xs) - 1) * p
    lo = int(math.floor(k))
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (k - lo)


def summarize(xs) -> dict:
    xs = list(xs)
    return {
        "mean": mean(xs),
        "p50": percentile(xs, 0.50),
        "p95": percentile(xs, 0.95),
        "p99": percentile(xs, 0.99),
        "std": std(xs),
        "cov": cov(xs),
        "n": len(xs),
    }


def jain_index(xs) -> float:
    """Jain's fairness index (sum x)^2 / (n * sum x^2) over per-replica
    load shares: 1.0 means perfectly balanced, 1/n means one replica took
    everything. The cluster tier reports it over per-replica busy-slot
    time and routed-request counts."""
    xs = [float(x) for x in xs]
    if not xs:
        return 1.0
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0  # nothing routed anywhere: vacuously balanced
    tot = sum(xs)
    return tot * tot / (len(xs) * sq)


def merge_record_streams(streams, offsets=None) -> list:
    """Merge per-process :class:`~repro.core.profiler.RequestRecord`
    streams into one timeline, tolerating clock skew.

    Each replica process stamps ``t_issue``/``t_done`` with its OWN
    ``time.perf_counter`` — an epoch that differs arbitrarily between
    processes (perf_counter has no defined zero). ``offsets[i]`` is
    stream i's estimated ``child_clock - reference_clock`` skew (the
    socket-handshake estimate ``ipc.ReplicaClient.clock_offset``);
    subtracting it rebases every absolute stamp onto the reference
    (parent) clock. Durations — ``stage_s``, ``cpu_s``,
    ``t_done - t_issue`` — are differences of same-clock stamps, so they
    are skew-invariant and pass through untouched; only the absolute
    placement on the merged timeline needs the offset.

    Returns ONE list sorted by rebased ``t_done`` (completion order, the
    order single-process stores accumulate in), with rebased copies —
    source records are never mutated. ``offsets=None`` means all streams
    already share the reference clock.
    """
    import dataclasses

    streams = [list(s) for s in streams]
    if offsets is None:
        offsets = [0.0] * len(streams)
    if len(offsets) != len(streams):
        raise ValueError(
            f"offsets length {len(offsets)} != streams length {len(streams)}"
        )
    merged = []
    for recs, off in zip(streams, offsets):
        for rec in recs:
            merged.append(
                rec if off == 0.0 else dataclasses.replace(
                    rec, t_issue=rec.t_issue - off, t_done=rec.t_done - off
                )
            )
    merged.sort(key=lambda r: r.t_done)
    return merged


def slo_summary(responses, *, warmup: int = 0) -> dict:
    """Warmup-aware serving SLO percentiles over Response objects.

    The first ``warmup`` responses (in completion order) are dropped
    before aggregation — they carry cold-start costs (first-touch jit
    compiles on unwarmed engines, cache population) that are not
    steady-state tail latency. Reports, each as a :func:`summarize` dict:

    * ``ttft_s``  — time to first token.
    * ``tpot_s``  — time per output token after the first,
      ``(total - ttft) / (tokens - 1)``, single-token responses excluded.
    * ``e2e_s``   — end-to-end request latency (``total_s``).
    * ``queue_s`` — the pre-admission 'queue' stage (submit -> prefill
      pick), the component load imbalance shows up in.
    * ``stages``  — one :func:`summarize` dict per charged stage name
      (queue/preprocess/transfer/inference/request/response/copy_*...),
      the paper's per-stage breakdown table straight from cluster
      telemetry — no raw-record access needed. A response missing a
      stage contributes 0.0 for it, so every stage's ``n`` matches the
      response count.
    """
    responses = list(responses)
    if warmup < 0:
        raise ValueError(f"warmup must be >= 0: {warmup}")
    rs = responses[warmup:]
    tpots = [
        (r.total_s - r.ttft_s) / (len(r.tokens) - 1)
        for r in rs if len(r.tokens) > 1
    ]
    stage_names = sorted({s for r in rs for s in r.stage_s})
    return {
        "n": len(rs),
        "warmup_dropped": min(warmup, len(responses)),
        "ttft_s": summarize(r.ttft_s for r in rs),
        "tpot_s": summarize(tpots),
        "e2e_s": summarize(r.total_s for r in rs),
        "queue_s": summarize(r.stage_s.get("queue", 0.0) for r in rs),
        "stages": {
            s: summarize(r.stage_s.get(s, 0.0) for r in rs)
            for s in stage_names
        },
    }
