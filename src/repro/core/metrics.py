"""Aggregation helpers for the profiler (Table I metrics)."""

from __future__ import annotations

import math


def mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def std(xs):
    xs = list(xs)
    if len(xs) < 2:
        return 0.0
    m = mean(xs)
    return math.sqrt(sum((x - m) ** 2 for x in xs) / (len(xs) - 1))


def cov(xs):
    """Coefficient of variation sigma/mu (paper Fig. 15c)."""
    m = mean(xs)
    return std(xs) / m if m else 0.0


def percentile(xs, p: float):
    xs = sorted(xs)
    if not xs:
        return 0.0
    k = (len(xs) - 1) * p
    lo = int(math.floor(k))
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (k - lo)


def summarize(xs) -> dict:
    xs = list(xs)
    return {
        "mean": mean(xs),
        "p50": percentile(xs, 0.50),
        "p95": percentile(xs, 0.95),
        "p99": percentile(xs, 0.99),
        "std": std(xs),
        "cov": cov(xs),
        "n": len(xs),
    }
