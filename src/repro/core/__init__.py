from repro.core.profiler import ProfileStore, RequestRecord
from repro.core.simulator import Cluster, ScenarioConfig, local_reference, run_scenario
from repro.core.transport import PAPER_A2, TPU_V5E, Transport, TransportProfile
from repro.core.workloads import TABLE_II, Workload, llm_workload

__all__ = [
    "Cluster", "ScenarioConfig", "run_scenario", "local_reference",
    "Transport", "TransportProfile", "PAPER_A2", "TPU_V5E",
    "ProfileStore", "RequestRecord", "TABLE_II", "Workload", "llm_workload",
]
