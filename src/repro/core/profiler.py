"""Fine-grained pipeline profiling (paper Table I).

Every request carries a stage-timestamped record; the store aggregates the
paper's metric set per client / per stage: total-time, request-time,
response-time, copy-time (H2D + D2H), preprocessing-time, inference-time,
CPU usage and memory usage proxies.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Optional

from repro.core.metrics import summarize

STAGES = (
    "request",  # client -> server wire (+ gateway hop)
    "copy_in",  # H2D through the copy engine (TCP/RDMA only)
    "queue",  # waiting for an execution lane
    "preprocess",
    "transfer",  # inter-stage KV handoff (disaggregated prefill -> decode)
    "inference",
    "copy_out",  # D2H
    "response",  # server -> client wire
)


@dataclasses.dataclass
class RequestRecord:
    request_id: int
    client_id: int
    priority: int = 0
    t_issue: float = 0.0
    t_done: float = 0.0
    stage_s: dict = dataclasses.field(default_factory=dict)
    cpu_s: float = 0.0  # host-CPU busy time attributable to this request
    bytes_in: int = 0
    bytes_out: int = 0
    # wall clock actually spent in the inter-stage handoff collective; when
    # the charged "transfer" stage is profile-modeled instead (host-device
    # runs), the engine swaps this measured wall out of ttft/total
    transfer_wall_s: float = 0.0

    def add(self, stage: str, dur: float):
        self.stage_s[stage] = self.stage_s.get(stage, 0.0) + dur

    @property
    def total(self) -> float:
        return self.t_done - self.t_issue

    @property
    def copy_time(self) -> float:
        return self.stage_s.get("copy_in", 0.0) + self.stage_s.get("copy_out", 0.0)

    @property
    def data_movement(self) -> float:
        """copy + request + transfer + response (the paper's 'data movement'
        fraction, plus the disaggregated inter-stage hop)."""
        return (
            self.copy_time
            + self.stage_s.get("request", 0.0)
            + self.stage_s.get("transfer", 0.0)
            + self.stage_s.get("response", 0.0)
        )

    @property
    def processing(self) -> float:
        return self.stage_s.get("preprocess", 0.0) + self.stage_s.get("inference", 0.0)


class ProfileStore:
    def __init__(self):
        self.records: list[RequestRecord] = []

    def add(self, rec: RequestRecord):
        self.records.append(rec)

    def totals(self, client_id: Optional[int] = None, priority=None):
        return [
            r.total
            for r in self.records
            if (client_id is None or r.client_id == client_id)
            and (priority is None or r.priority == priority)
        ]

    def stage_means(self, client_id: Optional[int] = None) -> dict:
        sums = defaultdict(float)
        n = 0
        for r in self.records:
            if client_id is not None and r.client_id != client_id:
                continue
            n += 1
            for s in STAGES:
                sums[s] += r.stage_s.get(s, 0.0)
        return {s: (sums[s] / n if n else 0.0) for s in STAGES}

    def breakdown_fractions(self) -> dict:
        means = self.stage_means()
        tot = summarize(self.totals())["mean"]
        return {s: (v / tot if tot else 0.0) for s, v in means.items()}

    def summary(self, **filt) -> dict:
        return summarize(self.totals(**filt))

    def processing_cov(self) -> float:
        from repro.core.metrics import cov

        return cov([r.processing for r in self.records])

    def cpu_per_request(self) -> float:
        return summarize([r.cpu_s for r in self.records])["mean"]
