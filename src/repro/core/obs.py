"""Unified metrics registry: counters, gauges, histograms + sampler.

The engines grew ad-hoc counter attributes (``prefill_padded_tokens``,
prefix hit counters, IPC byte counters, pipeline conservation counts) —
each queryable only by knowing where it lives. This module gives them
one surface: a :class:`Registry` of named instruments with
snapshot/delta semantics, wired into ``ServingCluster.telemetry()``
(each replica's engine counters are absorbed via
:meth:`Registry.ingest_counters`), plus a background :class:`Sampler`
that polls queue depth / slot occupancy into histograms while a drain
runs.

Hot-path posture: the engines keep charging their plain integer
attributes (a bare ``+=`` — no lock, nothing reprolint RL001 could see
as a sync); the registry is the *query* plane, built from those
attributes at telemetry time. Only the sampler's histograms take a lock,
and never on an engine hot path.
"""

from __future__ import annotations

import threading
import traceback
from collections import deque
from typing import Callable, Optional

from repro.core.metrics import percentile

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "Sampler"]


class Counter:
    """Monotonic accumulator (events, bytes, tokens)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n=1):
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease: {n}")
        self.value += n


class Gauge:
    """Point-in-time value (queue depth, free slots)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v):
        self.value = v


class Histogram:
    """Bounded-window distribution (sampler output).

    Keeps running count/total plus a sliding window of the last
    ``window`` observations for percentiles — snapshot percentiles are
    over that window, count/total over the full lifetime."""

    # tools/reprolint RL003 contract: touched only under `with
    # self._lock`; nothing blocks while the lock is held.
    _REPROLINT_GUARDED = ("_window", "count", "total", "vmin", "vmax")

    def __init__(self, name: str, window: int = 4096):
        self.name = name
        self._lock = threading.Lock()
        self._window: deque = deque(maxlen=window)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v):
        v = float(v)
        with self._lock:
            self._window.append(v)
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v

    def snapshot(self) -> dict:
        with self._lock:
            win = list(self._window)
            count, total = self.count, self.total
            vmin, vmax = self.vmin, self.vmax
        return {
            "count": count,
            "total": total,
            "mean": total / count if count else 0.0,
            "min": vmin if count else 0.0,
            "max": vmax if count else 0.0,
            "p50": percentile(win, 0.50),
            "p95": percentile(win, 0.95),
        }


class Registry:
    """Named instruments behind one get-or-create surface.

    ``snapshot()`` returns plain nested dicts (JSON-safe, what
    ``ServingCluster.telemetry()`` embeds); ``delta(prev, cur)`` gives
    counter increments between two snapshots."""

    # tools/reprolint RL003 contract: touched only under `with
    # self._lock`; nothing blocks while the lock is held.
    _REPROLINT_GUARDED = ("_metrics",)

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}"
                )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        return self._get(name, Histogram, window=window)

    def ingest_counters(self, mapping: dict, prefix: str = ""):
        """Absorb a plain ``{name: int}`` counter dict (the engines'
        ad-hoc attribute counters) as monotonic counters."""
        for name, value in mapping.items():
            c = self.counter(prefix + name)
            c.inc(max(int(value) - c.value, 0))

    def snapshot(self) -> dict:
        with self._lock:
            metrics = list(self._metrics.values())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in metrics:
            if isinstance(m, Counter):
                out["counters"][m.name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][m.name] = m.value
            else:
                out["histograms"][m.name] = m.snapshot()
        return out

    @staticmethod
    def delta(prev: dict, cur: dict) -> dict:
        """Counter increments between two ``snapshot()`` dicts."""
        pc = prev.get("counters", {})
        return {
            name: value - pc.get(name, 0)
            for name, value in cur.get("counters", {}).items()
        }


class Sampler:
    """Background poller: every ``interval_s``, call each source and
    observe the value into a same-named histogram in ``registry``.

    Sources are zero-arg callables (queue depth, occupancy, ...) read
    OUTSIDE any registry lock; a failing source is captured into
    ``errors`` (never swallowed, never fatal to the other sources) and
    surfaced by :meth:`stop`."""

    def __init__(self, registry: Registry,
                 sources: dict[str, Callable[[], float]],
                 interval_s: float = 0.005):
        self.registry = registry
        self.sources = dict(sources)
        self.interval_s = interval_s
        self.errors: list = []
        self.samples = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _run(self):
        try:
            while not self._stop.is_set():
                for name, fn in self.sources.items():
                    try:
                        v = fn()
                    except Exception:
                        self.errors.append(
                            f"sampler source {name!r} failed:\n"
                            f"{traceback.format_exc()}"
                        )
                        continue
                    self.registry.histogram(name).observe(v)
                    self.samples += 1
                self._stop.wait(self.interval_s)
        except BaseException:
            # capture, don't vanish: stop() re-raises for the caller
            self.errors.append(
                f"sampler thread failed:\n{traceback.format_exc()}"
            )

    def start(self) -> "Sampler":
        if self._thread is not None:
            raise RuntimeError("sampler already started")
        self._thread = threading.Thread(
            target=self._run, name="obs-sampler", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0, *, check: bool = True):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
        if check and self.errors:
            raise RuntimeError(
                "sampler captured failures:\n" + "\n".join(self.errors)
            )

    def __enter__(self) -> "Sampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(check=exc[0] is None)
