"""Serving workloads.

Two families:
  1. The paper's Table II DNNs (exact GFLOPs / input / output shapes) with
     service times calibrated to the paper's measurements on the A2 +
     TensorRT testbed — used by the figure-reproduction benchmarks.
  2. The 10 assigned LLM architectures, whose per-request service times are
     DERIVED from the dry-run roofline terms (max of compute/memory time on
     the production mesh) — this is how the paper's methodology composes
     with the rest of this framework.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    gflops: float
    in_bytes_raw: int  # client submits raw data (server preprocesses)
    in_bytes_pre: int  # client submits preprocessed tensors
    out_bytes: int
    t_pre_s: float  # GPU preprocessing time (resize + normalize)
    t_inf_s: float  # GPU inference time (single request, no contention)
    # aggregate concurrency headroom on the device: how many copies of this
    # model the GPU can effectively run before throughput saturates (small
    # kernels leave SM slack; dense ones don't). Calibrated per paper Figs
    # 11/15/16.
    concurrency: float = 3.0

    def in_bytes(self, preprocessed: bool) -> int:
        return self.in_bytes_pre if preprocessed else self.in_bytes_raw


def _img(c, h, w, fp=True):
    return c * h * w * (4 if fp else 1)


# Paper Table II. Raw images: camera-resolution uint8 JPEG-decoded frames
# (640x480x3); preprocessed: model-shape fp32 tensors.
# t_inf calibrated to the paper's reported local-processing latencies.
TABLE_II = {
    "mobilenetv3": Workload(
        "mobilenetv3", 0.06, _img(3, 480, 640, False), _img(3, 224, 224),
        1000 * 4, 0.45e-3, 0.9e-3, concurrency=10.0,
    ),
    "efficientnetb0": Workload(
        "efficientnetb0", 0.39, _img(3, 480, 640, False), _img(3, 224, 224),
        1000 * 4, 0.45e-3, 1.6e-3, concurrency=8.0,
    ),
    "resnet50": Workload(
        "resnet50", 4.1, _img(3, 480, 640, False), _img(3, 224, 224),
        1000 * 4, 0.45e-3, 2.85e-3, concurrency=1.6,
    ),
    "wideresnet101": Workload(
        "wideresnet101", 22.81, _img(3, 480, 640, False), _img(3, 224, 224),
        1000 * 4, 0.45e-3, 20.5e-3, concurrency=2.0,
    ),
    "yolov4": Workload(
        "yolov4", 128.46, _img(3, 720, 1280, False), _img(3, 416, 416),
        sum(s * s * 3 * 85 * 4 for s in (13, 26, 52)), 0.9e-3, 48e-3,
        concurrency=3.5,
    ),
    "deeplabv3": Workload(
        "deeplabv3", 178.72, _img(3, 720, 1280, False), _img(3, 520, 520),
        2 * 21 * 520 * 520 * 4, 1.1e-3, 105e-3, concurrency=1.6,
    ),
}


def llm_workload(arch: str, shape_name: str = "decode_32k",
                 results_dir: str | None = None) -> Workload:
    """Build a serving workload for an assigned arch from its dry-run roofline.

    Service time = max(compute, memory) roofline term of the serve_step on
    the single-pod mesh; ingress = one request's token + sampling params;
    egress = logits-topk. For disaggregated serving the ingress payload is
    the prefill-produced KV cache slice (the transfer the paper's GDR vs
    staged comparison acts on).
    """
    import json
    import os

    from repro.configs import get_config, get_shape

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    results_dir = results_dir or os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
    )
    path = os.path.join(results_dir, f"{arch}__{shape_name}__16x16.json")
    with open(path) as f:
        r = json.load(f)
    t_inf = max(r["t_compute"], r["t_memory"]) + r["t_collective"]
    # per-token ingress/egress for one decode step across the whole batch
    b = shape.global_batch
    return Workload(
        name=f"{arch}:{shape_name}",
        gflops=r["hlo_flops"] * r["chips"] / 1e9,
        in_bytes_raw=b * 8,  # token ids + params
        in_bytes_pre=b * 8,
        out_bytes=b * 4 * 32,  # top-k logits
        t_pre_s=0.0,
        t_inf_s=t_inf,
    )
