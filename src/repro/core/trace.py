"""Span-based tracing: timestamped pipeline hops on one merged timeline.

The profiler (:mod:`repro.core.profiler`) charges stage *durations* to
``RequestRecord.stage_s`` — enough for the paper's Table-I breakdown
means, but blind to *when* each stage ran. This module adds the missing
axis: every pipeline hop (gateway submit/response, router decision,
queue wait, prefill dispatch, KV handoff, decode windows, pipeline
threads, IPC RPC frames) emits a :class:`Span` with ``perf_counter``
start/end stamps into a process-global ring buffer, and the whole
multi-process timeline exports as Chrome trace-event JSON (loadable at
https://ui.perfetto.dev) or a text stage summary.

Design constraints, in order:

* **Hot-path safe.** ``emit`` is a guarded no-op when tracing is off
  (one attribute read), and when on it only builds a small dataclass and
  appends to a bounded deque under a short lock — no device syncs, no
  I/O, no allocation proportional to history (the ring drops oldest).
  reprolint RL001 stays clean because nothing here touches the device;
  RL003 lock discipline is declared via ``_REPROLINT_GUARDED``.
* **Cross-process mergeable.** Worker processes stamp spans with their
  OWN ``perf_counter`` epoch; spans ship over the existing RPC frames as
  primitive tuples (RL004-safe: no device state) and are rebased onto
  the parent clock by subtracting the socket-handshake
  ``clock_offset`` — the same machinery
  :func:`repro.core.metrics.merge_record_streams` uses for records.
* **Self-verifying.** :meth:`Trace.reconcile` checks every request's
  span tree against its charged ``stage_s`` (root span present, span
  walls cover each charge within epsilon) and
  :meth:`Trace.tree_problems` checks per-thread non-overlap of
  process-level spans; ``benchmarks.serving`` asserts both plus a
  < 3% tracing on/off wall-overhead budget (``BENCH_serving.json``
  ``tracing`` section).
"""

from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.core.metrics import mean, percentile

__all__ = [
    "Span", "TraceBuffer", "Trace", "tracer", "enable_tracing",
    "disable_tracing", "tracing_enabled", "spans_to_wire",
    "spans_from_wire", "validate_stamps",
]

DEFAULT_CAPACITY = 65536


@dataclass
class Span:
    """One timestamped pipeline hop.

    ``t_start``/``t_end`` are ``time.perf_counter`` stamps in the clock
    of the process named by ``process`` (after rebasing: the parent
    clock). ``request_id`` is None for process-level spans (decode
    windows, RPC frames, router decisions); request-scoped spans carry
    the id so :meth:`Trace.by_request` can build per-request trees.
    ``attrs`` holds primitive metadata only (mechanism, wire bytes,
    modeled-vs-measured charge provenance, ...).
    """

    name: str
    t_start: float
    t_end: float
    process: str = "main"
    thread: str = "main"
    request_id: Optional[int] = None
    attrs: dict = field(default_factory=dict)

    @property
    def wall(self) -> float:
        return max(self.t_end - self.t_start, 0.0)


def _span_to_wire(s: Span) -> tuple:
    return (s.name, s.t_start, s.t_end, s.process, s.thread,
            s.request_id, dict(s.attrs))


def spans_to_wire(spans) -> list:
    """Primitive-tuple wire form (RL004-safe RPC payload)."""
    return [_span_to_wire(s) for s in spans]


def spans_from_wire(wire, offset: float = 0.0,
                    process: Optional[str] = None) -> list:
    """Rehydrate wire tuples, rebasing child-clock stamps onto the
    reference clock by subtracting ``offset`` (``child - parent``, the
    :class:`~repro.serving.ipc.ReplicaClient` handshake estimate) — the
    span analogue of :func:`repro.core.metrics.merge_record_streams`.
    Durations are skew-invariant; only absolute placement moves.
    ``process`` overrides the recorded process label (e.g. "replica1").
    """
    out = []
    for (name, t0, t1, proc, thr, rid, attrs) in wire:
        out.append(Span(
            name=name, t_start=t0 - offset, t_end=t1 - offset,
            process=process if process is not None else proc,
            thread=thr, request_id=rid, attrs=dict(attrs),
        ))
    return out


class TraceBuffer:
    """Append-only ring buffer of spans, one per process.

    ``emit`` is the only hot-path entry point: a single ``enabled``
    attribute read when tracing is off. The ring (``deque(maxlen=...)``)
    bounds memory; overflow drops the OLDEST span and counts it in
    ``dropped`` so a truncated export is detectable, never silent.
    """

    # tools/reprolint RL003 contract: touched only under `with
    # self._lock`; nothing blocks while the lock is held.
    _REPROLINT_GUARDED = ("_spans", "emitted", "dropped")

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 process: str = "main"):
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=capacity)
        self.capacity = capacity
        self.process = process
        self.enabled = False
        self.emitted = 0
        self.dropped = 0

    def enable(self, process: Optional[str] = None, *, reset: bool = True):
        if process is not None:
            self.process = process
        if reset:
            self.clear()
        self.enabled = True

    def disable(self):
        self.enabled = False

    def clear(self):
        with self._lock:
            self._spans.clear()
            self.emitted = 0
            self.dropped = 0

    def emit(self, name: str, t_start: float, t_end: float, *,
             request_id: Optional[int] = None,
             thread: Optional[str] = None, **attrs):
        """Record one span (no-op unless enabled)."""
        if not self.enabled:
            return
        span = Span(
            name=name, t_start=t_start, t_end=t_end, process=self.process,
            thread=(thread if thread is not None
                    else threading.current_thread().name),
            request_id=request_id, attrs=attrs,
        )
        with self._lock:
            if len(self._spans) == self.capacity:
                self.dropped += 1
            self._spans.append(span)
            self.emitted += 1

    def snapshot(self) -> list:
        with self._lock:
            return list(self._spans)

    def drain(self) -> list:
        with self._lock:
            out = list(self._spans)
            self._spans.clear()
            return out

    def drain_wire(self) -> list:
        """Drain as primitive tuples (what worker RPC replies carry)."""
        return spans_to_wire(self.drain())

    def ingest_wire(self, wire, offset: float = 0.0,
                    process: Optional[str] = None):
        """Fold a child process's drained spans in, rebased onto this
        process's clock. Bypasses the ``enabled`` gate: the spans were
        emitted under the CHILD's enablement and must not be lost just
        because the parent's own emitters are off."""
        spans = spans_from_wire(wire, offset=offset, process=process)
        if not spans:
            return
        with self._lock:
            for s in spans:
                if len(self._spans) == self.capacity:
                    self.dropped += 1
                self._spans.append(s)
            self.emitted += len(spans)

    def stats(self) -> dict:
        with self._lock:
            return {
                "enabled": self.enabled,
                "capacity": self.capacity,
                "buffered": len(self._spans),
                "emitted": self.emitted,
                "dropped": self.dropped,
            }


_GLOBAL = TraceBuffer()


def tracer() -> TraceBuffer:
    """The process-global trace buffer (one per OS process)."""
    return _GLOBAL


def enable_tracing(process: Optional[str] = None,
                   capacity: Optional[int] = None, *,
                   reset: bool = True) -> TraceBuffer:
    if capacity is not None and capacity != _GLOBAL.capacity:
        with _GLOBAL._lock:
            _GLOBAL._spans = deque(_GLOBAL._spans, maxlen=capacity)
            _GLOBAL.capacity = capacity
    _GLOBAL.enable(process, reset=reset)
    return _GLOBAL


def disable_tracing():
    _GLOBAL.disable()


def tracing_enabled() -> bool:
    return _GLOBAL.enabled


def validate_stamps(t_arrival: float, t_first_token: float, t_done: float,
                    *, where: str = "", tol: float = 1e-9):
    """Debug-mode monotonicity check for the engine-filled Request
    stamps: ``t_arrival <= t_first_token <= t_done``. All three come
    from one ``perf_counter`` clock inside a single engine, so any
    violation means a stage clock ran backwards — in practice a bad
    cross-process rebase (wrong sign or stale ``clock_offset``).
    Raises ValueError naming the inversion."""
    ctx = f" ({where})" if where else ""
    if t_first_token and t_first_token + tol < t_arrival:
        raise ValueError(
            f"stamp inversion{ctx}: t_first_token {t_first_token:.6f} < "
            f"t_arrival {t_arrival:.6f}"
        )
    if t_done and t_first_token and t_done + tol < t_first_token:
        raise ValueError(
            f"stamp inversion{ctx}: t_done {t_done:.6f} < "
            f"t_first_token {t_first_token:.6f}"
        )
    if t_done and t_done + tol < t_arrival:
        raise ValueError(
            f"stamp inversion{ctx}: t_done {t_done:.6f} < "
            f"t_arrival {t_arrival:.6f}"
        )


class Trace:
    """Immutable view over a span list: export + self-verification."""

    def __init__(self, spans):
        self.spans = list(spans)

    @classmethod
    def from_buffer(cls, buf: Optional[TraceBuffer] = None) -> "Trace":
        return cls((buf or _GLOBAL).snapshot())

    def __len__(self) -> int:
        return len(self.spans)

    def processes(self) -> list:
        return sorted({s.process for s in self.spans})

    def by_request(self) -> dict:
        out: dict = {}
        for s in self.spans:
            if s.request_id is not None:
                out.setdefault(s.request_id, []).append(s)
        for spans in out.values():
            spans.sort(key=lambda s: (s.t_start, s.t_end))
        return out

    # ------------------------------------------------------------------ #
    # export
    # ------------------------------------------------------------------ #
    def chrome_events(self) -> dict:
        """Chrome trace-event JSON object (the ``export_chrome`` body).

        One "X" (complete) event per span — ``ts``/``dur`` in
        microseconds on the merged parent clock — plus "M" metadata
        events naming each process/thread, so Perfetto renders the
        gateway, router, replica engines and worker pipeline threads as
        labeled tracks."""
        pids: dict = {}
        tids: dict = {}
        events = []
        for proc in self.processes():
            pids[proc] = len(pids) + 1
            events.append({
                "ph": "M", "name": "process_name", "pid": pids[proc],
                "tid": 0, "args": {"name": proc},
            })
        for s in sorted(self.spans, key=lambda s: (s.t_start, s.t_end)):
            key = (s.process, s.thread)
            if key not in tids:
                tids[key] = len(tids) + 1
                events.append({
                    "ph": "M", "name": "thread_name", "pid": pids[s.process],
                    "tid": tids[key], "args": {"name": s.thread},
                })
            args = dict(s.attrs)
            if s.request_id is not None:
                args["request_id"] = s.request_id
            events.append({
                "ph": "X", "name": s.name, "pid": pids[s.process],
                "tid": tids[key], "ts": s.t_start * 1e6,
                "dur": s.wall * 1e6, "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path) -> dict:
        """Write Chrome trace-event JSON to ``path`` (load the file at
        https://ui.perfetto.dev or chrome://tracing). Returns the
        exported object."""
        obj = self.chrome_events()
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj

    def stage_summary(self) -> str:
        """Text flamegraph-style per-span-name rollup: count, total
        wall, mean, p95 — sorted by total wall descending."""
        groups: dict = {}
        for s in self.spans:
            groups.setdefault(s.name, []).append(s.wall)
        rows = sorted(
            ((name, walls) for name, walls in groups.items()),
            key=lambda kv: -sum(kv[1]),
        )
        width = max((len(n) for n, _ in rows), default=4)
        lines = [f"{'span':<{width}}  {'count':>6}  {'total_ms':>9}  "
                 f"{'mean_ms':>8}  {'p95_ms':>8}"]
        for name, walls in rows:
            lines.append(
                f"{name:<{width}}  {len(walls):>6}  "
                f"{sum(walls) * 1e3:>9.3f}  {mean(walls) * 1e3:>8.3f}  "
                f"{percentile(walls, 0.95) * 1e3:>8.3f}"
            )
        return "\n".join(lines)

    # ------------------------------------------------------------------ #
    # self-verification
    # ------------------------------------------------------------------ #
    def tree_problems(self, eps: float = 2e-3) -> list:
        """Structural span-tree checks; returns problem strings.

        * Per request: exactly one root ``request`` span, every other
          span of that request inside the root interval (± eps).
        * Per (process, thread, engine tag): process-level spans
          (request_id None) must not overlap — each thread's timeline is
          sequential, so overlap means a clock went backwards or an
          interval was mis-stamped. Request-scoped spans are exempt: a
          batched admission legitimately gives co-admitted requests
          identical prefill intervals.
        """
        problems = []
        for rid, spans in self.by_request().items():
            roots = [s for s in spans if s.name == "request"]
            if len(roots) != 1:
                problems.append(
                    f"request {rid}: {len(roots)} root 'request' spans "
                    f"(want exactly 1)"
                )
                continue
            root = roots[0]
            for s in spans:
                if s is root:
                    continue
                if (s.t_start < root.t_start - eps
                        or s.t_end > root.t_end + eps):
                    problems.append(
                        f"request {rid}: span '{s.name}' "
                        f"[{s.t_start:.6f}, {s.t_end:.6f}] outside root "
                        f"[{root.t_start:.6f}, {root.t_end:.6f}]"
                    )
        lanes: dict = {}
        for s in self.spans:
            if s.request_id is not None:
                continue
            key = (s.process, s.thread, s.attrs.get("tag", ""))
            lanes.setdefault(key, []).append(s)
        for key, spans in lanes.items():
            spans.sort(key=lambda s: (s.t_start, s.t_end))
            for a, b in zip(spans, spans[1:]):
                if b.t_start < a.t_end - eps:
                    problems.append(
                        f"lane {key}: '{b.name}' starts {a.t_end - b.t_start:.6f}s "
                        f"before '{a.name}' ends"
                    )
        return problems

    def reconcile(self, records, eps: float = 2e-3) -> list:
        """Check span trees against charged ``stage_s``; returns problem
        strings (empty = reconciled).

        For every record whose request has spans: the request's total
        span wall (root included) must cover EACH charged stage within
        ``eps`` — measured stages (queue/preprocess/inference) happen
        inside the root interval by construction, and modeled charges
        (request/response/copy, profile-modeled transfer) are folded
        into ``t_done`` at finish, so the root wall bounds them too. A
        charge exceeding every span the request ever emitted means the
        trace lost a hop or an interval was mis-stamped."""
        by_req = self.by_request()
        problems = []
        n_checked = 0
        for rec in records:
            spans = by_req.get(rec.request_id)
            if spans is None:
                continue
            n_checked += 1
            total_wall = sum(s.wall for s in spans)
            for stage, charge in rec.stage_s.items():
                if total_wall + eps < charge:
                    problems.append(
                        f"request {rec.request_id}: stage '{stage}' charge "
                        f"{charge:.6f}s exceeds total span wall "
                        f"{total_wall:.6f}s"
                    )
        if n_checked == 0:
            problems.append("no record had any spans to reconcile against")
        return problems + self.tree_problems(eps=eps)
