"""JAX ingress / KV-cache transfer — the paper's transport taxonomy expressed
as real collectives on the production mesh (DESIGN.md §2).

In disaggregated serving the prefill pod produces a KV cache that must land
in the decode pod's HBM. The three mechanisms:

  DIRECT_HBM (GDR analogue)   : one collective_permute across the "pod" axis
                                — NIC-to-HBM, zero staging copies.
  DIRECT_DMA (RDMA analogue)  : permute + an explicit staging round-trip
                                buffer copy on the destination (host-pinned
                                bounce modeled as an extra copy pair).
  HOST_STAGED (TCP analogue)  : permute of an int8-requantized payload via a
                                host-layout buffer: dst pays decode + two
                                copies (stack staging + H2D). Each SOURCE pod
                                quantizes with its own scale, and the scales
                                ppermute alongside the int8 payload. Integer
                                leaves (slot metadata, token ids) cross at
                                full width, unquantized.

The multi-pod dry-run lowers kv_transfer to prove the pod-axis collective
compiles; `transfer_bytes()` feeds the §Roofline collective term, and the
simulator's profile constants time the same byte counts. The disaggregated
serving tier (serving/disagg.py) runs the same collective per admission and
charges `TransportProfile.handoff_time` on the counted bytes.

Under per-pod stage placement the serving tier lays the pod-tiled payload
out sharded along the 'pod' axis — the live bytes committed to the
prefill slice, zeros on the decode slice — so the ppermute here is the
ONLY hop that crosses the two stages' compute boundary (see
serving/disagg.py and docs/architecture.md). `pod_tile`/`pod_take`
construct and unpack that [npods, ...] layout.
"""

from __future__ import annotations

import enum

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.transport import Transport

try:  # jax >= 0.4.44 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map


class TransferMode(enum.Enum):
    DIRECT_HBM = "direct_hbm"  # GDR
    DIRECT_DMA = "direct_dma"  # RDMA
    HOST_STAGED = "host_staged"  # TCP


# Inter-stage mechanism -> the transport whose calibrated constants time it.
MODE_TRANSPORT = {
    TransferMode.DIRECT_HBM: Transport.GDR,
    TransferMode.DIRECT_DMA: Transport.RDMA,
    TransferMode.HOST_STAGED: Transport.TCP,
}


def _quantizes(dtype) -> bool:
    """HOST_STAGED requantizes float payloads to int8; everything else
    (slot metadata, token ids) crosses at full width."""
    return jnp.issubdtype(jnp.dtype(dtype), jnp.floating)


def wire_itemsize(dtype, mode: TransferMode) -> int:
    """Bytes per element a leaf of ``dtype`` is actually permuted at."""
    if mode is TransferMode.HOST_STAGED and _quantizes(dtype):
        return 1  # int8 payload; the per-pod fp32 scale is counted separately
    return jnp.dtype(dtype).itemsize


def _pod_scales(x):
    """Per-SOURCE-pod int8 scales for a pod-tiled leaf [npods, ...].

    Each pod quantizes its own shard only — a scale taken over the globally
    tiled leaf would fold the destination pod's data into the quantization
    step and blow up the error whenever magnitudes differ across pods.
    """
    axes = tuple(range(1, x.ndim))
    return jnp.maximum(jnp.max(jnp.abs(x), axis=axes), 1e-6) / 127.0


def _permute_leaf(x, mesh, perm):
    """collective_permute along the 'pod' axis for one cache leaf."""

    def body(x_l):
        return jax.lax.ppermute(x_l, "pod", perm)

    spec = P(*(("pod",) + (None,) * (x.ndim - 1)))
    return _shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def kv_transfer(caches, mesh, *, mode: TransferMode = TransferMode.DIRECT_HBM,
                perm=None):
    """Move a prefill-pod KV cache tree to the decode pod.

    caches: pytree whose leaves carry a leading pod-sharded dim (we tile the
    tree leaves with a [npods, ...] leading axis in the launcher — see
    :func:`pod_tile`). Integer leaves may ride along as per-request slot
    metadata; they cross unquantized under every mode. perm: [(src, dst)]
    pod pairs; default ring 0->1, 1->0.

    The wire cost of what this permutes is exactly
    :func:`payload_wire_bytes` of the untiled payload — the reconciliation
    invariant the serving tier's ``handoff_wire_bytes`` counter is tested
    against.
    """
    npods = mesh.shape["pod"]
    perm = perm or [(i, (i + 1) % npods) for i in range(npods)]

    if mode is TransferMode.DIRECT_HBM:
        return jax.tree.map(lambda x: _permute_leaf(x, mesh, perm), caches)

    if mode is TransferMode.DIRECT_DMA:
        # staging bounce on the destination: permute, then a copy through a
        # bounce buffer (optimization barrier keeps XLA from eliding it)
        def leaf(x):
            y = _permute_leaf(x, mesh, perm)
            bounce = jax.lax.optimization_barrier(y + 0)
            return jax.lax.optimization_barrier(bounce * 1)

        return jax.tree.map(leaf, caches)

    # HOST_STAGED: requantize to int8 (host-format payload) with one scale
    # per source pod, permute payload + scales, then dequantize + two
    # staging copies on the destination.
    def staged(x):
        if not _quantizes(x.dtype):
            return _permute_leaf(x, mesh, perm)
        scale = _pod_scales(x)  # [npods]
        bshape = scale.shape + (1,) * (x.ndim - 1)
        q = jnp.clip(jnp.round(x / scale.reshape(bshape)), -127, 127)
        qq = _permute_leaf(q.astype(jnp.int8), mesh, perm)
        ss = _permute_leaf(scale.astype(jnp.float32), mesh, perm)
        bounce = jax.lax.optimization_barrier(qq)  # stack staging + H2D
        return (bounce.astype(jnp.float32) * ss.reshape(bshape)).astype(x.dtype)

    return jax.tree.map(staged, caches)


def pod_tile(tree, npods: int, src: int):
    """Tile a payload for the pod axis: [npods, ...] leaves carrying the real
    payload in pod ``src``'s slot and zeros elsewhere."""

    def tile(x):
        return jnp.zeros((npods,) + x.shape, x.dtype).at[src].set(x)

    return jax.tree.map(tile, tree)


def pod_take(tree, pod: int):
    """Extract pod ``pod``'s slice from a pod-tiled tree."""
    return jax.tree.map(lambda x: x[pod], tree)


def transfer_bytes(caches, mode: TransferMode) -> int:
    """Wire bytes per pod for the §Roofline collective term.

    Counts the itemsize each leaf is ACTUALLY permuted at: HOST_STAGED moves
    float leaves as int8 plus a per-pod fp32 scale, but integer leaves
    (metadata, token ids) cross at full width under every mode.
    """
    total = 0
    for leaf in jax.tree.leaves(caches):
        n = leaf.size // leaf.shape[0] if leaf.shape else leaf.size
        total += n * wire_itemsize(leaf.dtype, mode)
        if mode is TransferMode.HOST_STAGED and _quantizes(leaf.dtype):
            total += 4  # the ppermuted per-pod fp32 scale
    return total


def payload_wire_bytes(tree, mode: TransferMode) -> int:
    """``transfer_bytes`` for an UNTILED payload: the bytes one pod puts on
    the wire when ``tree`` is pod-tiled and permuted."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += leaf.size * wire_itemsize(leaf.dtype, mode)
        if mode is TransferMode.HOST_STAGED and _quantizes(leaf.dtype):
            total += 4
    return total
