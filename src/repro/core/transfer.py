"""JAX ingress / KV-cache transfer — the paper's transport taxonomy expressed
as real collectives on the production mesh (DESIGN.md §2).

In disaggregated serving the prefill pod produces a KV cache that must land
in the decode pod's HBM. The three mechanisms:

  DIRECT_HBM (GDR analogue)   : one collective_permute across the "pod" axis
                                — NIC-to-HBM, zero staging copies.
  DIRECT_DMA (RDMA analogue)  : permute + an explicit staging round-trip
                                buffer copy on the destination (host-pinned
                                bounce modeled as an extra copy pair).
  HOST_STAGED (TCP analogue)  : permute of an int8-requantized payload via a
                                host-layout buffer: dst pays decode + two
                                copies (stack staging + H2D).

The multi-pod dry-run lowers kv_transfer to prove the pod-axis collective
compiles; `transfer_bytes()` feeds the §Roofline collective term, and the
simulator's profile constants time the same byte counts.
"""

from __future__ import annotations

import enum
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class TransferMode(enum.Enum):
    DIRECT_HBM = "direct_hbm"  # GDR
    DIRECT_DMA = "direct_dma"  # RDMA
    HOST_STAGED = "host_staged"  # TCP


def _permute_leaf(x, mesh, perm):
    """collective_permute along the 'pod' axis for one cache leaf."""
    npods = mesh.shape["pod"]

    def body(x_l):
        return jax.lax.ppermute(x_l, "pod", perm)

    spec = P(*(("pod",) + (None,) * (x.ndim - 1)))
    return jax.shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)(x)


def kv_transfer(caches, mesh, *, mode: TransferMode = TransferMode.DIRECT_HBM,
                perm=None):
    """Move a prefill-pod KV cache tree to the decode pod.

    caches: pytree whose leaves carry a leading pod-sharded dim (we tile the
    tree leaves with a [npods, ...] leading axis in the launcher). perm:
    [(src, dst)] pod pairs; default ring 0->1, 1->0.
    """
    npods = mesh.shape["pod"]
    perm = perm or [(i, (i + 1) % npods) for i in range(npods)]

    if mode is TransferMode.DIRECT_HBM:
        return jax.tree.map(lambda x: _permute_leaf(x, mesh, perm), caches)

    if mode is TransferMode.DIRECT_DMA:
        # staging bounce on the destination: permute, then a copy through a
        # bounce buffer (optimization barrier keeps XLA from eliding it)
        def leaf(x):
            y = _permute_leaf(x, mesh, perm)
            bounce = jax.lax.optimization_barrier(y + 0)
            return jax.lax.optimization_barrier(bounce * 1)

        return jax.tree.map(leaf, caches)

    # HOST_STAGED: requantize to int8 (host-format payload), permute, then
    # dequantize + two staging copies on the destination.
    def staged(x):
        if x.dtype in (jnp.int32, jnp.int8):
            return _permute_leaf(x, mesh, perm)
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-6) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        qq = _permute_leaf(q, mesh, perm)
        s = jax.lax.psum(  # broadcast the scale (tiny)
            scale / mesh.shape["pod"], ()
        ) if False else scale
        bounce = jax.lax.optimization_barrier(qq)
        return (bounce.astype(x.dtype) * s).astype(x.dtype)

    return jax.tree.map(staged, caches)


def transfer_bytes(caches, mode: TransferMode) -> int:
    """Wire bytes per pod for the §Roofline collective term."""
    total = 0
    for leaf in jax.tree.leaves(caches):
        n = leaf.size // leaf.shape[0] if leaf.shape else leaf.size
        itemsize = 1 if mode is TransferMode.HOST_STAGED else leaf.dtype.itemsize
        total += n * itemsize
    return total
