"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

``cost_analysis`` on an SPMD-partitioned executable reports PER-DEVICE
flops/bytes (verified empirically), so no further division by chip count is
needed. collective_bytes is parsed from the compiled HLO text: we sum the
wire bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, using standard ring-algorithm byte counts.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|[su]\d+|bf16|f16|f32|f64|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind wire bytes (per device), ring-algorithm model:

      all-gather:        result*(n-1)/n   ~ result bytes sent+recv per dev
      all-reduce:        2*size*(n-1)/n   ~ 2x operand bytes
      reduce-scatter:    input*(n-1)/n    ~ input bytes
      all-to-all:        size*(n-1)/n     ~ size bytes
      collective-permute: size            (point to point)

    We use the simple upper-bound factors (dropping (n-1)/n) for stability;
    what matters for the roofline comparison is relative magnitude.
    """
    out: dict = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        result_types, kind = m.group(1), m.group(2)
        # -done ops repeat the -start shape; count each pair once
        if "-done(" in line:
            continue
        rb = _shape_bytes(result_types)
        if kind == "all-reduce":
            wire = 2 * rb
        elif kind == "reduce-scatter":
            # result is the scattered shard; input ~ result * group size
            wire = rb  # conservative: shard in+out
        else:
            wire = rb
        out[kind] = out.get(kind, 0) + wire
        out.setdefault(f"{kind}_count", 0)
        out[f"{kind}_count"] += 1
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    step: str
    chips: int
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    coll_bytes: float  # per device
    coll_breakdown: dict
    model_flops: float  # 6*N*D useful flops, global
    peak_bytes_per_device: int
    arg_bytes_per_device: int

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_fraction=self.useful_flops_fraction,
        )
        return d


def model_flops(cfg, shape) -> float:
    """6*N*D for training; 2*N*D per generated/processed token for serving."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def analyze(compiled, hlo_text, *, cfg, shape, mesh_name, step, chips) -> Roofline:
    ca = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = collective_bytes(hlo_text)
    coll_total = sum(v for k, v in coll.items() if not k.endswith("_count"))
    peak = (
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes
    )
    return Roofline(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        step=step,
        chips=chips,
        hlo_flops=float(ca.get("flops", 0.0)),
        hlo_bytes=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=float(coll_total),
        coll_breakdown=coll,
        model_flops=model_flops(cfg, shape),
        peak_bytes_per_device=int(peak),
        arg_bytes_per_device=int(mem.argument_size_in_bytes),
    )


def format_row(r: Roofline) -> str:
    return (
        f"{r.arch:24s} {r.shape:12s} {r.mesh:9s} {r.step:8s} "
        f"t_comp={r.t_compute*1e3:9.3f}ms t_mem={r.t_memory*1e3:9.3f}ms "
        f"t_coll={r.t_collective*1e3:9.3f}ms bound={r.bottleneck:10s} "
        f"useful={r.useful_flops_fraction*100:5.1f}% "
        f"peak_dev={r.peak_bytes_per_device/2**30:6.2f}GiB"
    )


def save(r: Roofline, path):
    with open(path, "w") as f:
        json.dump(r.to_dict(), f, indent=1, default=float)
