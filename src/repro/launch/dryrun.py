import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

This proves the distribution config is coherent without real hardware:
``.lower().compile()`` with ShapeDtypeStruct stand-ins allocates nothing but
runs the full GSPMD partitioner, so sharding mismatches, non-divisible
dimensions, OOM-at-compile and unsupported collectives all surface here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # single pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multipod # 512 chips
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCHITECTURES, SHAPES, get_config, get_shape
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.sharding.partition import ShardCtx, make_rules
from repro.training.optimizer import adamw_init_specs
from repro.training.steps import make_prefill_step, make_serve_step, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

# long_500k policy (DESIGN.md §4): SSM/hybrid run natively; dense/moe/vlm run
# the sliding-window decode variant; enc-dec audio skips.
LONG_WINDOW = 16_384


def reduced_depth_cfg(cfg, k: int):
    """Variant with every scanned layer-group at count=k (cost measurement).

    XLA's cost_analysis counts a scan body ONCE regardless of trip count, so
    the dry-run compiles unrolled k=1 and k=2 variants; their difference is
    the exact per-superblock cost, which we extrapolate to the real depth.
    """
    from repro.models.transformer import layer_groups

    groups = layer_groups(cfg)
    n = sum(len(g.sigs) * (k if g.count > 1 else g.count) for g in groups)
    changes = {"n_layers": n}
    if cfg.encoder_layers > 1:
        changes["encoder_layers"] = k
    return dataclasses.replace(cfg, **changes)


def scan_delta(cfg) -> int:
    """(count - 1) shared by all scanned groups (asserted equal)."""
    from repro.models.transformer import layer_groups

    deltas = {g.count - 1 for g in layer_groups(cfg) if g.count > 1}
    if cfg.encoder_layers > 1:
        deltas.add(cfg.encoder_layers - 1)
    assert len(deltas) <= 1, f"unequal scanned group counts: {deltas}"
    return deltas.pop() if deltas else 0


def arch_for_shape(cfg, shape):
    """Returns (config, skip_reason)."""
    if shape.name != "long_500k":
        return cfg, None
    if cfg.is_encdec:
        return None, "enc-dec: 500k-token decode target is meaningless (DESIGN.md §4)"
    if cfg.family in ("ssm", "hybrid"):
        return cfg, None  # sub-quadratic natively
    # dense/moe/vlm: sliding-window variant
    return dataclasses.replace(cfg, sliding_window=LONG_WINDOW), None


def shardings_for(model, ctx: ShardCtx, shape):
    mesh = ctx.mesh
    ns = lambda tree: jax.tree.map(lambda p: NamedSharding(mesh, p), tree)
    param_ps = ns(model.param_pspecs(ctx.rules))
    batch_axes = ctx.rules.get("batch")

    def data_spec(ndim, batch_dim=0):
        from jax.sharding import PartitionSpec as P

        parts = [None] * ndim
        parts[batch_dim] = batch_axes
        return NamedSharding(mesh, P(*parts))

    return param_ps, data_spec


def build_case(arch: str, shape_name: str, *, multi_pod: bool, mesh=None,
               cfg_override=None, unroll: bool = False, rule_overrides=None,
               remat_policy: str = "full"):
    """Returns (lowered, model, cfg, shape, ctx, step_name) or (None, reason)."""
    cfg0 = cfg_override if cfg_override is not None else get_config(arch)
    shape = get_shape(shape_name)
    cfg, skip = arch_for_shape(cfg0, shape)
    if cfg is None:
        return None, skip
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh, shape, rule_overrides)
    ctx = ShardCtx(mesh=mesh, rules=rules)
    model = Model(cfg, remat=(shape.kind == "train"), unroll=unroll,
                  remat_policy=remat_policy)

    param_ps, data_spec = shardings_for(model, ctx, shape)
    pspecs = model.param_specs()
    in_specs = model.input_specs(shape)

    from jax.sharding import PartitionSpec as P

    scalar_ps = NamedSharding(mesh, P())

    with mesh:
        if shape.kind == "train":
            step = make_train_step(model, shard_ctx=ctx)
            opt_specs = adamw_init_specs(pspecs)
            opt_ps = type(opt_specs)(
                step=scalar_ps,
                m=jax.tree.map(lambda p: p, param_ps),
                v=jax.tree.map(lambda p: p, param_ps),
            )
            batch_ps = jax.tree.map(lambda s: data_spec(len(s.shape)), in_specs)
            # explicit out_shardings so donated params/opt actually alias
            metrics_ps = {"loss": scalar_ps, "grad_norm": scalar_ps}
            fn = jax.jit(
                step,
                in_shardings=(param_ps, opt_ps, batch_ps),
                out_shardings=(param_ps, opt_ps, metrics_ps),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(pspecs, opt_specs, in_specs)
            return (lowered, model, cfg, shape, ctx, "train"), None

        if shape.kind == "prefill":
            step = make_prefill_step(model, shard_ctx=ctx)
            batch_ps = jax.tree.map(lambda s: data_spec(len(s.shape)), in_specs)
            fn = jax.jit(step, in_shardings=(param_ps, batch_ps))
            lowered = fn.lower(pspecs, in_specs)
            return (lowered, model, cfg, shape, ctx, "prefill"), None

        # decode
        step = make_serve_step(model, shard_ctx=ctx)
        cache_ps = jax.tree.map(
            lambda p: NamedSharding(mesh, p), model.cache_pspecs(ctx.rules)
        )
        tok_ps = data_spec(2)
        len_ps = data_spec(1)
        vocab_ax = ctx.rules.get("vocab")
        logits_ps = NamedSharding(mesh, P(ctx.rules.get("batch"), vocab_ax))
        fn = jax.jit(
            step,
            in_shardings=(param_ps, cache_ps, tok_ps, len_ps),
            out_shardings=(logits_ps, cache_ps, len_ps),
            donate_argnums=(1,),
        )
        lowered = fn.lower(
            pspecs, in_specs["caches"], in_specs["tokens"], in_specs["lengths"]
        )
        return (lowered, model, cfg, shape, ctx, "serve"), None


def _cost_triple(compiled):
    """(flops, bytes, collective_bytes) from one compiled executable."""
    ca = compiled.cost_analysis()
    coll = rl.collective_bytes(compiled.as_text())
    total = sum(v for k, v in coll.items() if not k.endswith("_count"))
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)), float(total), coll


def run_case(arch, shape_name, *, multi_pod, save=True, verbose=True, mesh=None,
             rule_overrides=None, tag="", correct_scan: bool = True):
    """Full-depth compile (validation + memory) plus k=1/k=2 unrolled variant
    compiles whose difference corrects XLA's scan-body-counted-once costs."""
    mesh_name = "2x16x16" if multi_pod else "16x16"
    built, skip = build_case(
        arch, shape_name, multi_pod=multi_pod, mesh=mesh,
        rule_overrides=rule_overrides,
    )
    if built is None:
        if verbose:
            print(f"SKIP  {arch:24s} {shape_name:12s} {mesh_name:9s} — {skip}")
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name, "skip": skip}
    lowered, model, cfg, shape, ctx, step_name = built
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    hlo = compiled.as_text()
    chips = 512 if multi_pod else 256
    r = rl.analyze(
        compiled, hlo, cfg=cfg, shape=shape, mesh_name=mesh_name,
        step=step_name, chips=chips,
    )

    # --- scan-count correction via unrolled depth variants ----------------- #
    delta = scan_delta(cfg) if correct_scan else 0
    if delta > 0:
        cfg0 = get_config(arch)
        variants = []
        for k in (1, 2):
            b, _ = build_case(
                arch, shape_name, multi_pod=multi_pod, mesh=ctx.mesh,
                cfg_override=reduced_depth_cfg(cfg0, k), unroll=True,
                rule_overrides=rule_overrides,
            )
            variants.append(_cost_triple(b[0].compile()))
        (fa, ba, ca_, cla), (fb, bb, cb, clb) = variants
        r.hlo_flops = fa + (fb - fa) * delta
        r.hlo_bytes = ba + (bb - ba) * delta
        r.coll_bytes = ca_ + (cb - ca_) * delta
        r.coll_breakdown = {
            k: max(0, cla.get(k, 0) + (clb.get(k, 0) - cla.get(k, 0)) * delta)
            for k in set(cla) | set(clb)
        }

    if verbose:
        print(f"OK    {rl.format_row(r)}  (compile {t_compile:.1f}s)")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        out = os.path.join(
            RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}{tag}.json"
        )
        rl.save(r, out)
    return r.to_dict()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="skip the scan-correction variant compiles "
                         "(compile-success proof only)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHITECTURES)
    if not args.arch:  # heaviest GSPMD case last so partial runs cover more
        archs = [a for a in archs if a != "deepseek-v2-236b"] + ["deepseek-v2-236b"]
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not args.all and not args.arch:
        ap.error("pass --arch/--shape or --all")

    failures = []
    for a in archs:
        for s in shapes:
            try:
                run_case(a, s, multi_pod=args.multipod, save=not args.no_save,
                         correct_scan=not args.fast)
            except Exception as e:
                failures.append((a, s, repr(e)))
                print(f"FAIL  {a:24s} {s:12s} — {type(e).__name__}: {e}")
                traceback.print_exc(limit=4)
    if failures:
        print(f"\n{len(failures)} FAILURES")
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
