"""Render the §Roofline table in EXPERIMENTS.md from experiments/dryrun/*.json."""

from __future__ import annotations

import glob
import json
import os

DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load(mesh: str = "16x16"):
    rows = []
    for path in glob.glob(os.path.join(DIR, f"*__{mesh}*.json")):
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9)))
    return rows


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def roofline_table(mesh: str = "16x16") -> str:
    rows = load(mesh)
    out = [
        "| arch | shape | step | t_comp (ms) | t_mem (ms) | t_coll (ms) | bottleneck | useful flops | peak GiB/dev |",
        "|---|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in rows:
        if "skip" in r:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | {fmt_ms(r['t_compute'])} "
            f"| {fmt_ms(r['t_memory'])} | {fmt_ms(r['t_collective'])} "
            f"| {r['bottleneck']} | {r['useful_flops_fraction']*100:.1f}% "
            f"| {r['peak_bytes_per_device']/2**30:.1f} |"
        )
    return "\n".join(out)


def dryrun_table(mesh: str = "16x16") -> str:
    rows = load(mesh)
    out = [
        "| arch | shape | step | HLO GFLOP/dev | HLO GB/dev | coll GB/dev | args GiB/dev | collective mix |",
        "|---|---|---|---:|---:|---:|---:|---|",
    ]
    for r in rows:
        if "skip" in r:
            continue
        mix = ", ".join(
            f"{k.split('_')[0] if k.endswith('count') else k}:{int(v)}"
            for k, v in sorted(r.get("coll_breakdown", {}).items())
            if k.endswith("_count")
        ) or "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} | {r['hlo_flops']/1e9:.1f} "
            f"| {r['hlo_bytes']/1e9:.1f} | {r['coll_bytes']/1e9:.2f} "
            f"| {r['arg_bytes_per_device']/2**30:.2f} | {mix} |"
        )
    skips = [r for r in rows if "skip" in r]
    for r in skips:
        out.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - | - | {r['skip']} |")
    return "\n".join(out)


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print("## Roofline\n")
    print(roofline_table(mesh))
    print("\n## Dry-run\n")
    print(dryrun_table(mesh))
