"""Distributed training launcher.

On the production mesh this drives the same train_step the dry-run compiles;
on this CPU container use --reduced for a runnable demonstration.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced --steps 50
"""

import argparse

import jax

from repro.configs import get_config, get_shape
from repro.models import Model
from repro.training import AdamWConfig, DataConfig, TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config on local devices (CPU demo)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots", "none"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        batch, seq = args.batch, args.seq
        shard_ctx = None
    else:
        from repro.launch.mesh import make_production_mesh
        from repro.sharding.partition import make_ctx

        shape = get_shape(args.shape)
        mesh = make_production_mesh()
        shard_ctx = make_ctx(cfg, mesh, shape)
        batch, seq = shape.global_batch, shape.seq_len

    model = Model(cfg, remat=True, remat_policy=args.remat_policy)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch={batch} seq={seq} on {len(jax.devices())} device(s)")
    train(
        model,
        DataConfig(vocab_size=cfg.vocab_size, seq_len=seq, batch_size=batch),
        TrainConfig(steps=args.steps, log_every=max(args.steps // 10, 1),
                    ckpt_every=args.steps // 2 if args.ckpt_dir else 0,
                    ckpt_dir=args.ckpt_dir or "checkpoints"),
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps),
        shard_ctx=shard_ctx,
    )


if __name__ == "__main__":
    main()
