"""Production meshes. v5e pod = 16x16 = 256 chips; multi-pod = 2 pods.

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax call).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Single-device mesh with the same axis names (CPU tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=(AxisType.Auto,) * 2)
