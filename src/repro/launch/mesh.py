"""Production meshes. v5e pod = 16x16 = 256 chips; multi-pod = 2 pods.

Every mesh constructor here is a FUNCTION so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before the first
jax call). Four mesh families:

* :func:`make_production_mesh` — the full TPU mesh the dry-run/roofline
  lower against ('pod' x 'data' x 'model' when multi-pod).
* :func:`make_local_mesh` — single-device stand-in with the same axis
  names (CPU tests/examples).
* :func:`make_serving_pod_mesh` — the 1-D ('pod',) mesh the
  disaggregated serving tier runs on: prefill and decode stages sit on
  opposite ends of this axis, the KV handoff collective permutes across
  it, and ``serving.disagg.PodPlacement`` carves per-stage compute
  slices out of it (via ``sharding.partition.pod_slice_mesh``).
* :func:`make_cluster_mesh` — the 1-D ('pod',) mesh a multi-replica
  serving cluster carves into per-replica slices
  (``serving.cluster.ServingCluster``): replica i owns pods
  [i*ppr, (i+1)*ppr) and commits its engine's params/state there, so
  replicas are genuinely independent failure/queueing domains on a
  multi-device backend.
"""

from __future__ import annotations

import jax
import numpy as np

try:  # jax >= 0.5 explicit-sharding API; older jax has no AxisType
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh with the same axis names (CPU tests/examples)."""
    return _mesh((1, 1), ("data", "model"))


def make_serving_pod_mesh(npods=None):
    """('pod',)-axis serving mesh over the first ``npods`` devices.

    Defaults to 2 pods when the backend has at least two devices, else the
    1-pod degenerate mesh (every collective becomes an identity permute,
    so the full disaggregated tier still runs in single-device tests).
    Re-exported as ``repro.serving.make_pod_mesh``.
    """
    from jax.sharding import Mesh

    avail = jax.devices()
    npods = min(2, len(avail)) if npods is None else npods
    if npods > len(avail):
        raise ValueError(f"npods {npods} > available devices {len(avail)}")
    return Mesh(np.asarray(avail[:npods]), ("pod",))


def make_cluster_mesh(n_replicas: int, pods_per_replica: int = 1):
    """('pod',)-axis mesh for an ``n_replicas``-replica serving cluster.

    The pod axis spans ``n_replicas * pods_per_replica`` slots —
    ``pods_per_replica`` is 1 for fused-engine replicas and 2 for
    disaggregated (prefill pod + decode pod) replicas. When the backend
    has fewer devices than slots, the axis clamps to what exists and the
    cluster's replica slices overlap modulo the axis (the degenerate
    single-device case runs every replica on one CPU, which is what lets
    the full cluster tier execute in tier-1 tests); with enough devices
    every replica owns a disjoint slice.
    """
    from jax.sharding import Mesh

    if n_replicas < 1 or pods_per_replica < 1:
        raise ValueError(
            f"need n_replicas >= 1 and pods_per_replica >= 1: "
            f"({n_replicas}, {pods_per_replica})"
        )
    avail = jax.devices()
    need = n_replicas * pods_per_replica
    return Mesh(np.asarray(avail[:min(need, len(avail))]), ("pod",))
