"""Serving launcher: continuous-batching engine + closed-loop load.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --transport gdr --clients 4
"""

import argparse

import jax

from repro.configs import get_config
from repro.core.transport import PROFILES, Transport
from repro.models import Model
from repro.serving import ClosedLoopClient, Gateway, ServingEngine, run_closed_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--transport", default="gdr",
                    choices=["local", "tcp", "rdma", "gdr"])
    ap.add_argument("--first-hop", default="",
                    choices=["", "tcp", "rdma"], help="proxied connection")
    ap.add_argument("--profile", default="paper_a2", choices=sorted(PROFILES))
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(
        model, params, max_batch=args.max_batch,
        max_seq=args.prompt_len + args.new_tokens + 8,
        transport=Transport(args.transport), profile=PROFILES[args.profile],
    )
    front = engine
    if args.first_hop:
        front = Gateway(engine, first_hop=Transport(args.first_hop),
                        profile=PROFILES[args.profile])
    clients = [
        ClosedLoopClient(i, cfg.vocab_size, prompt_len=args.prompt_len,
                         max_new_tokens=args.new_tokens)
        for i in range(args.clients)
    ]
    run_closed_loop(front, clients, requests_per_client=args.requests)
    s = engine.store
    print(f"{cfg.name} via {args.transport}"
          + (f" (proxied {args.first_hop})" if args.first_hop else ""))
    print("  requests:", len(s.records))
    print("  mean total: %.2f ms  p99: %.2f ms"
          % (s.summary()["mean"] * 1e3, s.summary()["p99"] * 1e3))
    print("  stage means (ms):",
          {k: round(v * 1e3, 3) for k, v in s.stage_means().items() if v})


if __name__ == "__main__":
    main()
