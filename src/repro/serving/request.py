"""Serving request/response types."""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

_ids = itertools.count()


@dataclasses.dataclass
class Request:
    prompt_tokens: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    priority: int = 0
    client_id: int = 0
    request_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    features: Optional[np.ndarray] = None  # vlm/audio stub payload
    # filled by the engine — all three stamps come from ONE clock
    # (time.perf_counter), so ttft/total latencies are clock-consistent
    # regardless of what the caller passes to submit().
    t_arrival: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    generated: list = dataclasses.field(default_factory=list)

    @property
    def payload_bytes(self) -> int:
        n = self.prompt_tokens.nbytes
        if self.features is not None:
            n += self.features.nbytes
        return n


@dataclasses.dataclass
class Response:
    request_id: int
    tokens: list
    ttft_s: float  # time to first token (perf_counter deltas)
    total_s: float
    stage_s: dict
