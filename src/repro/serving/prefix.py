"""Radix-style prefix index over admitted prompts (paged KV reuse).

The index maps *page-aligned* token prefixes to per-page payloads (the
engine stores KV block ids; the disaggregated tier stores (prefill_block,
decode_block) pairs). Granularity is one KV page: a prompt contributes
``len(tokens) // page_size`` full pages, and a lookup returns the longest
chain of already-indexed pages matching the query's page sequence —
classic radix/trie longest-prefix-match, with one trie edge per page so
match/insert are O(pages), not O(tokens).

Eviction is LRU over *leaves only*: an interior page is by construction at
least as recently used as every descendant (any match or insert that
touches a node touches its whole root path), so evicting leaves first
releases the coldest pages while keeping the shared trunk hot. The caller
owns block lifetime — evicted payloads are returned for deref'ing, and the
refcounts in :class:`repro.models.kvcache.PagedKVPool` guarantee a block a
live request still reads survives its index eviction.
"""

from __future__ import annotations

from typing import Optional


class _Node:
    __slots__ = ("key", "payload", "children", "parent", "last_use")

    def __init__(self, key, payload, parent):
        self.key = key  # page token tuple (None for root)
        self.payload = payload
        self.children = {}
        self.parent = parent
        self.last_use = 0


class RadixPrefixIndex:
    """Longest-prefix-match over page-aligned prompt prefixes.

    ``capacity_pages`` (optional) bounds the indexed page count; inserts
    beyond it evict LRU leaves first (the engine additionally evicts on
    KV-pool pressure).
    """

    def __init__(self, page_size: int, capacity_pages: Optional[int] = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1: {page_size}")
        self.page = int(page_size)
        self.capacity_pages = capacity_pages
        self.root = _Node(None, None, None)
        self.n_pages = 0
        self._clock = 0
        # telemetry
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------ #
    def _pages(self, tokens) -> list:
        toks = [int(t) for t in tokens]
        n = len(toks) // self.page
        return [tuple(toks[i * self.page:(i + 1) * self.page])
                for i in range(n)]

    def _touch(self, node) -> None:
        self._clock += 1
        while node is not None and node.key is not None:
            node.last_use = self._clock
            node = node.parent

    # ------------------------------------------------------------------ #
    def match(self, tokens, max_pages: Optional[int] = None, *,
              peek: bool = False) -> list:
        """Longest indexed page-chain prefixing ``tokens``.

        Returns the matched pages' payloads in order (possibly empty).
        ``max_pages`` caps the walk (the engine caps below the full prompt
        so at least one suffix token always remains to produce logits).
        ``peek`` skips the LRU touch and hit/miss counters — for router
        scoring, which must not distort replica-local recency.
        """
        pages = self._pages(tokens)
        if max_pages is not None:
            pages = pages[:max_pages]
        node = self.root
        out = []
        for pg in pages:
            child = node.children.get(pg)
            if child is None:
                break
            out.append(child.payload)
            node = child
        if not peek:
            if out:
                self.hits += 1
                self._touch(node)
            else:
                self.misses += 1
        return out

    def lookup_tokens(self, tokens) -> int:
        """Matched prefix length in TOKENS (LRU-neutral; router scoring)."""
        return len(self.match(tokens, peek=True)) * self.page

    # ------------------------------------------------------------------ #
    def insert(self, tokens, payloads, max_pages: Optional[int] = None) -> list:
        """Index ``tokens``' page chain; page ``i`` carries ``payloads[i]``.

        Existing pages keep their current payload (first writer wins — the
        engine refs THOSE blocks at match time instead). Returns the
        payloads of newly-created nodes, so the caller can take the index's
        block references. Respects ``capacity_pages`` by LRU-evicting
        leaves first; pages that still don't fit are skipped (deeper pages
        of a chain can never be indexed without their parents, so the walk
        stops).
        """
        pages = self._pages(tokens)
        if max_pages is not None:
            pages = pages[:max_pages]
        node = self.root
        created = []
        for i, pg in enumerate(pages):
            child = node.children.get(pg)
            if child is None:
                if self.capacity_pages is not None:
                    while (self.n_pages >= self.capacity_pages
                           and self.evict_lru()):
                        pass
                    if self.n_pages >= self.capacity_pages:
                        break
                child = _Node(pg, payloads[i], node)
                node.children[pg] = child
                self.n_pages += 1
                created.append(payloads[i])
            node = child
        self._touch(node)
        return created

    # ------------------------------------------------------------------ #
    def _leaves(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.key is not None and not n.children:
                yield n
            stack.extend(n.children.values())

    def evict_lru(self) -> Optional[object]:
        """Remove the least-recently-used LEAF page; returns its payload
        (None when the index is empty). One page per call so the caller
        can stop as soon as the KV pool has room again."""
        victim = None
        for leaf in self._leaves():
            if victim is None or leaf.last_use < victim.last_use:
                victim = leaf
        if victim is None:
            return None
        del victim.parent.children[victim.key]
        self.n_pages -= 1
        return victim.payload

    def clear(self) -> list:
        """Drop everything; returns all payloads (caller derefs blocks)."""
        out = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            if n.key is not None:
                out.append(n.payload)
            stack.extend(n.children.values())
        self.root = _Node(None, None, None)
        self.n_pages = 0
        return out
