"""Real-compute serving engine: continuous batching over a slot-based KV pool.

This is the executable twin of ``core/simulator.py``: the same four-stage
pipeline (request -> [copy] -> preprocess/prefill -> decode -> response), but
inference is REAL JAX compute (reduced-config models on CPU; the same code
drives full configs on TPU). Transport and copy-engine stage times come from
the calibrated TransportProfile so a request's end-to-end record composes
measured compute with modeled wires, exactly like the paper's Table I.

Continuous batching: a fixed pool of ``max_batch`` slots; finished sequences
free their slot, queued requests join at the next step boundary; every decode
step runs the whole active batch through one jitted serve_step.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiler import ProfileStore, RequestRecord
from repro.core.transport import PAPER_A2, Transport, TransportProfile
from repro.models import Model
from repro.serving.request import Request, Response


class ServingEngine:
    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 8,
        max_seq: int = 256,
        transport: Transport = Transport.GDR,
        profile: TransportProfile = PAPER_A2,
        eos_token: Optional[int] = None,
    ):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.transport = transport
        self.profile = profile
        self.eos = eos_token
        self.store = ProfileStore()

        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.caches = model.init_cache(max_batch, max_seq)
        self.lengths = jnp.zeros((max_batch,), jnp.int32)
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self._records: dict[int, RequestRecord] = {}

        self._decode = jax.jit(
            lambda p, c, t, l: model.decode_step(p, c, t, l)
        )
        self._prefill_cache = {}

    # ------------------------------------------------------------------ #
    def submit(self, req: Request, now: float):
        req.t_arrival = now
        rec = RequestRecord(
            request_id=req.request_id, client_id=req.client_id,
            priority=req.priority, t_issue=now,
            bytes_in=req.payload_bytes, bytes_out=4 * req.max_new_tokens,
        )
        # modeled ingress: wire + (copy engine for staged transports)
        rec.add("request", self.profile.wire_time(self.transport, rec.bytes_in))
        if self.transport.uses_copy_engine:
            rec.add("copy_in", self.profile.copy_time(rec.bytes_in))
        self._records[req.request_id] = rec
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, s in enumerate(self.slots) if s is None]

    def _prefill_one(self, slot: int, req: Request):
        S = len(req.prompt_tokens)
        toks = jnp.asarray(req.prompt_tokens, jnp.int32)[None, :]
        batch = {"tokens": toks}
        if req.features is not None:
            batch["features"] = jnp.asarray(req.features)
        key = (S, req.features is not None)
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(
                lambda p, b: self.model.prefill(p, b)
            )
        t0 = time.perf_counter()
        logits, cache1, lengths1 = self._prefill_cache[key](self.params, batch)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        rec = self._records[req.request_id]
        rec.add("preprocess", dt)  # prefill = the serving "preprocessing"

        from repro.models.kvcache import grow_cache

        cache1 = grow_cache(cache1, self.max_seq)

        # splice the single-sequence cache into the pool at `slot`;
        # grouped caches: leaves may be stacked [L, B, ...] or plain [B, ...]
        def splice_leaf(pool, one):
            if pool.ndim == one.ndim:  # both stacked: [L,B,...]
                return pool.at[:, slot].set(one[:, 0])
            return pool.at[slot].set(one[0])

        self.caches = jax.tree.map(splice_leaf, self.caches, cache1)
        self.lengths = self.lengths.at[slot].set(int(lengths1[0]))
        next_tok = int(jnp.argmax(logits[0]))
        self.tokens = self.tokens.at[slot, 0].set(next_tok)
        req.generated.append(next_tok)
        self.slots[slot] = req
        req.t_first_token = time.perf_counter()

    def _admit(self):
        # priority-aware admission
        while self.queue and self._free_slots():
            best = max(range(len(self.queue)), key=lambda i: self.queue[i].priority)
            req = self.queue[best]
            del self.queue[best]
            self._prefill_one(self._free_slots()[0], req)

    def step(self) -> list[Response]:
        """One continuous-batching iteration. Returns finished responses."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        t0 = time.perf_counter()
        logits, self.caches, self.lengths = self._decode(
            self.params, self.caches, self.tokens, self.lengths
        )
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        self.tokens = jnp.asarray(next_tokens[:, None], jnp.int32)

        done: list[Response] = []
        for i in active:
            req = self.slots[i]
            rec = self._records[req.request_id]
            rec.add("inference", dt / max(len(active), 1))
            tok = int(next_tokens[i])
            req.generated.append(tok)
            finished = len(req.generated) >= req.max_new_tokens or (
                self.eos is not None and tok == self.eos
            )
            if finished:
                rsp_wire = self.profile.wire_time(self.transport, rec.bytes_out)
                rec.add("response", rsp_wire)
                if self.transport.uses_copy_engine:
                    rec.add("copy_out", self.profile.copy_time(rec.bytes_out))
                rec.t_done = time.perf_counter() + rsp_wire
                self.store.add(rec)
                done.append(
                    Response(
                        request_id=req.request_id,
                        tokens=list(req.generated),
                        ttft_s=req.t_first_token - req.t_arrival,
                        total_s=rec.t_done - rec.t_issue,
                        stage_s=dict(rec.stage_s),
                    )
                )
                self.slots[i] = None
        return done

    def run_until_drained(self, max_steps: int = 10_000) -> list[Response]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and all(s is None for s in self.slots):
                break
        return out
