"""Real-compute serving engine: continuous batching over a slot-based KV pool.

This is the executable twin of ``core/simulator.py``: the same four-stage
pipeline (request -> [copy] -> preprocess/prefill -> decode -> response), but
inference is REAL JAX compute (reduced-config models on CPU; the same code
drives full configs on TPU). Transport and copy-engine stage times come from
the calibrated TransportProfile so a request's end-to-end record composes
measured compute with modeled wires, exactly like the paper's Table I.

The engine is two separable stages:

* **Admission + prefill** (this class): the request queue, priority pick,
  bucketed/exact prefill, and per-request records. Prefill produces a
  :class:`PrefillArtifact` — the max_seq-grown cache plus per-row slot
  metadata — which is everything a decode stage needs to take over a
  request.
* **Decode slot pool** (:class:`DecodePool`): slot occupancy, the ring KV
  pool, the per-slot device decode state, the jitted splice and decode
  step, and the async in-flight window. It knows nothing about transports
  or records, so a FOREIGN artifact — one produced on a different mesh pod
  and moved through ``core.transfer.kv_transfer`` — splices through the
  same entry point (see serving/disagg.py, which overrides the
  :meth:`ServingEngine._handoff` seam between the two stages).

Fast path (the serving hot loop, rebuilt for throughput):

* **Bucketed prefill** — prompts are right-padded to power-of-two length
  buckets and queued admissions sharing a bucket run as ONE padded prefill
  call (batch dim padded to the FIXED admission width max_batch — trading
  up to max_batch x prefill FLOPs on sparse admissions for exactly one
  compile per bucket; dummy rows scatter out-of-bounds and drop). Compile
  count is O(log max_seq) instead of O(distinct prompt lengths), and an
  admission burst is a single device dispatch.
* **Device-resident decode loop** — sampling (greedy argmax by default;
  temperature/top-k categorical with an in-jit threaded PRNG key when
  ``temperature > 0``), EOS detection, per-slot done flags, and length
  updates all live inside one jitted decode step that returns a
  device-side ``done`` mask. The host never syncs per token: up to
  ``inflight`` steps are dispatched ahead — capped adaptively at the live
  slots' outstanding token budget (``adaptive_window``), so the window
  stops paying overshoot steps past finishing requests — and each step's
  tokens+done arrive in one host transfer at harvest time. The KV pool is
  donated through the step, so steady-state decode holds a single cache
  buffer.
* **Fused admission splice** — growing a prefill cache to the pool window
  and scattering it into the free slots (plus lengths/tokens/flag updates)
  is one jitted, donated call instead of a per-leaf ``.at[].set`` chain.
* **Token-packed prefill** (``packed=True``) — instead of right-padding
  each prompt to its bucket, an admission concatenates every prompt into
  ONE ``[1, pow2(total_true_tokens)]`` sequence with per-token segment
  ids (cross-prompt attention masked in the kernel, positions
  segment-relative), so a ragged admission's prefill cost tracks the
  tokens it actually has. The packed cache unpacks per segment in-jit
  into the same bucketed-shaped artifact, and ``packed=False`` keeps the
  bucketed path as the measured A/B baseline.
* **Chunked prefill** (``prefill_chunk=C``) — prompts longer than C admit
  as fixed-width suffix-prefill chunks, ONE per engine iteration after
  the decode window top-up, so a long admission interleaves with live
  decodes instead of head-of-line blocking them for its full prefill
  wall (decode TPOT stays flat through a max_seq-token admission).

``legacy=True`` preserves the original synchronous loop (per-length jitted
prefill, ``block_until_ready`` + host argmax + per-slot Python bookkeeping
every token) as the measured A/B baseline for ``benchmarks/serving.py`` and
the drain-equivalence test.

Because every hot-loop shape is pow2-bounded, ``warmup=True`` can
pre-trace the whole grid at construction (:meth:`ServingEngine.warm`):
a warmed engine charges no XLA compile inside any timed serving stage.
The disaggregated tier extends the same warm pass over its handoff
extents and additionally commits each stage's params/compute to its own
mesh pod slice (see serving/disagg.py and docs/architecture.md).

Continuous batching: a fixed pool of ``max_batch`` slots; finished sequences
free their slot, queued requests join at the next step boundary; every decode
step runs the whole active batch through one jitted step.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
import traceback
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import trace
from repro.core.obs import Registry
from repro.core.profiler import ProfileStore, RequestRecord
from repro.core.transport import PAPER_A2, Transport, TransportProfile
from repro.models import Model
from repro.models import kvcache as kvc
from repro.serving.prefix import RadixPrefixIndex
from repro.serving.request import Request, Response


# Every jax.jit created in serving/ must either appear here — meaning
# warm() pre-traces it at construction, so it never compiles inside a
# timed stage — or carry a `# reprolint: disable=RL005` with the reason
# it cannot be pre-traced. tools/reprolint RL005 checks the union of
# these tables across serving/ against every jit creation site.
WARM_PRETRACE_TABLE = frozenset({
    "_step_jit",            # DecodePool: warmed by warm()'s fill_one
    "_splice_jit",          # DecodePool: warmed via _warm_admit's splice
    "_prefill_bucket_jit",  # one compile per pow2 bucket in warm()
    "_prefill_paged_jit",   # paged twin, same bucket grid
    "_prefill_suffix_jit",  # warmed per bucket when prefix_reuse is on
    "_prefill_packed_jit",  # packed=True: one compile per pow2 packed width
    "_chunk_jit",           # prefill_chunk>0: ONE shape (fixed-width prior)
    "_chunk_pad_jit",       # chunk artifact row pad, one shape
})


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unharvested decode step."""

    tokens: jax.Array  # [B, 1] device
    done: jax.Array  # [B] device
    slots: tuple  # Request-or-None per slot, snapshotted at dispatch


@dataclasses.dataclass
class PrefillArtifact:
    """Everything a prefill stage must deliver to a decode slot pool.

    Row j of every per-row array belongs to ``reqs[j]``; padding rows carry
    slot index == max_batch, which is out of bounds for the splice scatter
    and therefore dropped. ``caches`` is already grown to the pool's ring
    width (max_seq), so the splice sees one fixed shape.

    ``n_rows``/``prefix_len`` record the artifact's VALID extent — the
    occupied leading rows and the max true cache length among them (prompt
    tokens, plus feature frames on the vlm/audio exact path) — so a
    pod-boundary handoff can move only the live KV prefix
    (``kvcache.slice_cache``) instead of the padded admission tree, and
    grow back to the pool shape on the far side.
    """

    caches: object  # cache tree, ring dim grown to max_seq
    slot_idx: np.ndarray  # [npad] int32 host-side (OOB => dummy row)
    lengths: jax.Array  # [npad] true prompt lengths
    next_tokens: jax.Array  # [npad] greedy first token per row
    max_new: jax.Array  # [npad] per-request token budget
    reqs: list  # the real requests (row-aligned prefix)
    slots: list  # pool slot per request
    n_rows: int = 0  # occupied leading rows (== len(reqs))
    prefix_len: int = 0  # max true cache length among occupied rows
    # paged-mode extras: ``caches`` then holds the SUFFIX cache at bucket
    # width (never grown to max_seq); the splice scatters its pages into
    # the block pool at ``dest_blocks`` (0 => dropped), and ``cached_lens``
    # records each row's reused prefix (its KV already lives in shared
    # blocks, so it never rides the artifact — or, disaggregated, the wire)
    dest_blocks: Optional[np.ndarray] = None  # [npad, bucket/page] int32
    cached_lens: Optional[np.ndarray] = None  # [npad] int32 reused prefix
    bucket: int = 0  # suffix bucket width (paged handoff extent)


@dataclasses.dataclass
class _PagedJob:
    """Per-request admission bookkeeping for a paged prefill group."""

    req: Request
    slot: int
    cached: int  # reused prefix tokens (page-aligned)
    p_ids: list  # prior-side blocks gathered for the suffix prefill
    d_ids: list  # shared decode-side blocks (the row's pt prefix)
    own: list  # freshly-allocated blocks (suffix + decode growth)
    pt_row: list  # d_ids + own = the row's page table


@dataclasses.dataclass
class _ChunkJob:
    """One in-progress chunked admission: its reserved slot, its
    fixed-width prior cache tree, and the tokens prefilled so far."""

    req: Request
    slot: int
    prior: object  # [.., 1, max_seq, ..] cache tree, donated per chunk
    done: int = 0  # prompt tokens already prefilled + spliced


class DecodePool:
    """Decode-side slot pool, separable from admission/prefill.

    Owns slot occupancy, the ring KV pool, the per-slot device decode state
    (tokens/lengths/gen/done/max_new), the jitted splice and decode step,
    and the async in-flight window. A local prefill stage and a remote pod
    handing a cache off through ``core.transfer`` splice through the same
    :meth:`splice` entry point. :meth:`place` commits the whole pool to a
    device slice (per-pod placement); :meth:`reset_state` re-zeros it
    after a construction-time warmup without dropping compiled jits.
    """

    def __init__(self, model: Model, *, max_batch: int, max_seq: int,
                 eos_token: Optional[int], inflight: int,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, paged: bool = False,
                 page_size: int = 16, cache_blocks: Optional[int] = None):
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.inflight = inflight
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.eos_arr = jnp.int32(eos_token if eos_token is not None else -1)
        # paged mode: the ring pool becomes a block pool + per-slot page
        # tables (host-built, pushed before each dispatch). Block count =
        # sentinel + worst-case live rows + cache_blocks headroom the
        # prefix index can keep warm (default: another full pool's worth).
        self.paged = bool(paged)
        self.page = int(page_size)
        if paged:
            if max_seq % self.page:
                raise ValueError(
                    f"max_seq {max_seq} must be a multiple of page_size "
                    f"{page_size}"
                )
            self.pages_per_seq = max_seq // self.page
            need = max_batch * self.pages_per_seq
            extra = need if cache_blocks is None else int(cache_blocks)
            self.allocator = kvc.PagedKVPool(1 + need + extra, self.page)
        # device-side sampling: temperature 0 keeps the greedy argmax path
        # (the test baseline); temperature > 0 samples inside the jitted
        # step from top_k-filtered logits with a PRNG key threaded through
        # the pool state — no host round-trip per token.
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0: {temperature}")
        if top_k < 0:
            raise ValueError(f"top_k must be >= 0: {top_k}")
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.sample_seed = int(sample_seed)
        self.window: deque[_InFlight] = deque()
        self._sharding = None  # optional committed placement (pod slice)
        self._init_state()
        if self.paged:
            self._step_jit = jax.jit(self._step_paged_impl, donate_argnums=(1,))
            self._splice_jit = jax.jit(self._splice_paged_impl,
                                       donate_argnums=(0,))
        else:
            self._step_jit = jax.jit(self._step_impl, donate_argnums=(1,))
            self._splice_jit = jax.jit(self._splice_impl, donate_argnums=(0,))

    # every device-state array the pool owns: _init_state (re)builds them
    # and place() commits them — keep the two in sync through this tuple
    _STATE_FIELDS = ("caches", "lengths", "tokens", "gen", "maxn", "done",
                     "eos_arr", "key")

    def _state_field_names(self) -> tuple:
        if self.paged:  # the block pool + page table replace the ring tree
            return tuple(f for f in self._STATE_FIELDS if f != "caches") + (
                "blocks", "page_table")
        return self._STATE_FIELDS

    def _init_state(self):
        """(Re)build the device-side slot state (the ``_STATE_FIELDS``
        arrays, minus the constant eos_arr): empty pool, all slots done.
        Re-placed onto the committed sharding when one is set."""
        if self.paged:
            self.caches = None
            self.blocks = kvc.init_paged(
                self.model.cache_specs(self.max_batch, self.max_seq),
                self.allocator.num_blocks, self.page,
            )
            self.pt_host = np.zeros((self.max_batch, self.pages_per_seq),
                                    np.int32)
            self.page_table = jnp.asarray(self.pt_host)
            self._pt_dirty = False
            self._slot_blocks: list[list] = [[] for _ in range(self.max_batch)]
            self.allocator.reset()
        else:
            self.caches = self.model.init_cache(self.max_batch, self.max_seq)
        self.lengths = jnp.zeros((self.max_batch,), jnp.int32)
        self.tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        self.gen = jnp.zeros((self.max_batch,), jnp.int32)
        self.maxn = jnp.zeros((self.max_batch,), jnp.int32)
        self.done = jnp.ones((self.max_batch,), bool)
        # raw uint32 key data (not a typed key array) so the whole state
        # tuple stays plain arrays for place()/device_put
        self.key = jax.random.PRNGKey(self.sample_seed)
        if self._sharding is not None:
            self.place(self._sharding)

    def place(self, sharding):
        """Commit the pool's entire device state (``_STATE_FIELDS``) to
        ``sharding`` (a pod slice in the disaggregated tier): every
        subsequent splice/step jit then compiles for — and provably
        executes on — exactly that slice's devices, since jit placement
        follows its committed arguments."""
        self._sharding = sharding
        for name in self._state_field_names():
            setattr(self, name, jax.device_put(getattr(self, name), sharding))

    def reset_state(self):
        """Re-zero the slot state (post-warmup): a pristine pool, with the
        compiled splice/step executables and the placement retained."""
        if any(s is not None for s in self.slots):
            raise RuntimeError("reset_state on an occupied pool")
        self.window.clear()
        self._init_state()

    # ------------------------------------------------------------------ #
    # jitted bodies
    # ------------------------------------------------------------------ #
    def _step_impl(self, params, caches, tokens, lengths, gen, maxn, done,
                   eos, key):
        """One whole-batch decode step, sampling and stop logic on device.

        Frozen (done/empty) slots keep their token and length so their ring
        slot stays put; their lane still flows through the batched compute
        (the output is discarded), which is what keeps the loop shape-stable.

        Sampling is greedy argmax at temperature 0 (the default and the
        token-identity baseline); otherwise one categorical draw per slot
        from the temperature-scaled, top_k-filtered logits, with the PRNG
        key split in-jit and threaded back through the state — the whole
        batch consumes one split per step, so the token stream is a pure
        function of (sample_seed, step index, slot).
        """
        active = ~done
        logits, caches, lengths2 = self.model.decode_step(
            params, caches, tokens, lengths
        )
        if self.temperature > 0.0:
            key, sub = jax.random.split(key)
        else:
            sub = key
        next_tok = self._sample(logits, sub)
        next_tok = jnp.where(active, next_tok, tokens[:, 0])
        gen = gen + active.astype(jnp.int32)
        done = done | (gen >= maxn) | (active & (next_tok == eos))
        lengths = jnp.where(active, lengths2, lengths)
        return next_tok[:, None], caches, lengths, gen, done, key

    def _sample(self, logits, key):
        """Next-token choice on device: argmax, or temperature/top-k
        categorical (``top_k == 0`` keeps the full vocabulary; ``top_k ==
        1`` degenerates to argmax exactly, temperature notwithstanding)."""
        if self.temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        lg = logits.astype(jnp.float32) / self.temperature
        if self.top_k > 0:
            kth = jax.lax.top_k(lg, self.top_k)[0][:, -1:]
            lg = jnp.where(lg < kth, -jnp.inf, lg)
        return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)

    def _step_paged_impl(self, params, blocks, page_table, tokens, lengths,
                         gen, maxn, done, eos, key):
        """Paged decode step: gather -> ring decode -> scatter one token.

        The per-row dense caches are materialized from the block pool
        through the page table, the UNCHANGED ``Model.decode_step`` runs on
        them (so the math — and at temperature 0 the token stream — is
        bitwise the ring path's: unallocated pages gather the zero
        sentinel, exactly what grow_cache pads), and only the ONE ring slot
        the step wrote is scattered back per row. Freed slots' page-table
        rows are zero, so their frozen-lane writes drop at the sentinel
        redirect. The TPU-optimal variant that skips the gather entirely is
        ``kernels.ops.paged_decode_attention`` (equivalence-tested); this
        reference path stays pure-jnp like the model's.
        """
        active = ~done
        dense = kvc.gather_pages(blocks, page_table)
        logits, dense, lengths2 = self.model.decode_step(
            params, dense, tokens, lengths
        )
        blocks = kvc.scatter_token(blocks, dense, lengths, page_table)
        if self.temperature > 0.0:
            key, sub = jax.random.split(key)
        else:
            sub = key
        next_tok = self._sample(logits, sub)
        next_tok = jnp.where(active, next_tok, tokens[:, 0])
        gen = gen + active.astype(jnp.int32)
        done = done | (gen >= maxn) | (active & (next_tok == eos))
        lengths = jnp.where(active, lengths2, lengths)
        return next_tok[:, None], blocks, lengths, gen, done, key

    def _splice_paged_impl(self, blocks, suffix, dest_blocks, slots,
                           true_lens, next_toks, maxn_new, lengths, tokens,
                           gen, done, maxn):
        """Paged admission: scatter the bucket-width suffix cache into the
        block pool page-wise, plus the same per-slot state updates as the
        ring splice. Dummy rows carry dest block 0 (the zero sentinel) and
        slot index max_batch — both dropped by their scatters."""
        blocks = kvc.scatter_pages(blocks, suffix, dest_blocks)
        lengths = lengths.at[slots].set(true_lens)
        tokens = tokens.at[slots, 0].set(next_toks)
        gen = gen.at[slots].set(1)
        done = done.at[slots].set(maxn_new <= 1)
        maxn = maxn.at[slots].set(maxn_new)
        return blocks, lengths, tokens, gen, done, maxn

    def _splice_impl(self, pool, group, slots, true_lens, next_toks, maxn_new,
                     lengths, tokens, gen, done, maxn):
        """Scatter a (max_seq-grown) prefill cache into ``slots``, updating
        all per-slot decode state in the same dispatch.

        Dummy rows (batch padding) carry slot index == max_batch, which is
        out of bounds: JAX scatters drop OOB updates, so they vanish without
        a separate code path or extra compile.
        """
        out = {}
        for gi, g in enumerate(self.model.groups):
            stacked = g.count > 1

            def leaf(p, n, _stacked=stacked):
                if _stacked:  # [L, B, ...] pool, [L, N, ...] group
                    return p.at[:, slots].set(n.astype(p.dtype))
                return p.at[slots].set(n.astype(p.dtype))

            out[f"g{gi}"] = jax.tree.map(leaf, pool[f"g{gi}"], group[f"g{gi}"])
        lengths = lengths.at[slots].set(true_lens)
        tokens = tokens.at[slots, 0].set(next_toks)
        gen = gen.at[slots].set(1)
        # the prefill token may already exhaust the budget (max_new=1):
        # such slots start done so decode never advances them
        done = done.at[slots].set(maxn_new <= 1)
        maxn = maxn.at[slots].set(maxn_new)
        return out, lengths, tokens, gen, done, maxn

    # ------------------------------------------------------------------ #
    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def all_free(self) -> bool:
        return all(s is None for s in self.slots)

    @property
    def done_mask(self) -> np.ndarray:
        """Host copy of the device-side per-slot done flags."""
        return np.asarray(self.done)

    def splice(self, art: PrefillArtifact):
        """Admit a prefill artifact (local or transferred) into the pool."""
        if self.paged:
            (self.blocks, self.lengths, self.tokens, self.gen, self.done,
             self.maxn) = self._splice_jit(
                self.blocks, art.caches, jnp.asarray(art.dest_blocks),
                jnp.asarray(art.slot_idx), art.lengths, art.next_tokens,
                art.max_new, self.lengths, self.tokens, self.gen, self.done,
                self.maxn,
            )
            return
        (self.caches, self.lengths, self.tokens, self.gen, self.done,
         self.maxn) = self._splice_jit(
            self.caches, art.caches, jnp.asarray(art.slot_idx), art.lengths,
            art.next_tokens, art.max_new, self.lengths, self.tokens,
            self.gen, self.done, self.maxn,
        )

    # ------------------------------------------------------------------ #
    # paged page-table plumbing (host-authored, device-consumed)
    # ------------------------------------------------------------------ #
    def set_row(self, slot: int, blocks_list: list):
        """Install a slot's page table row (admission). The device copy is
        pushed lazily before the next dispatch; steps already in flight
        read the OLD table, whose entries for this row are zero — their
        writes drop at the sentinel, so a stale window is harmless."""
        self.pt_host[slot, :] = 0
        self.pt_host[slot, : len(blocks_list)] = blocks_list
        self._pt_dirty = True
        self._slot_blocks[slot] = list(blocks_list)

    def release_slot(self, slot: int):
        """Drop a finished row's block references and zero its page-table
        row. Shared prefix blocks survive as long as the prefix index (or
        another row) still holds them — the refcount, not the slot, owns
        block lifetime."""
        if not self.paged:
            return
        self.allocator.deref(self._slot_blocks[slot])
        self._slot_blocks[slot] = []
        self.pt_host[slot, :] = 0
        self._pt_dirty = True

    def _sync_pt(self):
        if self._pt_dirty:
            pt = jnp.asarray(self.pt_host)
            if self._sharding is not None:
                pt = jax.device_put(pt, self._sharding)
            self.page_table = pt
            self._pt_dirty = False

    def fill_one(self, params, limit: Optional[int] = None) -> bool:
        """Dispatch one decode step if the in-flight window has room.

        ``limit`` caps the window below ``inflight`` (adaptive dispatch:
        the engine passes the live slots' max outstanding token budget, so
        the device never runs steps no request can consume — the overshoot
        the fixed-depth window wasted on every finishing request).
        """
        cap = self.inflight if limit is None else max(0, min(self.inflight,
                                                             limit))
        if len(self.window) >= cap:
            return False
        if self.paged:
            self._sync_pt()
            (self.tokens, self.blocks, self.lengths, self.gen,
             self.done, self.key) = self._step_jit(
                params, self.blocks, self.page_table, self.tokens,
                self.lengths, self.gen, self.maxn, self.done, self.eos_arr,
                self.key,
            )
        else:
            (self.tokens, self.caches, self.lengths, self.gen,
             self.done, self.key) = self._step_jit(
                params, self.caches, self.tokens, self.lengths,
                self.gen, self.maxn, self.done, self.eos_arr, self.key,
            )
        self.window.append(_InFlight(self.tokens, self.done, tuple(self.slots)))
        return True

    def pop_oldest(self) -> Optional[_InFlight]:
        return self.window.popleft() if self.window else None


class ServingEngine:
    """Continuous-batching serving engine over a slot-based KV pool.

    The public surface is three calls: :meth:`submit` queues a request,
    :meth:`step` runs one continuous-batching iteration (admit -> dispatch
    -> harvest) and returns any finished :class:`~repro.serving.request.
    Response` objects, and :meth:`run_until_drained` loops :meth:`step`
    until queue, slots, and in-flight window are all empty. Per-request
    stage accounting accumulates in ``self.store`` (a ProfileStore); the
    pre-admission wait (submit -> the admission that picks the request)
    is charged as the 'queue' stage, so single-engine and cluster
    breakdowns compare like for like.

    ``warmup=True`` pre-traces the pow2 serving shape grid at
    construction (see :meth:`warm`), so no timed serving stage ever
    charges an XLA compile. ``legacy=True`` keeps the seed synchronous
    loop as the measured A/B baseline.
    """

    def __init__(
        self,
        model: Model,
        params,
        *,
        max_batch: int = 8,
        max_seq: int = 256,
        transport: Transport = Transport.GDR,
        profile: TransportProfile = PAPER_A2,
        eos_token: Optional[int] = None,
        bucketed_prefill: bool = True,
        inflight: int = 4,
        min_bucket: int = 16,
        legacy: bool = False,
        warmup: bool = False,
        adaptive_window: bool = True,
        temperature: float = 0.0,
        top_k: int = 0,
        sample_seed: int = 0,
        paged: bool = False,
        page_size: int = 16,
        cache_blocks: Optional[int] = None,
        prefix_reuse: bool = True,
        packed: bool = False,
        prefill_chunk: int = 0,
        debug_stamps: bool = False,
        trace_tag: str = "engine",
    ):
        self.model = model
        self.params = params
        # per-stage param handles: the fused engine serves both stages
        # from one (uncommitted) copy; the disaggregated tier replaces
        # these with copies committed to each stage's pod slice.
        self.prefill_params = params
        self.decode_params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.transport = transport
        self.profile = profile
        self.eos = eos_token
        # bucketed (right-padded) prefill is only sound when trailing pad
        # cannot leak into cached state: pure-attention stacks. SSM/hybrid
        # recurrences integrate pad tokens into conv/state, so those archs
        # take the exact-shape path (see Model.prefill_bucketed).
        attention_only = all(
            kind == "attn" for g in model.groups for (kind, _) in g.sigs
        )
        self.bucketed_prefill = bucketed_prefill and attention_only and not legacy
        if model.cfg.sliding_window and model.cfg.sliding_window < max_seq:
            # the slot pool is sized to max_seq but a sliding-window cache
            # rings at W=window: growing/splicing prefill caches into the
            # pool would mismatch (and right-pad past the window would
            # clobber live slots). Serve with max_seq <= window instead.
            raise ValueError(
                f"slot-pool engine requires max_seq <= sliding_window "
                f"({max_seq} > {model.cfg.sliding_window})"
            )
        self.inflight = 1 if legacy else max(1, inflight)
        self.min_bucket = min_bucket
        self.legacy = legacy
        # adaptive in-flight window: never dispatch deeper than the live
        # slots' outstanding token budget (fixed-depth windows waste up to
        # inflight-1 steps per finishing request)
        self.adaptive_window = adaptive_window and not legacy
        if legacy and temperature > 0.0:
            raise ValueError(
                "device-side sampling requires the fast path (the legacy "
                "loop argmaxes on host)"
            )
        # paged KV pool: fixed-size blocks + per-slot page tables, with the
        # ring pool kept as the A/B baseline (paged=False). Rides the
        # bucketed fast path only — the exact/legacy paths splice max_seq
        # ring trees.
        self.paged = bool(paged)
        self.page = int(page_size)
        if self.paged and not self.bucketed_prefill:
            raise ValueError(
                "paged KV pool requires the bucketed fast path "
                "(attention-only stack, legacy=False, bucketed_prefill=True)"
            )
        if self.paged and self.min_bucket % self.page:
            raise ValueError(
                f"min_bucket {self.min_bucket} must be a multiple of "
                f"page_size {self.page} (suffix buckets scatter page-wise)"
            )
        # token-packed prefill: admitted prompts concatenate into ONE
        # [1, pow2(total_tokens)] sequence with per-token segment ids, so
        # prefill cost tracks total TRUE tokens instead of rows x bucket.
        # Same soundness gate as bucketing (attention-only), plus non-MLA:
        # segment masking rides chunked_attention's plain-score path.
        # Auto-downgrades silently (like bucketed_prefill) so cross-arch
        # callers can set packed=True unconditionally.
        self.packed = (
            bool(packed) and self.bucketed_prefill and model.cfg.mla is None
        )
        # chunked prefill: prompts longer than prefill_chunk admit as a
        # sequence of fixed-width suffix-prefill chunks interleaved with
        # decode steps (one chunk per engine iteration), so a long
        # admission never stalls live decodes for its full prefill wall.
        self.prefill_chunk = int(prefill_chunk)
        if self.prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0: {prefill_chunk}")
        if self.prefill_chunk:
            if self.paged:
                raise ValueError(
                    "chunked prefill rides the ring pool (its fixed-width "
                    "prior splices via dense dynamic_update_slice); use "
                    "paged=False with prefill_chunk"
                )
            if self.prefill_chunk > max_seq:
                raise ValueError(
                    f"prefill_chunk {prefill_chunk} exceeds max_seq {max_seq}"
                )
        self._chunk_enabled = (
            self.prefill_chunk > 0 and self.bucketed_prefill
            and model.cfg.mla is None
        )
        self._chunk_jobs: deque = deque()  # in-progress chunked admissions
        self._chunk_slots: set = set()  # slots reserved by chunk jobs
        # shared-prefix reuse rides the paged pool; MLA suffix prefill can't
        # consume a gathered latent prior, so MLA pages without reuse.
        # Packed admissions interleave segments inside one sequence, so
        # their pages never align with the prefix index — reuse turns off.
        self.prefix_reuse = bool(
            self.paged and prefix_reuse and model.cfg.mla is None
            and not self.packed
        )
        self.prefix_index = (RadixPrefixIndex(self.page)
                             if self.prefix_reuse else None)
        # prefill telemetry: total vs uncached prompt tokens. The ring path
        # tracks the same counters (everything uncached) so A/B runs share
        # a schema; with reuse on, uncached is what prefill actually paid.
        self.prefill_tokens_total = 0
        self.prefill_tokens_uncached = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        # padded token-rows actually dispatched to prefill jits: a
        # deterministic FLOPs proxy (bucketed pays npad*L per group,
        # packed pays the pow2 packed width) — the A/B win the packing
        # bench asserts without depending on wall-clock noise
        self.prefill_padded_tokens = 0
        # prefill sampling key: its own stream (decoupled from the decode
        # pool's by fold_in), only ever consumed when temperature > 0
        self.prefill_key = jax.random.fold_in(
            jax.random.PRNGKey(sample_seed), 1
        )
        self.store = ProfileStore()

        self.queue: deque[Request] = deque()
        self.pool = DecodePool(
            model, max_batch=max_batch, max_seq=max_seq,
            eos_token=eos_token, inflight=self.inflight,
            temperature=temperature, top_k=top_k, sample_seed=sample_seed,
            paged=self.paged, page_size=page_size, cache_blocks=cache_blocks,
        )
        self._records: dict[int, RequestRecord] = {}

        self._finished_ids: set[int] = set()
        # entries popped from the window but not yet finalized — empty on
        # the synchronous step() path; EnginePipeline parks its harvest/
        # detokenize backlog here so _finished_ids pruning sees them
        self._backlog_entries: deque = deque()
        self._prefill_finished: list[Response] = []
        self._t_mark = time.perf_counter()
        self.decode_steps = 0  # total whole-batch decode dispatches
        self.useful_steps = 0  # harvested steps that advanced a live request
        # tracing (core/trace): request-scoped queue/prefill spans, a root
        # span per finished request, and WINDOWED decode spans (one span
        # per _TRACE_WINDOW_STEPS harvested steps, not per-step spam).
        # trace_tag names this engine's process-level span lane so
        # co-resident engines (in-process cluster replicas) don't share a
        # sequential-timeline check lane.
        self.trace_tag = trace_tag
        self._win_t0: Optional[float] = None
        self._win_end = 0.0
        self._win_steps = 0
        self._win_busy = 0
        # debug-mode stamp validation: every finished request's
        # t_arrival/t_first_token/t_done monotonicity is checked (a stage
        # clock running backwards here means a bad cross-process rebase)
        self.debug_stamps = bool(debug_stamps)

        # jitted entry points; jax.jit retraces per input shape, so the
        # prefill compile count equals the number of distinct bucket shapes.
        self._decode = jax.jit(  # reprolint: disable=RL005 legacy-loop only; legacy retraces per shape by design (warm() is a no-op under legacy=True)
            lambda p, c, t, l: model.decode_step(p, c, t, l)
        )
        self._prefill_bucket_jit = jax.jit(self._prefill_bucket_impl)
        self._prefill_exact_jit = jax.jit(self._prefill_exact_impl)  # reprolint: disable=RL005 exact-shape path (feature payloads/SSM) compiles per ragged request shape and cannot be pre-traced; see warm() docstring
        self._prefill_paged_jit = jax.jit(self._prefill_paged_impl)
        self._prefill_suffix_jit = jax.jit(self._prefill_suffix_impl)
        self._prefill_packed_jit = jax.jit(self._prefill_packed_impl)
        # the chunk jits see ONE shape each (fixed-width prior + chunk), so
        # chunked prefill adds exactly two compiles per engine; the prior
        # is donated through every chunk (steady chunking holds one tree)
        self._chunk_jit = jax.jit(self._chunk_impl, donate_argnums=(1,))
        # (no donation: the row pad GROWS every leaf, so no buffer reuses)
        self._chunk_pad_jit = jax.jit(self._chunk_pad_impl)
        self._prefill_shapes: set = set()
        self._prefill_cache = {}  # legacy per-(S, features) jit cache

        self.warmup = warmup
        self.warm_s = 0.0  # construction-time warm wall, outside all stages
        if warmup:
            self.warm_s = self.warm()

    # ------------------------------------------------------------------ #
    # decode-pool delegation (legacy loop + external callers)
    # ------------------------------------------------------------------ #
    @property
    def slots(self):
        return self.pool.slots

    @property
    def caches(self):
        return self.pool.caches

    @caches.setter
    def caches(self, v):
        self.pool.caches = v

    @property
    def lengths(self):
        return self.pool.lengths

    @lengths.setter
    def lengths(self, v):
        self.pool.lengths = v

    @property
    def tokens(self):
        return self.pool.tokens

    @tokens.setter
    def tokens(self, v):
        self.pool.tokens = v

    @property
    def done_mask(self) -> np.ndarray:
        """Host copy of the device-side per-slot done flags."""
        return self.pool.done_mask

    # ------------------------------------------------------------------ #
    # jitted prefill bodies
    # ------------------------------------------------------------------ #
    def _prefill_bucket_impl(self, params, tokens, lengths, key):
        """Padded-bucket prefill + first token sampled on device (argmax at
        temperature 0 — the token-identity baseline — else the same
        temperature/top-k categorical the decode step uses, from the
        engine's own prefill key stream).

        The cache ring dim is grown to max_seq HERE, inside the same jit:
        the admission splice then sees one fixed shape regardless of bucket,
        so it compiles exactly once per engine.
        """
        logits, caches, lens = self.model.prefill_bucketed(
            params, {"tokens": tokens}, lengths
        )
        caches = kvc.grow_cache(caches, self.max_seq)
        return self.pool._sample(logits, key), caches, lens

    def _prefill_paged_impl(self, params, tokens, lengths, key):
        """Paged-bucket prefill: same padded prefill + device sampling, but
        the cache stays at BUCKET width — the paged splice scatters its
        pages straight into the block pool, so nothing grows to max_seq."""
        logits, caches, lens = self.model.prefill_bucketed(
            params, {"tokens": tokens}, lengths
        )
        return self.pool._sample(logits, key), caches, lens

    def _prefill_suffix_impl(self, params, blocks, prior_pt, tokens, lengths,
                             cached, key):
        """Suffix prefill over a reused prefix: the prior KV is gathered
        from the block pool THROUGH the page table inside the same jit (the
        shared blocks never copy host-side), and suffix queries attend to
        prior + suffix keys at per-row absolute positions. Returns the
        bucket-width SUFFIX cache; the reused prefix never moves again."""
        prior = kvc.gather_pages(blocks, prior_pt)
        logits, caches, lens = self.model.prefill_suffix(
            params, {"tokens": tokens}, lengths, cached, prior
        )
        return self.pool._sample(logits, key), caches, lens

    def _prefill_packed_impl(self, params, tokens, positions, seg_ids,
                             seg_starts, last_idx, key):
        """Token-packed prefill: ONE [1, T] sequence holding every admitted
        prompt back to back, masked by per-token segment ids. Positions are
        segment-relative (RoPE matches the unpacked run bitwise) and each
        segment's first-token logits gather at its last real token.

        The packed cache unpacks per segment in the SAME jit —
        ``kvcache.unpack_segments`` windows each segment's rows out to the
        pool's splice width — so the artifact downstream machinery sees is
        shaped exactly like a bucketed admission's.
        """
        logits, packed = self.model.prefill_packed(
            params, tokens, positions, seg_ids, last_idx
        )
        if self.paged:
            out_w = min(tokens.shape[1], self.max_seq)
        else:
            out_w = self.max_seq
        caches = kvc.unpack_segments(packed, seg_starts, out_w)
        return self.pool._sample(logits, key), caches

    def _chunk_impl(self, params, prior, tokens, lengths, cached, key):
        """One chunk of a chunked prefill: suffix-prefill the [1, C] chunk
        against the request's fixed-width prior tree (``prior_valid`` =
        ``cached`` masks the unwritten tail), then splice the suffix cache
        back into the prior at the chunk's offset. ``cached`` is traced, so
        ONE compile serves every chunk of every request."""
        logits, suffix, _total = self.model.prefill_suffix(
            params, {"tokens": tokens}, lengths, cached, prior
        )
        prior = kvc.splice_suffix(prior, suffix, cached[0])
        return self.pool._sample(logits, key), prior

    def _chunk_pad_impl(self, prior):
        """Final-chunk artifact shaping: pad the single-row prior tree out
        to the admission width so the standard fused splice consumes it."""
        return kvc.pad_cache_rows(prior, self.max_batch)

    def _new_chunk_prior(self):
        """Fresh fixed-width prior tree for one chunked admission (the
        disaggregated tier overrides this to place it on the prefill pod
        slice, so every chunk computes there and only the final artifact
        crosses the pod boundary)."""
        return self.model.init_cache(1, self.max_seq)

    def _next_prefill_key(self):
        """Advance the prefill sampling stream (one split per prefill
        dispatch). Temperature 0 never consumes entropy — the key passes
        through unsplit, so greedy runs stay bit-stable regardless of how
        many admissions preceded any given one."""
        if self.pool.temperature == 0.0:
            return self.prefill_key
        self.prefill_key, sub = jax.random.split(self.prefill_key)
        return sub

    def _prefill_exact_impl(self, params, batch):
        """Exact-shape prefill (feature payloads / non-bucketable archs),
        grown to max_seq in-jit so the splice shape stays fixed."""
        logits, caches, lens = self.model.prefill(params, batch)
        caches = kvc.grow_cache(caches, self.max_seq)
        return logits, caches, lens

    # ------------------------------------------------------------------ #
    def submit(self, req: Request, now: Optional[float] = None):
        """Queue a request for admission at the next step boundary.

        Stamps the arrival clock and charges the modeled INGRESS stages
        (request wire + copy engine, per the deployment's transport) to
        the request's record; both reach its TTFT/total at finish time,
        symmetric with the egress wire. Raises if the prompt exceeds
        ``max_seq``.
        """
        # one clock source (perf_counter) for arrival, first token, and done
        # stamps; the caller's ``now`` is accepted for API compatibility but
        # no longer mixed into latency math.
        req.t_arrival = time.perf_counter()
        if len(req.prompt_tokens) > self.max_seq:
            raise ValueError(
                f"prompt length {len(req.prompt_tokens)} exceeds max_seq "
                f"{self.max_seq}"
            )
        if self.paged:
            if req.features is not None:
                raise ValueError(
                    "paged KV pool serves token prompts only (feature "
                    "payloads take the exact-shape ring path)"
                )
            if len(req.prompt_tokens) + req.max_new_tokens > self.max_seq:
                # the ring pool wraps a long generation over its own oldest
                # positions; a paged row may SHARE its prefix blocks, so
                # wrapping would corrupt other readers — reject instead
                raise ValueError(
                    f"prompt + max_new ({len(req.prompt_tokens)} + "
                    f"{req.max_new_tokens}) exceeds max_seq {self.max_seq}: "
                    "the paged pool never ring-wraps"
                )
        rec = RequestRecord(
            request_id=req.request_id, client_id=req.client_id,
            priority=req.priority, t_issue=req.t_arrival,
            bytes_in=req.payload_bytes, bytes_out=4 * req.max_new_tokens,
        )
        # modeled ingress: wire + (copy engine for staged transports)
        rec.add("request", self.profile.wire_time(self.transport, rec.bytes_in))
        if self.transport.uses_copy_engine:
            rec.add("copy_in", self.profile.copy_time(rec.bytes_in))
        self._records[req.request_id] = rec
        self.queue.append(req)
        # instant span marking arrival (the modeled ingress charges are
        # attrs, not wall: they never happened on this clock)
        trace.tracer().emit(
            "submit", req.t_arrival, req.t_arrival,
            request_id=req.request_id, bytes_in=rec.bytes_in,
            charge="modeled",
        )

    def _free_slots(self):
        """Admittable slots: the pool's free list minus slots a chunked
        admission has reserved but not yet occupied (its request only
        lands in ``pool.slots`` at the final chunk)."""
        if self._chunk_slots:
            return [s for s in self.pool.free_slots()
                    if s not in self._chunk_slots]
        return self.pool.free_slots()

    @property
    def prefill_compile_count(self) -> int:
        """Distinct prefill shapes compiled so far (bucketed + exact)."""
        return len(self._prefill_shapes) + len(self._prefill_cache)

    def _bucket(self, s: int) -> int:
        return min(max(_next_pow2(s), self.min_bucket), self.max_seq)

    # ------------------------------------------------------------------ #
    # Construction-time warmup: pre-trace the serving shape grid
    # ------------------------------------------------------------------ #
    def bucket_grid(self) -> list:
        """Every pow2 prefill bucket this engine can admit into:
        ``min_bucket, 2*min_bucket, ..., max_seq`` (clamped)."""
        out, L = [], min(self.min_bucket, self.max_seq)
        while True:
            out.append(L)
            if L >= self.max_seq:
                return out
            L = min(L * 2, self.max_seq)

    def packed_grid(self) -> list:
        """Every pow2 packed width a packed admission can dispatch:
        ``min_bucket .. pow2(max_batch * max_seq)``."""
        out, T = [], min(self.min_bucket, self.packed_cap())
        while True:
            out.append(T)
            if T >= self.packed_cap():
                return out
            T = min(T * 2, self.packed_cap())

    def warm(self) -> float:
        """Pre-trace every shape the bucketed serving path can hit, so no
        timed serving stage ever charges an XLA compile.

        Runs the jits for REAL on dummy inputs (jit's executable cache is
        not populated by AOT lowering): one prefill per pow2 bucket, the
        fused admission splice (with every row's slot index out of bounds,
        so nothing is written), and one decode step on the all-done pool
        (whose outputs are discarded and the state re-zeroed). The
        disaggregated tier extends this over its (mode, rows, prefix)
        handoff extent grid via the :meth:`_warm_admit` seam. Returns the
        warm wall seconds — charged to no request stage.

        The exact-shape path (feature payloads / SSM-hybrid stacks)
        compiles per ragged request shape and cannot be pre-traced; under
        ``legacy=True`` this is a no-op (the legacy loop retraces per
        prompt length by design).
        """
        if self.legacy:
            return 0.0
        t0 = time.perf_counter()
        art = None
        if self.bucketed_prefill:
            if self.packed:
                # packed admissions replace the bucket groups entirely:
                # warm the pow2 PACKED width grid instead
                for T in self.packed_grid():
                    art = self._warm_packed(T)
                    if self.paged:
                        self._warm_admit(art)
            else:
                for L in self.bucket_grid():
                    art = self._warm_bucket(L)
                    if self.paged:
                        # paged splice/handoff shapes follow the bucket width
                        # (the suffix cache is never grown to max_seq), so the
                        # admission path warms once per bucket, not once total
                        self._warm_admit(art)
                        if self.prefix_reuse:
                            self._warm_suffix(L)
        if self._chunk_enabled:
            # one chunk + one row-pad compile covers every chunked
            # admission; the artifact is ring-shaped like a bucket's
            art = self._warm_chunk()
        if not self.paged:
            self._warm_admit(art)
        # the decode step compiles once; its ring writes land in rows the
        # next real splice overwrites, but reset anyway for a bit-pristine
        # pool
        self.pool.fill_one(self.decode_params)
        jax.block_until_ready(self.pool.tokens)
        self.pool.reset_state()
        return time.perf_counter() - t0

    def _warm_bucket(self, L: int) -> PrefillArtifact:
        """Compile one pow2 prefill bucket and return the (all-dummy-row)
        artifact — shaped and placed exactly like a real admission's, so
        downstream warm calls hit the same jit cache entries."""
        npad = self.max_batch
        toks = jnp.asarray(np.zeros((npad, L), np.int32))
        lens = jnp.asarray(np.ones((npad,), np.int32))
        if self.paged:
            next_toks, cache1, lens_d = self._prefill_paged_jit(
                self.prefill_params, toks, lens, self.prefill_key
            )
            self._prefill_shapes.add(("paged", L))
            return PrefillArtifact(
                cache1, np.full((npad,), npad, np.int32),  # every row OOB
                lens_d, next_toks, jnp.asarray(np.ones((npad,), np.int32)),
                [], [], n_rows=0, prefix_len=1,
                # dest block 0 = zero sentinel: the splice writes nothing
                dest_blocks=np.zeros((npad, L // self.page), np.int32),
                cached_lens=np.zeros((npad,), np.int32), bucket=L,
            )
        next_toks, cache1, lens_d = self._prefill_bucket_jit(
            self.prefill_params, toks, lens, self.prefill_key
        )
        self._prefill_shapes.add(("bucket", L))
        return PrefillArtifact(
            cache1, np.full((npad,), npad, np.int32),  # every row OOB
            lens_d, next_toks, jnp.asarray(np.ones((npad,), np.int32)),
            [], [], n_rows=0, prefix_len=1,
        )

    def _warm_packed(self, T: int) -> PrefillArtifact:
        """Compile one pow2 packed width and return the all-dummy-row
        artifact (every token pad, every slot OOB), shaped and placed like
        a real packed admission's."""
        npad = self.max_batch
        next_toks, caches = self._prefill_packed_jit(
            self.prefill_params,
            jnp.asarray(np.zeros((1, T), np.int32)),
            jnp.asarray(np.zeros((1, T), np.int32)),
            jnp.asarray(np.full((1, T), -1, np.int32)),
            jnp.asarray(np.zeros((npad,), np.int32)),
            jnp.asarray(np.zeros((npad,), np.int32)),
            self.prefill_key,
        )
        self._prefill_shapes.add(("packed", T))
        lens_d = jnp.asarray(np.ones((npad,), np.int32))
        ones = jnp.asarray(np.ones((npad,), np.int32))
        oob = np.full((npad,), npad, np.int32)
        if self.paged:
            out_w = min(T, self.max_seq)
            return PrefillArtifact(
                caches, oob, lens_d, next_toks, ones, [], [],
                n_rows=0, prefix_len=1,
                dest_blocks=np.zeros((npad, out_w // self.page), np.int32),
                cached_lens=np.zeros((npad,), np.int32), bucket=out_w,
            )
        return PrefillArtifact(caches, oob, lens_d, next_toks, ones, [], [],
                               n_rows=0, prefix_len=1)

    def _warm_chunk(self) -> PrefillArtifact:
        """Compile the chunk + row-pad jits (their shapes never vary) and
        return an all-dummy ring-shaped artifact for the splice warm."""
        npad = self.max_batch
        C = self.prefill_chunk
        next_tok, prior = self._chunk_jit(
            self.prefill_params, self._new_chunk_prior(),
            jnp.asarray(np.zeros((1, C), np.int32)),
            jnp.asarray(np.ones((1,), np.int32)),
            jnp.asarray(np.zeros((1,), np.int32)),
            self.prefill_key,
        )
        caches = self._chunk_pad_jit(prior)
        self._prefill_shapes.add(("chunk", C))
        jax.block_until_ready(next_tok)
        ones = jnp.asarray(np.ones((npad,), np.int32))
        return PrefillArtifact(
            caches, np.full((npad,), npad, np.int32), ones,
            jnp.asarray(np.zeros((npad,), np.int32)), ones, [], [],
            n_rows=0, prefix_len=1,
        )

    def _warm_suffix(self, L: int):
        """Compile the suffix-prefill jit for bucket ``L``: the prior is
        gathered from the pristine block pool through an all-sentinel page
        table (reads zeros), and the output shapes match the plain paged
        bucket's, so the splice jit entry is already warm."""
        npad = self.max_batch
        out = self._prefill_suffix_jit(
            self.prefill_params, self._prior_blocks(),
            jnp.asarray(np.zeros((npad, self.pool.pages_per_seq), np.int32)),
            jnp.asarray(np.zeros((npad, L), np.int32)),
            jnp.asarray(np.ones((npad,), np.int32)),
            jnp.asarray(np.zeros((npad,), np.int32)),
            self.prefill_key,
        )
        jax.block_until_ready(out[0])
        self._prefill_shapes.add(("suffix", L))

    def _warm_admit(self, art: Optional[PrefillArtifact]):
        """Warm the admission path for one all-dummy artifact. The fused
        engine compiles the pool splice; the disaggregated tier overrides
        this to also pre-trace its handoff extent grid."""
        if art is not None:
            self.pool.splice(art)  # all rows OOB: compiles, writes nothing

    # ------------------------------------------------------------------ #
    # Stage seams (overridden by the disaggregated tier)
    # ------------------------------------------------------------------ #
    def _handoff(self, art: PrefillArtifact):
        """Hook between prefill and the decode-pool splice.

        The single-node engine is a no-op. The disaggregated tier moves
        ``art`` across the mesh pod boundary here and returns the handoff
        wall seconds alongside, so the caller charges that time to the
        'transfer' stage instead of 'preprocess'.
        """
        return art, 0.0

    def _ttft_adjust(self, rec: RequestRecord) -> float:
        """Modeled latency folded into ttft/total beyond the measured stamps
        (the disagg tier swaps the measured handoff wall for the
        profile-modeled hop on host-device runs)."""
        return 0.0

    # ------------------------------------------------------------------ #
    # Tracing emitters (core/trace) — all no-ops unless tracing is on
    # ------------------------------------------------------------------ #
    _TRACE_WINDOW_STEPS = 8  # harvested decode steps per window span

    def _trace_admission(self, path: str, reqs: list, t0: float, now: float,
                         dt: float, n: int, **attrs):
        """Per admitted request: the measured queue-wait span (submit ->
        admission pick, exactly the charged 'queue' stage) and the
        prefill span over the admission's dispatch->completion interval
        (each request's charge is its dt/n share, carried as an attr)."""
        tr = trace.tracer()
        if not tr.enabled:
            return
        for req in reqs:
            rec = self._records[req.request_id]
            tr.emit("queue", rec.t_issue, t0, request_id=req.request_id)
            tr.emit(f"prefill.{path}", t0, now, request_id=req.request_id,
                    share_s=dt / max(n, 1), n=n, **attrs)

    def _trace_note_step(self, t_end: float, dt: float, busy: int):
        """Accumulate one harvested decode step into the open decode
        window; flush a ``decode.window`` span every
        ``_TRACE_WINDOW_STEPS`` steps (windowed, never per-step spam).
        Step intervals are contiguous chains of the inference clock
        (``_t_mark``), so the window span's wall is exactly the sum of
        the charged inference walls it covers."""
        if not trace.tracer().enabled:
            return
        if self._win_t0 is None:
            self._win_t0 = t_end - dt
        self._win_end = t_end
        self._win_steps += 1
        self._win_busy += busy
        if self._win_steps >= self._TRACE_WINDOW_STEPS:
            self._trace_flush_window()

    def _trace_flush_window(self):
        """Emit and reset the open decode window (called at the step
        threshold, before every inference-clock reset — prefill
        admissions and idle restarts — and at drain end via
        :meth:`trace_flush`, so a window never spans a gap)."""
        if self._win_t0 is not None and self._win_steps:
            # fixed thread label: window flushes can run on whichever
            # pipeline thread resets the inference clock, but the windows
            # themselves chain one logical timeline per engine
            trace.tracer().emit(
                "decode.window", self._win_t0, self._win_end,
                thread="decode-window", steps=self._win_steps,
                busy_slot_steps=self._win_busy, tag=self.trace_tag,
            )
        self._win_t0 = None
        self._win_steps = 0
        self._win_busy = 0

    def trace_flush(self):
        """Flush any open windowed trace state (drain boundaries)."""
        self._trace_flush_window()

    # ------------------------------------------------------------------ #
    # Metrics registry (core/obs): the query plane over the ad-hoc
    # counter attributes the hot paths charge with bare integer adds
    # ------------------------------------------------------------------ #
    def counters(self) -> dict:
        """The engine's ad-hoc counters as one plain dict."""
        return {
            "prefill_tokens_total": self.prefill_tokens_total,
            "prefill_tokens_uncached": self.prefill_tokens_uncached,
            "prefill_padded_tokens": self.prefill_padded_tokens,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefill_compiles": self.prefill_compile_count,
            "decode_steps": self.decode_steps,
            "useful_steps": self.useful_steps,
            "requests_finished": len(self.store.records),
        }

    def metrics_snapshot(self) -> dict:
        """Counters + live-load gauges absorbed into a fresh
        :class:`~repro.core.obs.Registry` and snapshotted (what
        ``ServingCluster.telemetry()`` embeds per replica)."""
        reg = Registry()
        reg.ingest_counters(self.counters(), prefix="engine.")
        reg.gauge("engine.queue_depth").set(len(self.queue))
        reg.gauge("engine.occupancy").set(
            sum(1 for s in self.pool.slots if s is not None)
        )
        return reg.snapshot()

    # ------------------------------------------------------------------ #
    # Admission
    # ------------------------------------------------------------------ #
    def _admit(self):
        free = self._free_slots()
        if not self.queue or not free:
            return
        order = sorted(
            range(len(self.queue)),
            key=lambda i: (-self.queue[i].priority, i),
        )[: len(free)]
        picked = [self.queue[i] for i in order]
        for i in sorted(order, reverse=True):
            del self.queue[i]

        if self.paged:
            self._admit_paged(picked, free)
            return
        free_it = iter(free)
        if not self.bucketed_prefill:
            # exact-shape path still initializes the device-side decode
            # state (gen/done/max_new) — _prefill_one is legacy-loop-only.
            for req in picked:
                self._prefill_exact(next(free_it), req)
            return
        packables: list[Request] = []
        buckets: dict[int, list[Request]] = {}
        for req in picked:
            if req.features is not None:  # ragged feature payloads: exact path
                self._prefill_exact(next(free_it), req)
            elif (self._chunk_enabled
                  and len(req.prompt_tokens) > self.prefill_chunk):
                # long prompts admit chunk-by-chunk, one chunk per engine
                # iteration, interleaved with decode dispatches
                self._chunk_admit(req, next(free_it))
            elif self.packed:
                packables.append(req)
            else:
                buckets.setdefault(self._bucket(len(req.prompt_tokens)), []).append(req)
        if packables:
            self._prefill_packed(packables, [next(free_it) for _ in packables])
        for L, reqs in buckets.items():
            self._prefill_bucket(L, reqs, [next(free_it) for _ in reqs])

    def _prefill_bucket(self, L: int, reqs: list, slots: list):
        """One padded prefill + fused splice for every request in a bucket.

        The batch dim is padded to a FIXED width (max_batch, the most an
        admission can hold), so the prefill compile count is exactly the
        number of length buckets — O(log max_seq) — with no batch-size
        shape axis."""
        n = len(reqs)
        npad = self.max_batch
        toks = np.zeros((npad, L), np.int32)
        lens = np.zeros((npad,), np.int32)
        maxn = np.zeros((npad,), np.int32)
        slot_idx = np.full((npad,), self.max_batch, np.int32)  # OOB => dropped
        for j, (req, slot) in enumerate(zip(reqs, slots)):
            s = len(req.prompt_tokens)
            toks[j, :s] = req.prompt_tokens
            lens[j] = s
            maxn[j] = req.max_new_tokens
            slot_idx[j] = slot
        self.prefill_tokens_total += int(lens[:n].sum())
        self.prefill_tokens_uncached += int(lens[:n].sum())
        self.prefill_padded_tokens += npad * L
        t0 = time.perf_counter()
        next_toks, cache1, lens_d = self._prefill_bucket_jit(
            self.prefill_params, jnp.asarray(toks), jnp.asarray(lens),
            self._next_prefill_key(),
        )
        art = PrefillArtifact(cache1, slot_idx, lens_d, next_toks,
                              jnp.asarray(maxn), reqs, list(slots),
                              n_rows=n, prefix_len=int(lens.max()))
        art, t_xfer = self._handoff(art)  # disagg: pod-boundary KV handoff
        self.pool.splice(art)
        toks_host = np.asarray(art.next_tokens)  # reprolint: disable=RL001 deliberate fence: 'preprocess' must include prefill device completion
        dt = max(time.perf_counter() - t0 - t_xfer, 0.0)
        self._prefill_shapes.add(("bucket", L))
        now = time.perf_counter()
        self._trace_flush_window()  # decode windows never span a prefill
        self._trace_admission("bucket", reqs, t0, now, dt, n, bucket=L)
        for j, (req, slot) in enumerate(zip(reqs, slots)):
            rec = self._records[req.request_id]
            # pre-admission wait: submit -> this admission picking the
            # request. Measured wall inside [t_issue, t_done], so
            # total_s >= sum(stage_s) still holds.
            rec.add("queue", max(t0 - rec.t_issue, 0.0))
            rec.add("preprocess", dt / n)  # prefill = serving "preprocessing"
            req.generated.append(int(toks_host[j]))
            req.t_first_token = now
            self._place(req, slot)
        self._t_mark = now  # prefill time is "preprocess", not "inference"

    def _prefill_packed(self, reqs: list, slots: list, jobs: list = None):
        """One token-packed prefill for every admitted prompt.

        Prompts concatenate back to back into a single [1, T] sequence
        (T = pow2 of the TOTAL true tokens, clamped to min_bucket), so a
        ragged admission pays for the tokens it actually has instead of
        rows x bucket width. Segment ids forbid cross-prompt attention and
        segment-relative positions keep RoPE bitwise identical to the
        unpacked run; the in-jit unpack emits the same bucketed-shaped
        artifact every downstream path (splice, disagg handoff, paged
        scatter) already consumes.

        ``jobs`` is the paged admission's planned block rows; counters for
        that path were already charged by :meth:`_admit_paged`.
        """
        n = len(reqs)
        npad = self.max_batch
        total = sum(len(r.prompt_tokens) for r in reqs)
        T = min(max(_next_pow2(total), self.min_bucket), self.packed_cap())
        toks = np.zeros((1, T), np.int32)
        pos = np.zeros((1, T), np.int32)
        seg = np.full((1, T), -1, np.int32)  # -1 = pad: matches nothing
        seg_starts = np.zeros((npad,), np.int32)
        last_idx = np.zeros((npad,), np.int32)
        lens = np.zeros((npad,), np.int32)
        maxn = np.zeros((npad,), np.int32)
        slot_idx = np.full((npad,), npad, np.int32)  # OOB => dropped
        off = 0
        for j, (req, slot) in enumerate(zip(reqs, slots)):
            s = len(req.prompt_tokens)
            toks[0, off:off + s] = req.prompt_tokens
            pos[0, off:off + s] = np.arange(s)
            seg[0, off:off + s] = j
            seg_starts[j] = off
            last_idx[j] = off + s - 1
            lens[j] = s
            maxn[j] = req.max_new_tokens
            slot_idx[j] = slot
            off += s
        # dummy rows keep seg_starts/last_idx 0: their unpacked rows and
        # logits are garbage the OOB slot scatter drops
        if jobs is None:
            self.prefill_tokens_total += total
            self.prefill_tokens_uncached += total
        self.prefill_padded_tokens += T
        t0 = time.perf_counter()
        next_toks, caches = self._prefill_packed_jit(
            self.prefill_params, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(seg), jnp.asarray(seg_starts),
            jnp.asarray(last_idx), self._next_prefill_key(),
        )
        self._prefill_shapes.add(("packed", T))
        if self.paged:
            out_w = min(T, self.max_seq)
            dest = np.zeros((npad, out_w // self.page), np.int32)
            for j, job in enumerate(jobs):
                for k in range(out_w // self.page):
                    if k < len(job.pt_row):
                        dest[j, k] = job.pt_row[k]
            art = PrefillArtifact(
                caches, slot_idx, jnp.asarray(lens), next_toks,
                jnp.asarray(maxn), reqs, list(slots),
                n_rows=n, prefix_len=int(lens.max()),
                dest_blocks=dest, cached_lens=np.zeros((npad,), np.int32),
                bucket=out_w,
            )
        else:
            art = PrefillArtifact(
                caches, slot_idx, jnp.asarray(lens), next_toks,
                jnp.asarray(maxn), reqs, list(slots),
                n_rows=n, prefix_len=int(lens.max()),
            )
        art, t_xfer = self._handoff(art)  # disagg: pod-boundary handoff
        self.pool.splice(art)
        toks_host = np.asarray(art.next_tokens)  # reprolint: disable=RL001 deliberate fence: packed 'preprocess' includes prefill device completion
        dt = max(time.perf_counter() - t0 - t_xfer, 0.0)
        now = time.perf_counter()
        self._trace_flush_window()
        self._trace_admission("packed", reqs, t0, now, dt, n, packed_width=T)
        for j, (req, slot) in enumerate(zip(reqs, slots)):
            rec = self._records[req.request_id]
            rec.add("queue", max(t0 - rec.t_issue, 0.0))
            rec.add("preprocess", dt / n)
            req.generated.append(int(toks_host[j]))
            req.t_first_token = now
            self._place(req, slot)
        self._t_mark = now

    def packed_cap(self) -> int:
        """Widest packed sequence this engine can dispatch: every slot
        admitted at once, each at a full max_seq prompt, rounded to pow2."""
        return _next_pow2(self.max_batch * self.max_seq)

    # ------------------------------------------------------------------ #
    # Chunked prefill: fixed-width chunks interleaved with decode steps
    # ------------------------------------------------------------------ #
    def _chunk_admit(self, req: Request, slot: int):
        """Reserve ``slot`` and enqueue the request as a chunk job; the
        prompt prefills ``prefill_chunk`` tokens per engine iteration from
        :meth:`_chunk_step` until the final chunk splices it in."""
        self._chunk_slots.add(slot)
        self._chunk_jobs.append(_ChunkJob(req, slot, self._new_chunk_prior()))
        P = len(req.prompt_tokens)
        self.prefill_tokens_total += P
        self.prefill_tokens_uncached += P

    def _chunk_step(self):
        """Run ONE chunk of the oldest chunk job (called once per engine
        iteration, after decode dispatch, so live slots' decode steps are
        already queued ahead of the chunk on the device stream).

        The REMAINDER chunk runs FIRST (sizes r, C, C, ..., C with
        r = ((P-1) % C) + 1): every later chunk is exactly C wide, so the
        final chunk's logits gather at a fixed index and no splice can
        overrun the prior (done + C <= P <= max_seq always). The first
        chunk's pad-token rows write garbage KV beyond r that the next
        chunk's splice overwrites; ``prior_valid`` masks them meanwhile.
        """
        if not self._chunk_jobs:
            return
        job = self._chunk_jobs[0]
        C = self.prefill_chunk
        P = len(job.req.prompt_tokens)
        t0 = time.perf_counter()
        rec = self._records[job.req.request_id]
        if job.done == 0:
            # pre-admission wait ends at the first chunk's dispatch
            rec.add("queue", max(t0 - rec.t_issue, 0.0))
            trace.tracer().emit("queue", rec.t_issue, t0,
                                request_id=job.req.request_id)
        n = ((P - 1) % C) + 1 if job.done == 0 else C
        toks = np.zeros((1, C), np.int32)
        toks[0, :n] = job.req.prompt_tokens[job.done:job.done + n]
        next_tok, job.prior = self._chunk_jit(
            self.prefill_params, job.prior, jnp.asarray(toks),
            jnp.asarray(np.asarray([n], np.int32)),
            jnp.asarray(np.asarray([job.done], np.int32)),
            self._next_prefill_key(),
        )
        job.done += n
        self.prefill_padded_tokens += C
        self._prefill_shapes.add(("chunk", C))
        if job.done < P:
            np.asarray(next_tok)  # reprolint: disable=RL001 deliberate fence: chunk 'preprocess' includes device completion (and bounds host run-ahead to one chunk)
            t1 = time.perf_counter()
            rec.add("preprocess", max(t1 - t0, 0.0))
            trace.tracer().emit("prefill.chunk", t0, t1,
                                request_id=job.req.request_id,
                                chunk=C, done=job.done, prompt=P)
            return
        # final chunk: shape the prior into a standard bucketed-style
        # artifact (row dim padded to npad, OOB dummy rows) and splice
        self._chunk_jobs.popleft()
        self._chunk_slots.discard(job.slot)
        npad = self.max_batch
        caches = self._chunk_pad_jit(job.prior)
        job.prior = None  # donated away
        slot_idx = np.full((npad,), npad, np.int32)
        slot_idx[0] = job.slot
        lens = np.zeros((npad,), np.int32)
        lens[0] = P
        maxn = np.zeros((npad,), np.int32)
        maxn[0] = job.req.max_new_tokens
        tok0 = int(np.asarray(next_tok)[0])  # reprolint: disable=RL001 deliberate fence: final-chunk 'preprocess' includes device completion
        next_full = np.zeros((npad,), np.int32)
        next_full[0] = tok0
        art = PrefillArtifact(
            caches, slot_idx, jnp.asarray(lens), jnp.asarray(next_full),
            jnp.asarray(maxn), [job.req], [job.slot],
            n_rows=1, prefix_len=P,
        )
        art, t_xfer = self._handoff(art)  # disagg: pod-boundary handoff
        self.pool.splice(art)
        dt = max(time.perf_counter() - t0 - t_xfer, 0.0)
        rec.add("preprocess", dt)
        job.req.generated.append(tok0)
        now = time.perf_counter()
        self._trace_flush_window()
        trace.tracer().emit("prefill.chunk", t0, now,
                            request_id=job.req.request_id,
                            chunk=C, done=job.done, prompt=P, final=True)
        job.req.t_first_token = now
        self._place(job.req, job.slot)
        self._t_mark = now  # chunk time is "preprocess", not "inference"

    def _prefill_exact(self, slot: int, req: Request):
        """Exact-shape prefill for feature-carrying (vlm/audio) requests."""
        toks = jnp.asarray(req.prompt_tokens, jnp.int32)[None, :]
        batch = {"tokens": toks}
        if req.features is not None:
            batch["features"] = jnp.asarray(req.features)
        self.prefill_tokens_total += len(req.prompt_tokens)
        self.prefill_tokens_uncached += len(req.prompt_tokens)
        self.prefill_padded_tokens += len(req.prompt_tokens)
        t0 = time.perf_counter()
        logits, cache1, lengths1 = self._prefill_exact_jit(
            self.prefill_params, batch
        )
        # eager sample (the exact path compiles per ragged shape anyway);
        # temperature 0 stays the argmax baseline bit-for-bit
        next_tok = self.pool._sample(logits, self._next_prefill_key())
        # feature frames (vlm) prepend to the token sequence, so the cache's
        # true length is frames + prompt — len(prompt_tokens) alone would
        # let a pod handoff slice live KV off the wire. Derived host-side
        # (no device sync on the single-node hot path); the disagg feature
        # regression test pins it against the model-returned lengths.
        frames = 0 if req.features is None else int(np.shape(req.features)[-2])
        art = PrefillArtifact(
            cache1, np.asarray([slot], np.int32), lengths1, next_tok,
            jnp.asarray([req.max_new_tokens], jnp.int32), [req], [slot],
            n_rows=1, prefix_len=len(req.prompt_tokens) + frames,
        )
        art, t_xfer = self._handoff(art)
        self.pool.splice(art)
        tok_host = int(np.asarray(art.next_tokens)[0])  # reprolint: disable=RL001 deliberate fence: exact-path 'preprocess' includes device completion
        dt = max(time.perf_counter() - t0 - t_xfer, 0.0)
        self._prefill_shapes.add(
            ("exact", toks.shape[1],
             None if req.features is None else np.shape(req.features))
        )
        rec = self._records[req.request_id]
        rec.add("queue", max(t0 - rec.t_issue, 0.0))  # submit -> admission
        rec.add("preprocess", dt)
        req.generated.append(tok_host)
        req.t_first_token = time.perf_counter()
        self._trace_flush_window()
        self._trace_admission("exact", [req], t0, req.t_first_token, dt, 1,
                              prompt=len(req.prompt_tokens))
        self._place(req, slot)
        self._t_mark = req.t_first_token  # prefill time is not "inference"

    # ------------------------------------------------------------------ #
    # Paged admission: prefix match -> block plan -> grouped prefill
    # ------------------------------------------------------------------ #
    def _admit_paged(self, picked: list, free: list):
        """Plan every picked request's page table, then prefill in groups.

        All prefix MATCHES happen before any INSERT, so two requests
        sharing a prefix admitted in the same batch can't false-match
        pages whose KV this very admission is still computing — the second
        request recomputes the shared prefix once; reuse starts at the
        next admission. Matched blocks are refcount-pinned here (the
        d-side for the row's lifetime, the p-side until its suffix jit
        has the prior in hand), so index eviction under pool pressure can
        never free KV a picked request is about to read.
        """
        page = self.page
        jobs: list[_PagedJob] = []
        for req, slot in zip(picked, free):
            P = len(req.prompt_tokens)
            p_ids: list = []
            d_ids: list = []
            cached = 0
            if self.prefix_reuse:
                # cap the match below the full prompt: at least one suffix
                # token must remain to produce the first-token logits
                payloads = self.prefix_index.match(
                    req.prompt_tokens, (P - 1) // page
                )
                if payloads:
                    cached = len(payloads) * page
                    p_ids = [p for (p, _) in payloads]
                    d_ids = [d for (_, d) in payloads]
                    self._store_alloc().ref(p_ids)
                    self.pool.allocator.ref(d_ids)
                    self.prefix_hits += 1
                    self.prefix_hit_tokens += cached
            n_pages = -(-(P + req.max_new_tokens) // page)
            own = self._alloc_blocks(n_pages - cached // page)
            pt_row = d_ids + own
            self.pool.set_row(slot, pt_row)
            self.prefill_tokens_total += P
            self.prefill_tokens_uncached += P - cached
            jobs.append(_PagedJob(req, slot, cached, p_ids, d_ids, own,
                                  pt_row))
        if self.packed:
            # prefix reuse is off under packing (cached == 0 for every
            # job): one packed dispatch replaces the bucket groups
            self._prefill_packed(
                [job.req for job in jobs], [job.slot for job in jobs],
                jobs=jobs,
            )
            return
        groups: dict[tuple, list[_PagedJob]] = {}
        for job in jobs:
            L = self._bucket(len(job.req.prompt_tokens) - job.cached)
            groups.setdefault((L, job.cached > 0), []).append(job)
        for (L, has_prior), gjobs in sorted(groups.items()):
            self._prefill_paged_group(L, has_prior, gjobs)

    def _alloc_blocks(self, n: int) -> list:
        """Allocate ``n`` fresh blocks, LRU-evicting cold prefix-index
        pages under pool pressure. Eviction only drops the INDEX's
        references — a block a live row still reads is refcount-protected
        and stays resident until its last reader releases it."""
        while True:
            got = self.pool.allocator.alloc(n)
            if got is not None:
                return got
            payload = (self.prefix_index.evict_lru()
                       if self.prefix_reuse else None)
            if payload is None:
                raise RuntimeError(
                    "paged KV pool exhausted with no evictable prefix "
                    "pages; raise cache_blocks or lower max_batch"
                )
            self._evict_index_page(payload)

    def _evict_index_page(self, payload):
        """Drop the index's references on one evicted page (fused engine:
        both payload sides name the same decode-pool block)."""
        p, d = payload
        self._store_alloc().deref([p])
        self.pool.allocator.deref([d])

    def _index_insert(self, jobs: list, store_ctx):
        """Index each admitted prompt's fully-in-prompt pages.

        Existing pages keep their first writer's blocks (matches ref THOSE
        at admission); only newly-created nodes take references — one per
        payload side — so the index keeps a released slot's prefix KV
        alive for future hits. A row whose matched interior was LRU-evicted
        during this very admission's allocations is skipped: its chain
        would root orphaned payloads the index can no longer reach.
        """
        if not self.prefix_reuse:
            return
        for job in jobs:
            toks = job.req.prompt_tokens
            n_ins = len(toks) // self.page
            if n_ins == 0:
                continue
            depth = len(self.prefix_index.match(toks, n_ins, peek=True))
            if depth < job.cached // self.page:
                continue
            payloads = [(job.pt_row[i], job.pt_row[i])
                        for i in range(n_ins)]
            created = self.prefix_index.insert(toks, payloads, n_ins)
            for (p, d) in created:
                self._store_alloc().ref([p])
                self.pool.allocator.ref([d])

    # hooks the disaggregated tier overrides: the prior side of a reused
    # prefix lives wherever prefill runs (fused: the decode pool itself;
    # disagg: a prefill-pod block store, so suffix prefill never re-crosses
    # the pod boundary for prefix KV)
    def _store_alloc(self):
        return self.pool.allocator

    def _store_deref(self, ids: list):
        self.pool.allocator.deref(ids)

    def _prior_blocks(self):
        return self.pool.blocks

    def _store_prepare(self, jobs: list, caches, L: int):
        """Seam before the handoff plans wire bytes (disagg stashes the
        suffix cache into the prefill-side store here). Fused: no-op."""
        return None

    def prefix_lookup_tokens(self, tokens) -> int:
        """Router scoring hook: matched prefix length in tokens, LRU- and
        counter-neutral (a peek, not a hit). 0 when reuse is off."""
        if not self.prefix_reuse:
            return 0
        return self.prefix_index.lookup_tokens(tokens)

    def _prefill_paged_group(self, L: int, has_prior: bool, jobs: list):
        """One padded (suffix-)prefill + paged splice for a group of
        admissions sharing a suffix bucket.

        Groups with no reused prefix run the plain paged prefill — bitwise
        the ring bucket path's math. Groups with a prior gather it from the
        block pool inside the suffix jit. Either way the artifact carries
        the bucket-width SUFFIX cache only: reused prefix KV never moves
        again (and, disaggregated, never re-rides the wire).
        """
        page = self.page
        n = len(jobs)
        npad = self.max_batch
        toks = np.zeros((npad, L), np.int32)
        lens = np.zeros((npad,), np.int32)
        cached = np.zeros((npad,), np.int32)
        maxn = np.zeros((npad,), np.int32)
        slot_idx = np.full((npad,), self.max_batch, np.int32)  # OOB => drop
        dest = np.zeros((npad, L // page), np.int32)  # 0 => sentinel drop
        prior_pt = np.zeros((npad, self.pool.pages_per_seq), np.int32)
        for j, job in enumerate(jobs):
            suffix = job.req.prompt_tokens[job.cached:]
            s = len(suffix)
            toks[j, :s] = suffix
            lens[j] = s
            cached[j] = job.cached
            maxn[j] = job.req.max_new_tokens
            slot_idx[j] = job.slot
            cpages = job.cached // page
            for k in range(L // page):
                if cpages + k < len(job.pt_row):
                    dest[j, k] = job.pt_row[cpages + k]
            prior_pt[j, : len(job.p_ids)] = job.p_ids
        self.prefill_padded_tokens += npad * L
        t0 = time.perf_counter()
        key = self._next_prefill_key()
        if has_prior:
            next_toks, cacheL, lens_d = self._prefill_suffix_jit(
                self.prefill_params, self._prior_blocks(),
                jnp.asarray(prior_pt), jnp.asarray(toks), jnp.asarray(lens),
                jnp.asarray(cached), key,
            )
            self._prefill_shapes.add(("suffix", L))
            # the p-pins held the gathered prior across the dispatch; the
            # page-table row (d-side) keeps the row's own hold from here
            for job in jobs:
                self._store_deref(job.p_ids)
        else:
            next_toks, cacheL, lens_d = self._prefill_paged_jit(
                self.prefill_params, jnp.asarray(toks), jnp.asarray(lens),
                key,
            )
            self._prefill_shapes.add(("paged", L))
        store_ctx = self._store_prepare(jobs, cacheL, L)
        art = PrefillArtifact(
            cacheL, slot_idx, lens_d, next_toks, jnp.asarray(maxn),
            [job.req for job in jobs], [job.slot for job in jobs],
            n_rows=n, prefix_len=int((cached + lens).max()),
            dest_blocks=dest, cached_lens=cached, bucket=L,
        )
        art, t_xfer = self._handoff(art)  # disagg: pod-boundary handoff
        self.pool.splice(art)
        toks_host = np.asarray(art.next_tokens)  # reprolint: disable=RL001 deliberate fence: paged 'preprocess' includes prefill device completion
        dt = max(time.perf_counter() - t0 - t_xfer, 0.0)
        # index the prompts' pages BEFORE the records loop: a request the
        # prefill token already finishes releases its slot there, and the
        # index must take its block references first
        self._index_insert(jobs, store_ctx)
        now = time.perf_counter()
        self._trace_flush_window()
        self._trace_admission(
            "suffix" if has_prior else "paged",
            [job.req for job in jobs], t0, now, dt, n, bucket=L,
        )
        for j, job in enumerate(jobs):
            rec = self._records[job.req.request_id]
            rec.add("queue", max(t0 - rec.t_issue, 0.0))
            rec.add("preprocess", dt / n)
            job.req.generated.append(int(toks_host[j]))
            job.req.t_first_token = now
            self._place(job.req, job.slot)
        self._t_mark = now

    def _place(self, req: Request, slot: int):
        """Occupy ``slot`` — or, if the prefill token already exhausted the
        budget (max_new_tokens <= 1), finish the request right away (the
        legacy loop instead runs one decode step and returns 2 tokens; the
        fast path honors the budget)."""
        if req.max_new_tokens <= 1:
            # never occupies the slot, so no in-flight snapshot can
            # reference it — no _finished_ids entry needed. Paged rows
            # still release their page-table hold (the prefix index has
            # already taken its own references by this point).
            self.pool.release_slot(slot)
            self._prefill_finished.append(
                self._finish(req, self._records[req.request_id])
            )
            return
        self.pool.slots[slot] = req

    # ------------------------------------------------------------------ #
    # Decode: async dispatch window + single-transfer harvest
    # ------------------------------------------------------------------ #
    def _window_limit(self) -> Optional[int]:
        """Adaptive dispatch depth: the max outstanding token budget among
        live slots. Steps dispatched beyond it cannot advance any request
        (every slot's device-side done flag freezes first), so they are
        pure waste — the fixed window paid up to inflight-1 of them per
        finishing request. EOS can still finish a request earlier than its
        budget; the cap only removes the waste the budget proves."""
        if not self.adaptive_window:
            return None
        out = [
            req.max_new_tokens - len(req.generated)
            for req in self.pool.slots if req is not None
        ]
        return max(out, default=0)

    def _dispatch(self, outstanding: int = 0):
        """Top up the in-flight window. ``outstanding`` is the number of
        steps already popped from the window but not yet finalized (the
        threaded pipeline's harvest/detokenize backlogs); the inference
        clock only restarts when the device is genuinely idle — window
        empty AND nothing in the backlogs."""
        if self.pool.all_free:
            return
        if not self.pool.window and outstanding == 0:
            # pipeline (re)start: don't charge idle time to "inference"
            self._trace_flush_window()  # a window never spans an idle gap
            self._t_mark = time.perf_counter()
        limit = self._window_limit()
        while self.pool.fill_one(self.decode_params, limit=limit):
            self.decode_steps += 1

    def _harvest(self) -> list[Response]:
        """Synchronous harvest: device transfer + finalize in one call (the
        ``step()`` path). The threaded pipeline runs the same two stages on
        separate threads — :class:`EnginePipeline` pops the entry, moves the
        device transfer onto its harvest thread, and hands
        :meth:`_finalize_harvest` to its detokenize thread."""
        e = self.pool.pop_oldest()
        if e is None:
            return []
        toks, _done = jax.device_get((e.tokens, e.done))  # one host transfer
        now = time.perf_counter()
        dt = max(now - self._t_mark, 0.0)
        self._t_mark = now
        return self._finalize_harvest(e, toks, dt)

    def _finalize_harvest(self, e: _InFlight, toks, dt: float) -> list[Response]:
        """Detokenize/record-finalize stage: pure host bookkeeping over one
        harvested step's tokens — per-request records, EOS/budget checks,
        slot release. No device work happens here, which is what lets the
        threaded pipeline run it concurrently with the next dispatch."""
        live = [
            (i, r) for i, r in enumerate(e.slots)
            if r is not None and r.request_id not in self._finished_ids
        ]
        if live:
            self.useful_steps += 1
        self._trace_note_step(self._t_mark, dt, len(live))
        done: list[Response] = []
        for i, req in live:
            rec = self._records[req.request_id]
            rec.add("inference", dt / len(live))
            tok = int(toks[i, 0])
            req.generated.append(tok)
            finished = len(req.generated) >= req.max_new_tokens or (
                self.eos is not None and tok == self.eos
            )
            if finished:
                done.append(self._finish(req, rec))
                self._finished_ids.add(req.request_id)
                if self.pool.slots[i] is req:
                    self.pool.slots[i] = None
                    # paged: drop the row's block references (safe while
                    # stale in-flight steps remain — their frozen-lane
                    # writes are dispatched before any splice that could
                    # reuse a freed block, and device order is dispatch
                    # order)
                    self.pool.release_slot(i)
        if done and self._finished_ids:
            # ids only matter while an in-flight snapshot still references
            # them — prune so the set stays O(max_batch * inflight). The
            # threaded pipeline holds popped-but-unfinalized entries in
            # ``_backlog_entries``; their snapshots count as in-flight too,
            # or a stale step could double-finish a pruned request.
            live_ids = {
                r.request_id
                for ent in (*self.pool.window, *self._backlog_entries)
                for r in ent.slots if r is not None
            }
            self._finished_ids &= live_ids
        return done

    def _finish(self, req: Request, rec: RequestRecord) -> Response:
        rsp_wire = self.profile.wire_time(self.transport, rec.bytes_out)
        rec.add("response", rsp_wire)
        egress = rsp_wire
        if self.transport.uses_copy_engine:
            copy_out = self.profile.copy_time(rec.bytes_out)
            rec.add("copy_out", copy_out)
            egress += copy_out
        # the modeled ingress stages (request wire + copy_in) were charged
        # to stage_s at submit but never reached the latency stamps, while
        # the egress wire was folded into total only — include BOTH hops
        # symmetrically so total_s >= sum(stage_s) holds end to end
        ingress = (rec.stage_s.get("request", 0.0)
                   + rec.stage_s.get("copy_in", 0.0))
        adj = self._ttft_adjust(rec)
        rec.t_done = time.perf_counter() + ingress + egress + adj
        req.t_done = rec.t_done
        if self.debug_stamps:
            trace.validate_stamps(
                req.t_arrival, req.t_first_token, req.t_done,
                where=f"request {req.request_id} at finish",
            )
        # root span: the whole request interval (modeled ingress/egress
        # folded into t_done bounds every charged stage, measured or not)
        trace.tracer().emit(
            "request", rec.t_issue, rec.t_done, request_id=req.request_id,
            tokens=len(req.generated), bytes_in=rec.bytes_in,
            bytes_out=rec.bytes_out,
        )
        self.store.add(rec)
        return Response(
            request_id=req.request_id,
            tokens=list(req.generated),
            ttft_s=req.t_first_token - req.t_arrival + ingress + adj,
            total_s=rec.t_done - rec.t_issue,
            stage_s=dict(rec.stage_s),
        )

    # ------------------------------------------------------------------ #
    def step(self) -> list[Response]:
        """One continuous-batching iteration. Returns finished responses.

        Fast path: top up the in-flight window (dispatch-ahead, no sync),
        then harvest the OLDEST dispatched step — the host runs up to
        ``inflight`` steps behind the device and never blocks on the newest
        work.
        """
        if self.legacy:
            return self._step_legacy()
        self._admit()
        self._dispatch()
        # one chunk AFTER the decode top-up: live slots' steps are already
        # on the device stream, so the chunk interleaves instead of
        # head-of-line blocking a full prefill
        self._chunk_step()
        done = self._harvest()
        if self._prefill_finished:  # budget met by the prefill token itself
            done = self._prefill_finished + done
            self._prefill_finished = []
        return done

    @property
    def idle(self) -> bool:
        """No queued requests, no occupied slots, no in-flight steps —
        the drain condition, shared with the cluster tier's router and
        the open-loop load generator."""
        return (not self.queue and self.pool.all_free
                and not self.pool.window and not self._chunk_jobs)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Response]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if self.idle:
                break
        self.trace_flush()
        return out

    # ------------------------------------------------------------------ #
    # Legacy synchronous loop (seed behavior): the A/B baseline.
    # ------------------------------------------------------------------ #
    def _prefill_one(self, slot: int, req: Request):  # reprolint: disable=RL001 legacy A/B baseline: the seed loop blocks per token by design
        S = len(req.prompt_tokens)
        toks = jnp.asarray(req.prompt_tokens, jnp.int32)[None, :]
        batch = {"tokens": toks}
        if req.features is not None:
            batch["features"] = jnp.asarray(req.features)
        key = (S, req.features is not None)
        if key not in self._prefill_cache:
            self._prefill_cache[key] = jax.jit(  # reprolint: disable=RL005 legacy loop retraces per (S, features) key by design — the measured A/B baseline
                lambda p, b: self.model.prefill(p, b)
            )
        t0 = time.perf_counter()
        logits, cache1, lengths1 = self._prefill_cache[key](self.params, batch)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        rec = self._records[req.request_id]
        rec.add("queue", max(t0 - rec.t_issue, 0.0))  # submit -> admission
        rec.add("preprocess", dt)

        cache1 = kvc.grow_cache(cache1, self.max_seq)

        # splice the single-sequence cache into the pool at `slot`
        def splice_group(pool, one, stacked):
            if stacked:  # [L, B, ...]
                return jax.tree.map(
                    lambda p, n: p.at[:, slot].set(n[:, 0].astype(p.dtype)),
                    pool, one,
                )
            return jax.tree.map(
                lambda p, n: p.at[slot].set(n[0].astype(p.dtype)), pool, one,
            )

        self.caches = {
            f"g{gi}": splice_group(
                self.caches[f"g{gi}"], cache1[f"g{gi}"], g.count > 1
            )
            for gi, g in enumerate(self.model.groups)
        }
        self.lengths = self.lengths.at[slot].set(int(lengths1[0]))
        next_tok = int(jnp.argmax(logits[0]))
        self.tokens = self.tokens.at[slot, 0].set(next_tok)
        req.generated.append(next_tok)
        self.pool.slots[slot] = req
        req.t_first_token = time.perf_counter()
        self._trace_admission("legacy", [req], t0, req.t_first_token, dt, 1)

    def _admit_legacy(self):
        while self.queue and self._free_slots():
            best = max(range(len(self.queue)), key=lambda i: self.queue[i].priority)
            req = self.queue[best]
            del self.queue[best]
            self._prefill_one(self._free_slots()[0], req)

    def _step_legacy(self) -> list[Response]:  # reprolint: disable=RL001 legacy A/B baseline: the seed loop blocks per token by design
        """Seed loop: host sync + host argmax + per-slot Python loop.

        Kept byte-faithful to the seed, including its max_new_tokens=1
        quirk (always runs one decode step, returning 2 tokens); the fast
        path finishes such requests at prefill time instead.
        """
        self._admit_legacy()
        active = [i for i, s in enumerate(self.pool.slots) if s is not None]
        if not active:
            return []
        t0 = time.perf_counter()
        logits, self.caches, self.lengths = self._decode(
            self.params, self.caches, self.tokens, self.lengths
        )
        self.decode_steps += 1
        self.useful_steps += 1  # sync loop only ever steps live slots
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self._trace_note_step(t0 + dt, dt, len(active))
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        self.tokens = jnp.asarray(next_tokens[:, None], jnp.int32)

        done: list[Response] = []
        for i in active:
            req = self.pool.slots[i]
            rec = self._records[req.request_id]
            rec.add("inference", dt / max(len(active), 1))
            tok = int(next_tokens[i])
            req.generated.append(tok)
            finished = len(req.generated) >= req.max_new_tokens or (
                self.eos is not None and tok == self.eos
            )
            if finished:
                done.append(self._finish(req, rec))
                self.pool.slots[i] = None
        return done


class EnginePipeline:
    """Threaded host pipeline over a (fast-path) :class:`ServingEngine`.

    The single-threaded ``step()`` loop interleaves three host jobs —
    admission+dispatch, the blocking device->host harvest transfer, and
    per-token record bookkeeping — on one thread, so the device waits
    whenever the host is busy detokenizing. This class decouples them onto
    three daemon threads joined by BOUNDED backlog queues, the
    JetStream-style shape (dispatch / device harvest / detokenize backlog):

      dispatch thread   : admits queued requests (prefill + splice) and
                          tops up the in-flight decode window, then moves
                          the oldest dispatched step onto the harvest
                          backlog. All jit dispatch happens here.
      harvest thread    : ``jax.device_get`` of each step's tokens+done —
                          the only stage that blocks on the device.
      detokenize thread : :meth:`ServingEngine._finalize_harvest` — record
                          bookkeeping, EOS/budget checks, slot release,
                          response finalization.

    Each queue edge has a single producer and a single consumer and every
    queue is FIFO, so steps are finalized in dispatch order: records can
    neither reorder nor drop (``submitted``/``emitted`` count the
    conservation invariant, asserted in tests). When detokenize falls
    behind, the harvest thread blocks on its bounded put and dispatch
    blocks in turn — backpressure, never loss.

    The facade stays step()-compatible with a single engine (``submit`` /
    ``step`` / ``queue`` / ``store`` / ``_records`` / ``idle`` /
    ``run_until_drained``), so the Gateway, the load generators, and the
    cluster Router drive it unchanged; ``step()`` just drains finished
    responses (``async_draining = True`` tells the open-loop driver that
    stepping is not what makes progress, so it may sleep instead of spin).
    Engine state is guarded by one lock; the device transfer and the queue
    hand-offs run outside it. Thread failures are captured and re-raised
    on the caller's next ``submit``/``step``/``idle`` touch, so a broken
    pipeline surfaces instead of hanging.

    This is the per-replica host pipeline of the process-per-replica
    cluster tier: ``serving/worker.py`` runs one of these inside each
    replica process behind the socket RPC control plane (serving/ipc.py).
    """

    # tools/reprolint RL003 contract: these attributes are only touched
    # under `with self._lock`, and nothing blocks while the lock is held
    # (a blocking put under the lock is the deadlock shape: a full queue
    # parks every thread that needs the lock)
    _REPROLINT_GUARDED = (
        "_outputs", "_outstanding", "submitted", "emitted",
        "submitted_bytes", "steps", "busy_slot_steps",
    )

    def __init__(self, engine: ServingEngine, *, backlog: int = 2,
                 poll_s: float = 0.0005):
        if engine.legacy:
            raise ValueError(
                "EnginePipeline requires the fast path (the legacy loop "
                "is synchronous by design)"
            )
        if backlog < 1:
            raise ValueError(f"backlog must be >= 1: {backlog}")
        self.engine = engine
        self.poll_s = poll_s
        self.async_draining = True  # step() drains results; threads drive
        self._lock = threading.RLock()
        self._harvest_q: queue_mod.Queue = queue_mod.Queue(maxsize=backlog)
        self._detok_q: queue_mod.Queue = queue_mod.Queue(maxsize=backlog)
        self._outputs: deque = deque()
        self._outstanding = 0  # popped from the window, not yet finalized
        self._stop = threading.Event()
        self._exc: Optional[str] = None
        # conservation + occupancy telemetry (the worker's load snapshot)
        self.submitted = 0
        self.emitted = 0
        self.submitted_bytes = 0
        self.steps = 0  # finalized decode steps (occupancy samples)
        self.busy_slot_steps = 0
        self._threads = [
            threading.Thread(target=self._run_guarded, args=(fn,),
                             name=f"engine-pipeline-{tag}", daemon=True)
            for tag, fn in (("dispatch", self._dispatch_loop),
                            ("harvest", self._harvest_loop),
                            ("detokenize", self._detok_loop))
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------------ #
    # thread bodies
    # ------------------------------------------------------------------ #
    def _run_guarded(self, fn):
        try:
            fn()
        except BaseException:  # noqa: BLE001 — surface to the caller
            self._exc = traceback.format_exc()
            self._stop.set()

    def _put(self, q, item) -> bool:
        """Bounded put that stays responsive to shutdown."""
        while not self._stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue_mod.Full:
                continue
        return False

    def _get(self, q):
        while not self._stop.is_set():
            try:
                return q.get(timeout=0.05)
            except queue_mod.Empty:
                continue
        return None

    def _dispatch_loop(self):
        eng = self.engine
        while not self._stop.is_set():
            entry = None
            t0 = time.perf_counter()
            with self._lock:
                eng._admit()
                if eng._prefill_finished:  # budget met at prefill time
                    done = list(eng._prefill_finished)
                    eng._prefill_finished = []
                    self._outputs.extend(done)
                    self.emitted += len(done)
                eng._dispatch(outstanding=self._outstanding)
                eng._chunk_step()  # one chunk behind the decode top-up
                if eng.pool.window:
                    entry = eng.pool.pop_oldest()
                    eng._backlog_entries.append(entry)
                    self._outstanding += 1
            if entry is not None:
                trace.tracer().emit("pipeline.dispatch", t0,
                                    time.perf_counter(), tag="pipeline")
                # NEVER under the lock: a full backlog must block dispatch
                # without blocking the detokenize thread's finalize
                self._put(self._harvest_q, entry)
            else:
                time.sleep(self.poll_s)

    def _harvest_loop(self):
        while not self._stop.is_set():
            entry = self._get(self._harvest_q)
            if entry is None:
                continue
            # the blocking device->host transfer, off every other thread's
            # critical path (no lock: snapshot arrays are read-only here)
            t0 = time.perf_counter()
            toks, _done = jax.device_get((entry.tokens, entry.done))
            t_h = time.perf_counter()
            trace.tracer().emit("pipeline.harvest", t0, t_h, tag="pipeline")
            self._put(self._detok_q, (entry, toks, t_h))

    def _detok_loop(self):
        eng = self.engine
        while not self._stop.is_set():
            item = self._get(self._detok_q)
            if item is None:
                continue
            entry, toks, t_h = item
            t0 = time.perf_counter()
            with self._lock:
                # FIFO edges: the entry being finalized is always the
                # oldest backlog entry; drop it BEFORE finalize so the
                # _finished_ids prune is tight
                if eng._backlog_entries and eng._backlog_entries[0] is entry:
                    eng._backlog_entries.popleft()
                dt = max(t_h - eng._t_mark, 0.0)
                eng._t_mark = t_h
                done = eng._finalize_harvest(entry, toks, dt)
                self.steps += 1
                self.busy_slot_steps += sum(
                    1 for r in entry.slots if r is not None
                )
                self._outputs.extend(done)
                self.emitted += len(done)
                self._outstanding -= 1
            trace.tracer().emit("pipeline.detokenize", t0,
                                time.perf_counter(), tag="pipeline")

    # ------------------------------------------------------------------ #
    # step()-compatible facade
    # ------------------------------------------------------------------ #
    def _check(self):
        if self._exc is not None:
            raise RuntimeError(
                f"engine pipeline thread failed:\n{self._exc}"
            )

    def submit(self, req: Request, now: Optional[float] = None):
        self._check()
        with self._lock:
            self.engine.submit(req, now)
            self.submitted += 1
            self.submitted_bytes += req.payload_bytes

    def step(self) -> list[Response]:
        """Drain finished responses (completion order). The pipeline
        threads make the actual progress; this never blocks."""
        self._check()
        with self._lock:
            out = list(self._outputs)
            self._outputs.clear()
        return out

    @property
    def idle(self) -> bool:
        self._check()
        with self._lock:
            eng = self.engine
            return (not eng.queue and eng.pool.all_free
                    and not eng.pool.window and not eng._chunk_jobs
                    and self._outstanding == 0 and not self._outputs)

    def run_until_drained(self, max_steps: int = 10_000) -> list[Response]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if self.idle:
                break
            time.sleep(self.poll_s)
        self.trace_flush()
        return out

    def trace_flush(self):
        """Flush the engine's open decode-window span (drain boundary)."""
        with self._lock:
            self.engine._trace_flush_window()

    def load_snapshot(self) -> dict:
        """Router-visible load + conservation counters, read atomically
        (what the worker returns on every RPC round-trip)."""
        with self._lock:
            eng = self.engine
            free = len(eng._free_slots())  # chunk-reserved slots are busy
            queued = sum(r.max_new_tokens for r in eng.queue)
            live = sum(
                r.max_new_tokens - len(r.generated)
                for r in eng.pool.slots if r is not None
            )
            chunking = sum(j.req.max_new_tokens for j in eng._chunk_jobs)
            return {
                "queue_depth": len(eng.queue),
                "occupancy": eng.max_batch - free,
                "free_slots": free,
                "outstanding_tokens": queued + live + chunking,
                "steps": self.steps,
                "busy_slot_steps": self.busy_slot_steps,
                "submitted": self.submitted,
                "emitted": self.emitted,
                "submitted_bytes": self.submitted_bytes,
                "idle": (not eng.queue and eng.pool.all_free
                         and not eng.pool.window and not eng._chunk_jobs
                         and self._outstanding == 0 and not self._outputs),
            }

    def metrics_snapshot(self) -> dict:
        """Engine registry snapshot, read atomically."""
        with self._lock:
            return self.engine.metrics_snapshot()

    # passthroughs (Gateway / loadgen / tests reach the engine surface)
    @property
    def queue(self):
        return self.engine.queue

    @property
    def store(self):
        return self.engine.store

    @property
    def _records(self):
        return self.engine._records

    @property
    def max_batch(self):
        return self.engine.max_batch

    @property
    def pool(self):
        return self.engine.pool

    def close(self, timeout: float = 5.0):
        """Stop the pipeline threads (idempotent). In-flight entries are
        abandoned — close after draining if the results matter."""
        self._stop.set()
        for t in self._threads:
            t.join(timeout=timeout)

    def __enter__(self) -> "EnginePipeline":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
