"""Disaggregated prefill->decode serving tier: the paper's multi-stage
pipeline study on the REAL JAX serving path.

A :class:`DisaggregatedEngine` runs admission+prefill as one stage and the
decode slot pool as another, and hands each admitted request's KV cache
across the mesh "pod" axis via ``core.transfer.kv_transfer``. The hop
mechanism is selectable per deployment and maps onto the paper's taxonomy:

  DIRECT_HBM  (GDR)  : collective permute straight into decode-pod HBM.
  DIRECT_DMA  (RDMA) : permute + one pinned-host bounce copy.
  HOST_STAGED (TCP)  : int8-requantized payload (per-source-pod scales),
                       two staging copies, CPU on the data path.

The collective moves ONLY the valid KV prefix: the artifact's occupied
rows and their max true prompt length (both rounded up to powers of two,
the prefix floored at ``handoff_block`` — bounding jit shapes like the
prefill buckets) are sliced out of the max_batch x max_seq pool tree
before tiling (``kvcache.slice_cache``, ring-dim aware), and the landed
prefix is grown back to the pool's ring width on the DECODE side — after
the wire — so the splice's OOB-drop scatter is unchanged. The three byte
counters reconcile exactly: ``handoff_wire_bytes`` is
``payload_wire_bytes`` of the sliced payload the collective actually
permutes, and ``handoff_request_bytes`` (per-request true-prefix bytes)
is <= wire bytes by only the pow2/block rounding.

Every handoff carries per-request slot metadata (true lengths, first
tokens, slot indices, budgets) alongside the cache leaves, so the decode
pool splices a FOREIGN artifact through the same entry point a local
prefill uses. The handoff cost lands in the request's 'transfer' stage and
its TTFT: measured (``block_until_ready`` wall) on real multi-pod
hardware, or charged from the calibrated ``TransportProfile.handoff_time``
model on host-device runs — where the collective's CPU wall says nothing
about NIC mechanisms — with the non-representative measured wall swapped
out of the latency stamps.

On a multi-device backend the collective genuinely crosses the pod axis
(CI runs it on 8 forced host devices); on one device the pod axis
degenerates to an identity permute, so the full tier — tiling,
quantization, metadata round-trip, splice — still executes in tier-1
tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transfer import (
    MODE_TRANSPORT,
    TransferMode,
    kv_transfer,
    payload_wire_bytes,
    pod_take,
    pod_tile,
    wire_itemsize,
)
from repro.core.transport import Transport
from repro.models import kvcache as kvc
from repro.serving.engine import PrefillArtifact, ServingEngine, _next_pow2

# per-row slot metadata riding the handoff: lengths/next_token/slot/max_new
_META_BYTES = 16


def make_pod_mesh(npods: Optional[int] = None):
    """('pod',)-axis mesh over the first ``npods`` devices (default 2 when
    the backend has them, else the 1-pod degenerate mesh)."""
    from jax.sharding import Mesh

    avail = jax.devices()
    npods = min(2, len(avail)) if npods is None else npods
    if npods > len(avail):
        raise ValueError(f"npods {npods} > available devices {len(avail)}")
    return Mesh(np.asarray(avail[:npods]), ("pod",))


class DisaggregatedEngine(ServingEngine):
    """ServingEngine whose prefill output crosses a pod boundary before it
    reaches the decode slot pool.

    charge: 'measured' bills the handoff's block_until_ready wall,
    'modeled' bills ``profile.handoff_time`` on the request's wire bytes,
    'auto' (default) picks measured on accelerator backends and modeled on
    host-device (CPU) runs.

    handoff_block: floor granularity of the moved KV prefix. The prefix
    rounds up to a power of two (floored at this block, clamped to
    max_seq) and the row count rounds up to a power of two likewise, so
    the slice/collective/regrow jits compile O(log max_batch * log
    max_seq) shapes per mechanism — matching the pow2 prefill buckets —
    instead of one shape per distinct admission extent. Coarser blocks
    cut recompiles further at the cost of more dead ring slots on the
    wire.
    """

    def __init__(self, model, params, *,
                 transfer_mode: TransferMode = TransferMode.DIRECT_HBM,
                 mesh=None, prefill_pod: int = 0,
                 decode_pod: Optional[int] = None,
                 charge: str = "auto", handoff_block: int = 16, **kw):
        if kw.get("legacy"):
            raise ValueError(
                "disaggregated tier requires the fast path (legacy=True "
                "keeps prefill and decode fused in one synchronous loop)"
            )
        if charge not in ("auto", "measured", "modeled"):
            raise ValueError(f"charge must be auto|measured|modeled: {charge}")
        super().__init__(model, params, **kw)
        self.mesh = mesh if mesh is not None else make_pod_mesh()
        self.npods = self.mesh.shape["pod"]
        self.transfer_mode = transfer_mode
        self.hop = MODE_TRANSPORT[transfer_mode]
        self.prefill_pod = prefill_pod
        self.decode_pod = (self.npods - 1) if decode_pod is None else decode_pod
        self.charge = charge
        if handoff_block < 1:
            raise ValueError(f"handoff_block must be >= 1: {handoff_block}")
        self.handoff_block = handoff_block
        self.handoffs = 0
        self.handoff_wire_bytes = 0  # bytes the collective actually moved
        self.handoff_request_bytes = 0  # useful bytes (true KV prefixes)
        self.handoff_wall_s = 0.0
        self._xfer_jit: dict = {}
        self._xfer_warm: set = set()  # (mode, rows, prefix) extents warmed
        # prefill-side prefix slice and decode-side regrow around the wire;
        # both retrace per (extent, payload-shape) like the collective itself
        self._slice_jit = jax.jit(kvc.slice_cache, static_argnums=(1, 2))
        self._land_jit = jax.jit(self._land_impl)

    # ------------------------------------------------------------------ #
    def _measured(self) -> bool:
        if self.charge == "auto":
            return jax.default_backend() != "cpu"
        return self.charge == "measured"

    def _xfer(self, mode: TransferMode):
        """Jitted tile -> permute -> take for one mechanism (one dispatch;
        compiles once per payload shape-set)."""
        if mode not in self._xfer_jit:
            perm = ([(self.prefill_pod, self.decode_pod)]
                    if self.npods > 1 else [(0, 0)])

            def impl(payload, *, _mode=mode, _perm=perm):
                tiled = pod_tile(payload, self.npods, self.prefill_pod)
                moved = kv_transfer(tiled, self.mesh, mode=_mode, perm=_perm)
                return pod_take(moved, self.decode_pod)

            self._xfer_jit[mode] = jax.jit(impl)
        return self._xfer_jit[mode]

    def request_handoff_bytes(self, true_len: int) -> int:
        """Wire bytes one request's KV prefix + slot metadata put on the
        inter-stage hop under this deployment's mechanism."""
        return _META_BYTES + kvc.request_cache_nbytes(
            self.pool.caches, true_len, itemsize=self._wire_isz,
        )

    def padded_tree_wire_bytes(self) -> int:
        """Wire bytes ONE pre-prefix-slicing handoff moved: the full
        max_batch x max_seq pool cache tree plus full-width slot metadata.
        The benchmark/test baseline the prefix-only collective is held
        against."""
        meta = {k: jnp.zeros((self.max_batch,), jnp.int32)
                for k in ("lengths", "next_tokens", "slot_idx", "max_new")}
        return payload_wire_bytes(
            {"caches": self.pool.caches, "meta": meta}, self.transfer_mode
        )

    def _wire_isz(self, leaf) -> int:
        return wire_itemsize(leaf.dtype, self.transfer_mode)

    def _land_impl(self, caches, meta):
        """Decode-side regrow, AFTER the wire: pad the landed prefix back to
        the pool's fixed admission width (rows) and ring width (seq), with
        padding rows carrying OOB slot indices so the pool's existing
        drop-OOB splice scatter sees one fixed shape and ignores them."""
        caches = kvc.grow_cache(
            kvc.pad_cache_rows(caches, self.max_batch), self.max_seq
        )
        n = meta["lengths"].shape[0]
        width = (0, self.max_batch - n)

        def pad(x, fill=0):
            return jnp.pad(x, width, constant_values=fill)

        meta = {
            "lengths": pad(meta["lengths"]),
            "next_tokens": pad(meta["next_tokens"]),
            "slot_idx": pad(meta["slot_idx"], self.max_batch),  # OOB
            "max_new": pad(meta["max_new"]),
        }
        return caches, meta

    def handoff_prefix(self, true_len: int) -> int:
        """Ring slots the collective moves for a ``true_len``-token row:
        next power of two, floored at ``handoff_block``, clamped to the
        pool's ring width."""
        p = max(_next_pow2(max(true_len, 1)), self.handoff_block)
        return min(p, self.max_seq)

    def _prefix_extent(self, art: PrefillArtifact) -> tuple[int, int]:
        """(rows, prefix) extent the wire carries: both round up to powers
        of two — bounding jit shapes like the prefill buckets do — with
        rows clamped to the artifact's actual width (the extra rows are the
        artifact's own OOB-slot dummies, dropped by the far-side splice)."""
        n = min(_next_pow2(max(art.n_rows, 1)), len(art.slot_idx))
        return n, self.handoff_prefix(art.prefix_len)

    # ------------------------------------------------------------------ #
    def _handoff(self, art: PrefillArtifact):
        """Move the prefill artifact's VALID KV PREFIX across the pod
        boundary and charge each riding request for its share.

        The prefill jit grows caches to max_seq for the single-node splice;
        here that padding is sliced back off to [rows, prefix_blocks] (plus
        the rows' slot metadata) before the collective, so the wire carries
        only live cache bytes. The landed prefix regrows to the ring width
        on the decode side, after the wire."""
        n, prefix = self._prefix_extent(art)
        payload = {
            "caches": self._slice_jit(art.caches, n, prefix),
            "meta": {
                "lengths": art.lengths[:n],
                "next_tokens": art.next_tokens[:n],
                "slot_idx": jnp.asarray(art.slot_idx[:n]),
                "max_new": art.max_new[:n],
            },
        }
        xfer = self._xfer(self.transfer_mode)
        measured = self._measured()
        key = (self.transfer_mode, n, prefix)
        warm_s = 0.0
        if key not in self._xfer_warm:
            # ONCE per pow2 extent (not per handoff): compile plus one
            # throwaway out-of-band collective — jit's cache isn't
            # populated by AOT lowering — outside the timed window, and
            # hand the warm wall back to the caller so it stays out of
            # 'preprocess' too. No charged stage ever bills XLA
            # compilation, and the wall counters stay steady-state on
            # measured and modeled backends alike.
            tw = time.perf_counter()
            jax.block_until_ready(xfer(payload))
            self._xfer_warm.add(key)
            warm_s = time.perf_counter() - tw
        t0 = time.perf_counter()
        landed = xfer(payload)
        jax.block_until_ready(landed)
        wall = time.perf_counter() - t0

        wire_now = payload_wire_bytes(payload, self.transfer_mode)
        self.handoffs += 1
        self.handoff_wall_s += wall
        self.handoff_wire_bytes += wire_now
        share = wall / max(len(art.reqs), 1)
        # per-request TRUE cache lengths ride the (already materialized)
        # landed metadata — for feature-carrying requests the cache extends
        # past the prompt, so len(prompt_tokens) would undercount
        true_lens = np.asarray(landed["meta"]["lengths"])
        req_bytes = [
            _META_BYTES + kvc.request_cache_nbytes(
                art.caches, int(true_lens[j]), itemsize=self._wire_isz,
            )
            for j in range(len(art.reqs))
        ]
        tot_bytes = max(sum(req_bytes), 1)
        for req, nbytes in zip(art.reqs, req_bytes):
            rec = self._records[req.request_id]
            self.handoff_request_bytes += nbytes
            # each request's prefix-proportional share of the bytes the
            # collective ACTUALLY moved (block rounding + co-rider dummy
            # rows included): modeled hop and TCP CPU both charge on this,
            # so the per-request stages sum to the real wire cost
            wire_share = wire_now * nbytes / tot_bytes
            # every co-admitted request waits the FULL collective wall
            # before its first token; the charged stage splits it (measured
            # attribution, like preprocess/inference) or models the hop on
            # this request's share of the moved bytes
            rec.transfer_wall_s += wall
            rec.add(
                "transfer",
                share if measured
                else self.profile.handoff_time(self.hop, wire_share),
            )
            if self.hop is Transport.TCP:
                # the host stack keeps the CPU on the handoff data path,
                # symmetric with the gateway's ingress/egress accounting;
                # sum(cpu_s) == wire * tcp_cpu_per_byte exactly
                rec.cpu_s += wire_share * self.profile.tcp_cpu_per_byte
        caches, meta = self._land_jit(landed["caches"], landed["meta"])
        # n_rows stays == len(reqs): the pow2-rounded wire extent is a
        # transport detail, not part of the artifact's occupancy contract
        art = dataclasses.replace(
            art, caches=caches,
            slot_idx=np.asarray(meta["slot_idx"]), lengths=meta["lengths"],
            next_tokens=meta["next_tokens"], max_new=meta["max_new"],
        )
        # warm_s rides along so the caller excludes it from 'preprocess';
        # the charged transfer wall above is the steady-state `wall` only
        return art, wall + warm_s

    def _ttft_adjust(self, rec) -> float:
        # measured charge: the handoff wall is already inside the latency
        # stamps — adjust by 0. modeled charge (host-device runs): swap the
        # FULL non-representative collective wall the request waited for
        # out of the stamps and fold the profile-modeled hop in.
        if self._measured():
            return 0.0
        return rec.stage_s.get("transfer", 0.0) - rec.transfer_wall_s
