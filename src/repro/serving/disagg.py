"""Disaggregated prefill->decode serving tier: the paper's multi-stage
pipeline study on the REAL JAX serving path.

A :class:`DisaggregatedEngine` runs admission+prefill as one stage and the
decode slot pool as another, and hands each admitted request's KV cache
across the mesh "pod" axis via ``core.transfer.kv_transfer``. The hop
mechanism is selectable per deployment and maps onto the paper's taxonomy:

  DIRECT_HBM  (GDR)  : collective permute straight into decode-pod HBM.
  DIRECT_DMA  (RDMA) : permute + one pinned-host bounce copy.
  HOST_STAGED (TCP)  : int8-requantized payload (per-source-pod scales),
                       two staging copies, CPU on the data path.

**Per-pod compute placement** (:class:`PodPlacement`, on by default):
prefill params and the prefill/slice jits are committed to the PREFILL
pod slice, the decode pool's params and entire device state to the DECODE
slice (``sharding.partition.place_on_slice``), so each stage's jitted
compute provably executes on its own devices — jit placement follows its
committed arguments, and every stage output reports its slice as the
device set. The handoff collective is then the ONLY cross-slice hop: the
pod-tiled payload is laid out with the live bytes on the prefill slice
(``P('pod')`` over the full mesh), the ``ppermute`` crosses a genuine
compute boundary, and the landed prefix is committed to the decode slice
before the regrow/splice. ``placement=False`` restores the pre-placement
behavior (both stages on the default device sharding).

The collective moves ONLY the valid KV prefix: the artifact's occupied
rows and their max true prompt length (both rounded up to powers of two,
the prefix floored at ``handoff_block`` — bounding jit shapes like the
prefill buckets) are sliced out of the max_batch x max_seq pool tree
before tiling (``kvcache.slice_cache``, ring-dim aware), and the landed
prefix is grown back to the pool's ring width on the DECODE side — after
the wire — so the splice's OOB-drop scatter is unchanged. The three byte
counters reconcile exactly: ``handoff_wire_bytes`` is
``payload_wire_bytes`` of the sliced payload the collective actually
permutes, and ``handoff_request_bytes`` (per-request true-prefix bytes)
is <= wire bytes by only the pow2/block rounding.

**Warmup** (``warmup=True``): engine construction pre-traces the whole
pow2 shape grid — every prefill bucket, and every (rows, prefix-blocks)
handoff extent through the slice/tile/collective/land jits, plus the
splice and decode step — so a warmed engine charges no XLA compile inside
any timed serving stage (compile-count-asserted in tests and the
benchmark's warmed smoke).

Every handoff carries per-request slot metadata (true lengths, first
tokens, slot indices, budgets) alongside the cache leaves, so the decode
pool splices a FOREIGN artifact through the same entry point a local
prefill uses. The handoff cost lands in the request's 'transfer' stage and
its TTFT: measured (``block_until_ready`` wall) on real multi-pod
hardware, or charged from the calibrated ``TransportProfile.handoff_time``
model on host-device runs — where the collective's CPU wall says nothing
about NIC mechanisms — with the non-representative measured wall swapped
out of the latency stamps.

On a multi-device backend the collective genuinely crosses the pod axis
(CI runs it on 8 forced host devices); on one device the pod axis
degenerates to an identity permute and both slices collapse onto the same
device, so the full tier — placement, tiling, quantization, metadata
round-trip, splice — still executes in tier-1 tests.

See docs/architecture.md for the end-to-end pipeline and the mapping of
every hop onto the paper's GDR/RDMA/TCP mechanisms.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import trace
from repro.core.transfer import (
    MODE_TRANSPORT,
    TransferMode,
    _quantizes,
    kv_transfer,
    payload_wire_bytes,
    pod_take,
    pod_tile,
    wire_itemsize,
)
from repro.core.transport import Transport
from repro.models import kvcache as kvc
from repro.serving.engine import PrefillArtifact, ServingEngine, _next_pow2
from repro.sharding.partition import place_on_slice, pod_slice_mesh

# per-row slot metadata riding the handoff: lengths/next_token/slot/max_new
_META_BYTES = 16
# paged handoffs additionally carry cached_lens (the reused-prefix split)
_META_BYTES_PAGED = 20

# tools/reprolint RL005 contract (see serving/engine.py): jits listed
# here are pre-traced by warm() over the pow2 bucket x handoff-extent
# grid, so none compiles inside a timed stage on the bucketed path.
# (Exact-shape extents still compile lazily — ROADMAP carry-over.)
WARM_PRETRACE_TABLE = frozenset({
    "_slice_jit",          # prefill-side prefix slice, per extent
    "_land_jit",           # decode-side regrow, per extent
    "_land_paged_jit",     # paged twin
    "_store_scatter_jit",  # prefix-store block scatter
    "_xfer_jit",           # per-mechanism (prep, move) pair
    "coll_jit",            # placement collective inside _xfer
})


def make_pod_mesh(npods: Optional[int] = None):
    """('pod',)-axis mesh over the first ``npods`` devices (default 2 when
    the backend has them, else the 1-pod degenerate mesh). Thin re-export
    of ``launch.mesh.make_serving_pod_mesh``."""
    from repro.launch.mesh import make_serving_pod_mesh

    return make_serving_pod_mesh(npods)


@dataclasses.dataclass(frozen=True)
class PodPlacement:
    """Which pod-axis slices the two serving stages' compute lives on.

    ``prefill_pods`` / ``decode_pods`` are index tuples into the mesh's
    "pod" axis; each stage's params (and, for decode, the whole pool
    state) are replicated onto its slice, so the stage's jits compile for
    exactly those devices. The handoff collective permutes from
    ``prefill_pods[0]`` to ``decode_pods[0]``. Slices may overlap — the
    1-pod degenerate mesh collapses both onto one device (``disjoint``
    False), which is what lets the tier run on a single test CPU; a real
    two-pool deployment uses disjoint slices.
    """

    mesh: object
    prefill_pods: tuple
    decode_pods: tuple

    def __post_init__(self):
        object.__setattr__(self, "prefill_pods", tuple(self.prefill_pods))
        object.__setattr__(self, "decode_pods", tuple(self.decode_pods))
        # pod_slice_mesh validates indices; build each slice's mesh once
        object.__setattr__(
            self, "_prefill_mesh", pod_slice_mesh(self.mesh, self.prefill_pods)
        )
        object.__setattr__(
            self, "_decode_mesh", pod_slice_mesh(self.mesh, self.decode_pods)
        )

    @classmethod
    def from_mesh(cls, mesh, prefill_pod: int = 0,
                  decode_pod: Optional[int] = None) -> "PodPlacement":
        """Single-pod-per-stage placement: prefill on ``prefill_pod``,
        decode on ``decode_pod`` (default: the last pod)."""
        npods = mesh.shape["pod"]
        decode_pod = (npods - 1) if decode_pod is None else decode_pod
        return cls(mesh, (prefill_pod,), (decode_pod,))

    @property
    def disjoint(self) -> bool:
        """True when the stages share no pod — a genuine two-pool split."""
        return not set(self.prefill_pods) & set(self.decode_pods)

    def prefill_sharding(self, spec: P = P()) -> NamedSharding:
        """Sharding scoped to the prefill slice (replicated by default)."""
        return NamedSharding(self._prefill_mesh, spec)

    def decode_sharding(self, spec: P = P()) -> NamedSharding:
        """Sharding scoped to the decode slice (replicated by default)."""
        return NamedSharding(self._decode_mesh, spec)

    def prefill_devices(self) -> tuple:
        return tuple(self._prefill_mesh.devices.flat)

    def decode_devices(self) -> tuple:
        return tuple(self._decode_mesh.devices.flat)


class DisaggregatedEngine(ServingEngine):
    """ServingEngine whose prefill output crosses a pod boundary before it
    reaches the decode slot pool.

    placement: True (default) derives a :class:`PodPlacement` from
    ``prefill_pod``/``decode_pod`` and commits each stage's params and
    compute to its own pod slice; pass an explicit PodPlacement for
    multi-pod slices, or False for the pre-placement behavior (both
    stages on the default device sharding, the collective still crossing
    the pod axis).

    charge: 'measured' bills the handoff's block_until_ready wall,
    'modeled' bills ``profile.handoff_time`` on the request's wire bytes,
    'auto' (default) picks measured on accelerator backends and modeled on
    host-device (CPU) runs.

    handoff_block: floor granularity of the moved KV prefix. The prefix
    rounds up to a power of two (floored at this block, clamped to
    max_seq) and the row count rounds up to a power of two likewise, so
    the slice/collective/regrow jits compile O(log max_batch * log
    max_seq) shapes per mechanism — matching the pow2 prefill buckets —
    instead of one shape per distinct admission extent. Coarser blocks
    cut recompiles further at the cost of more dead ring slots on the
    wire.

    warmup: pre-trace the full bucket + handoff extent grid at
    construction (see :meth:`ServingEngine.warm`), so the serving path
    never compiles.
    """

    def __init__(self, model, params, *,
                 transfer_mode: TransferMode = TransferMode.DIRECT_HBM,
                 mesh=None, prefill_pod: int = 0,
                 decode_pod: Optional[int] = None,
                 placement=True, charge: str = "auto",
                 handoff_block: int = 16, warmup: bool = False, **kw):
        if kw.get("legacy"):
            raise ValueError(
                "disaggregated tier requires the fast path (legacy=True "
                "keeps prefill and decode fused in one synchronous loop)"
            )
        if charge not in ("auto", "measured", "modeled"):
            raise ValueError(f"charge must be auto|measured|modeled: {charge}")
        super().__init__(model, params, **kw)  # base never warms: placement
        self.mesh = mesh if mesh is not None else make_pod_mesh()
        self.npods = self.mesh.shape["pod"]
        self.transfer_mode = transfer_mode
        self.hop = MODE_TRANSPORT[transfer_mode]
        self.prefill_pod = prefill_pod
        self.decode_pod = (self.npods - 1) if decode_pod is None else decode_pod
        self.charge = charge
        if handoff_block < 1:
            raise ValueError(f"handoff_block must be >= 1: {handoff_block}")
        self.handoff_block = handoff_block
        self.handoffs = 0
        self.handoff_wire_bytes = 0  # bytes the collective actually moved
        self.handoff_request_bytes = 0  # useful bytes (true KV prefixes)
        # paged reconciliation oracle: expected wire bytes from the HOST-
        # SIDE admission plan alone (rows x suffix bucket x per-token wire
        # bytes + metadata) — never reads the device payload, and must
        # equal handoff_wire_bytes exactly at every prefix hit rate
        self.handoff_payload_bytes = 0
        self.handoff_wall_s = 0.0
        self._xfer_jit: dict = {}
        self._xfer_warm: set = set()  # (mode, rows, prefix) extents warmed
        # dead filler shards for the placed tiling: LRU, capped at one
        # pool-tree's worth of bytes so the extent grid can't pin a
        # multiple of the pool in never-read zeros
        self._zero_shards: OrderedDict = OrderedDict()
        self._zero_bytes = 0
        self._zero_budget = sum(
            leaf.nbytes for leaf in jax.tree.leaves(
                self.pool.blocks if self.paged else self.pool.caches
            )
        )

        # --- per-pod compute placement -------------------------------- #
        self.placement: Optional[PodPlacement] = None
        if placement:
            if placement is True:
                placement = PodPlacement.from_mesh(
                    self.mesh, prefill_pod=self.prefill_pod,
                    decode_pod=self.decode_pod,
                )
            if placement.mesh != self.mesh:
                raise ValueError("placement.mesh differs from engine mesh")
            if int(np.asarray(self.mesh.devices).size) != self.npods:
                # the placed tiling enumerates one device per pod slot
                raise ValueError(
                    "per-pod placement requires a mesh whose only "
                    f"non-trivial axis is 'pod' (got {dict(self.mesh.shape)}"
                    "); pass placement=False for multi-axis meshes"
                )
            self.placement = placement
            # the collective's endpoints follow the placement
            self.prefill_pod = placement.prefill_pods[0]
            self.decode_pod = placement.decode_pods[0]
            # each stage serves from params committed to ITS slice; every
            # jit consuming them then executes on that slice's devices.
            # Equal slices (the 1-pod degenerate mesh) share ONE committed
            # replica — two device_put copies on the same device would
            # triple resident weight memory for nothing.
            self.prefill_params = place_on_slice(
                params, self.mesh, placement.prefill_pods
            )
            self.decode_params = (
                self.prefill_params
                if placement.decode_pods == placement.prefill_pods
                else place_on_slice(params, self.mesh, placement.decode_pods)
            )
            self.pool.place(placement.decode_sharding())

        # prefill-side prefix slice and decode-side regrow around the wire;
        # both retrace per (extent, payload-shape) like the collective itself
        self._slice_jit = jax.jit(kvc.slice_cache, static_argnums=(1, 2))
        self._land_jit = jax.jit(self._land_impl)
        if self.paged:
            self._land_paged_jit = jax.jit(self._land_paged_impl)
            # dense-shaped template (abstract, never materialized) for the
            # byte accountants that sized payloads off the ring pool tree
            self._dense_template = jax.eval_shape(
                lambda: self.model.init_cache(self.max_batch, self.max_seq)
            )
        # prefill-side prefix store (paged reuse): suffix prefills gather
        # their prior HERE, on the prefill pod — reused prefix KV never
        # re-crosses the pod boundary. Its blocks pair 1:1 (by index page)
        # with decode-pool blocks in the radix payloads.
        if self.prefix_reuse:
            self._store_pool = kvc.PagedKVPool(
                self.pool.allocator.num_blocks, self.page
            )
            blocks = kvc.init_paged(
                self.model.cache_specs(self.max_batch, self.max_seq),
                self._store_pool.num_blocks, self.page,
            )
            if self.placement is not None:
                blocks = jax.device_put(
                    blocks, self.placement.prefill_sharding()
                )
            self._prefix_store_blocks = blocks
            self._store_scatter_jit = jax.jit(
                kvc.scatter_pages, donate_argnums=(0,)
            )

        self.warmup = warmup
        if warmup:
            self.warm_s = self.warm()  # buckets + extent grid + splice/step

    # ------------------------------------------------------------------ #
    def _measured(self) -> bool:
        if self.charge == "auto":
            return jax.default_backend() != "cpu"
        return self.charge == "measured"

    def _xfer(self, mode: TransferMode):
        """(prep, move) pair for one mechanism.

        ``prep`` assembles the wire payload (host-side, charged to no wire
        stage); ``move`` is the hop itself — the part the measured wall
        times. Without placement, prep is the identity and move is one jit
        doing tile -> permute -> take (compiles once per payload
        shape-set). With placement, prep lays the [npods, ...] pod-sharded
        payload out from per-device shards — live bytes on the prefill
        slice, cached dead zeros elsewhere (:meth:`_tile_committed`) — and
        move runs the collective and commits the landed payload to the
        decode slice, so the wire wall covers exactly the cross-slice
        hop."""
        if mode not in self._xfer_jit:
            perm = ([(self.prefill_pod, self.decode_pod)]
                    if self.npods > 1 else [(0, 0)])

            if self.placement is None:
                def impl(payload, *, _mode=mode, _perm=perm):
                    tiled = pod_tile(payload, self.npods, self.prefill_pod)
                    moved = kv_transfer(tiled, self.mesh, mode=_mode,
                                        perm=_perm)
                    return pod_take(moved, self.decode_pod)

                self._xfer_jit[mode] = ((lambda p: p), jax.jit(impl))
            else:
                decode_sh = self.placement.decode_sharding()

                def collective(tiled, *, _mode=mode, _perm=perm):
                    moved = kv_transfer(tiled, self.mesh, mode=_mode,
                                        perm=_perm)
                    return pod_take(moved, self.decode_pod)

                coll_jit = jax.jit(collective)

                def move(tiled):
                    return jax.device_put(coll_jit(tiled), decode_sh)

                self._xfer_jit[mode] = (self._tile_committed, move)
        return self._xfer_jit[mode]

    def _tile_committed(self, payload):
        """Pod-tile ``payload`` without moving a byte across the slice
        boundary: each leaf becomes a [npods, ...] array sharded P('pod')
        over the full mesh, assembled from single-device shards — the live
        payload on the prefill pod, per-(shape, dtype, device)-cached zero
        buffers on every other pod (``ppermute`` under a [(src, dst)] perm
        never delivers those shards anywhere, so their values are dead).
        The subsequent collective is therefore the ONLY cross-slice hop."""
        wire_sh = NamedSharding(self.mesh, P("pod"))
        devs = list(np.asarray(self.mesh.devices).flat)

        def tile(x):
            shape = (1,) + tuple(x.shape)
            shards = [
                jax.device_put(x[None], d) if i == self.prefill_pod
                else self._zero_shard(shape, x.dtype, d)
                for i, d in enumerate(devs)
            ]
            return jax.make_array_from_single_device_arrays(
                (self.npods,) + tuple(x.shape), wire_sh, shards
            )

        return jax.tree.map(tile, payload)

    def _zero_shard(self, shape, dtype, device):
        """Dead filler shard for the non-source pods of the tiled wire
        layout, created host->device once per (shape, dtype, device) and
        LRU-cached under a one-pool-tree byte budget: hot extents reuse
        resident buffers (first touch happens in the warm pass or the
        out-of-band extent warm), cold extents evicted past the budget
        pay a compile-free zero re-upload."""
        key = (shape, str(dtype), device)
        buf = self._zero_shards.get(key)
        if buf is None:
            buf = jax.device_put(np.zeros(shape, dtype), device)
            self._zero_shards[key] = buf
            self._zero_bytes += buf.nbytes
            while (self._zero_bytes > self._zero_budget
                   and len(self._zero_shards) > 1):
                # callers hold refs to shards mid-tile, so eviction here
                # never invalidates an in-flight handoff
                _, old = self._zero_shards.popitem(last=False)
                self._zero_bytes -= old.nbytes
        else:
            self._zero_shards.move_to_end(key)
        return buf

    def request_handoff_bytes(self, true_len: int) -> int:
        """Wire bytes one request's KV prefix + slot metadata put on the
        inter-stage hop under this deployment's mechanism (paged: the
        ``true_len`` tokens that actually ride — the caller passes the
        UNCACHED suffix length there, and the metadata row is wider)."""
        if self.paged:
            return _META_BYTES_PAGED + kvc.request_cache_nbytes(
                self._dense_template, true_len, itemsize=self._wire_isz,
            )
        return _META_BYTES + kvc.request_cache_nbytes(
            self.pool.caches, true_len, itemsize=self._wire_isz,
        )

    def padded_tree_wire_bytes(self) -> int:
        """Wire bytes ONE pre-prefix-slicing handoff moved: the full
        max_batch x max_seq pool cache tree plus full-width slot metadata.
        The benchmark/test baseline the prefix-only collective is held
        against."""
        meta = {k: jnp.zeros((self.max_batch,), jnp.int32)
                for k in ("lengths", "next_tokens", "slot_idx", "max_new")}
        dense = (self._dense_template if self.paged else self.pool.caches)
        return payload_wire_bytes(
            {"caches": dense, "meta": meta}, self.transfer_mode
        )

    def _wire_isz(self, leaf) -> int:
        return wire_itemsize(leaf.dtype, self.transfer_mode)

    def _land_impl(self, caches, meta):
        """Decode-side regrow, AFTER the wire: pad the landed prefix back to
        the pool's fixed admission width (rows) and ring width (seq), with
        padding rows carrying OOB slot indices so the pool's existing
        drop-OOB splice scatter sees one fixed shape and ignores them."""
        caches = kvc.grow_cache(
            kvc.pad_cache_rows(caches, self.max_batch), self.max_seq
        )
        n = meta["lengths"].shape[0]
        width = (0, self.max_batch - n)

        def pad(x, fill=0):
            return jnp.pad(x, width, constant_values=fill)

        meta = {
            "lengths": pad(meta["lengths"]),
            "next_tokens": pad(meta["next_tokens"]),
            "slot_idx": pad(meta["slot_idx"], self.max_batch),  # OOB
            "max_new": pad(meta["max_new"]),
        }
        return caches, meta

    def handoff_prefix(self, true_len: int) -> int:
        """Ring slots the collective moves for a ``true_len``-token row:
        next power of two, floored at ``handoff_block``, clamped to the
        pool's ring width."""
        p = max(_next_pow2(max(true_len, 1)), self.handoff_block)
        return min(p, self.max_seq)

    def _prefix_extent(self, art: PrefillArtifact) -> tuple[int, int]:
        """(rows, prefix) extent the wire carries: both round up to powers
        of two — bounding jit shapes like the prefill buckets do — with
        rows clamped to the artifact's actual width (the extra rows are the
        artifact's own OOB-slot dummies, dropped by the far-side splice)."""
        n = min(_next_pow2(max(art.n_rows, 1)), len(art.slot_idx))
        return n, self.handoff_prefix(art.prefix_len)

    def handoff_extent_grid(self) -> list:
        """Every (rows, prefix) wire extent a bucketed admission can
        produce: pow2 row counts clamped to max_batch x pow2 prefixes
        floored at handoff_block and clamped to max_seq — the grid
        :meth:`warm` pre-traces."""
        rows = sorted({min(_next_pow2(r), self.max_batch)
                       for r in range(1, self.max_batch + 1)})
        prefixes, L = set(), 1
        while True:
            prefixes.add(self.handoff_prefix(L))
            if L >= self.max_seq:
                break
            L *= 2
        return [(r, p) for r in rows for p in sorted(prefixes)]

    def _wire_payload(self, art: PrefillArtifact, n: int, prefix: int):
        """The exact pytree the collective permutes for one admission: the
        [rows, prefix_blocks] cache slice plus those rows' slot metadata.
        Shared by :meth:`_handoff` and the warmup pass so both hit the
        same jit cache entries."""
        return {
            "caches": self._slice_jit(art.caches, n, prefix),
            "meta": {
                "lengths": art.lengths[:n],
                "next_tokens": art.next_tokens[:n],
                "slot_idx": jnp.asarray(art.slot_idx[:n]),
                "max_new": art.max_new[:n],
            },
        }

    def _wire_payload_paged(self, art: PrefillArtifact, n: int):
        """The paged handoff's wire pytree: the bucket-width SUFFIX cache
        sliced to ``n`` rows (seq already at the bucket — reused prefix KV
        is not aboard) plus those rows' slot metadata, cached_lens
        included. dest_blocks stay on the host: they index the decode
        pool's block ids, pure control plane."""
        return {
            "caches": self._slice_jit(art.caches, n, art.bucket),
            "meta": {
                "lengths": art.lengths[:n],
                "next_tokens": art.next_tokens[:n],
                "slot_idx": jnp.asarray(art.slot_idx[:n]),
                "max_new": art.max_new[:n],
                "cached_lens": jnp.asarray(art.cached_lens[:n]),
            },
        }

    def _land_paged_impl(self, caches, meta):
        """Decode-side landing for a paged handoff: pad ROWS back to the
        admission width (padding rows carry OOB slots and dest block 0, so
        the paged splice drops them) — the seq dim stays at the suffix
        bucket; the splice scatters pages, never a max_seq ring."""
        caches = kvc.pad_cache_rows(caches, self.max_batch)
        n = meta["lengths"].shape[0]
        width = (0, self.max_batch - n)

        def pad(x, fill=0):
            return jnp.pad(x, width, constant_values=fill)

        meta = {
            "lengths": pad(meta["lengths"]),
            "next_tokens": pad(meta["next_tokens"]),
            "slot_idx": pad(meta["slot_idx"], self.max_batch),  # OOB
            "max_new": pad(meta["max_new"]),
            "cached_lens": pad(meta["cached_lens"]),
        }
        return caches, meta

    def _paged_geometry_bytes(self, n: int, L: int) -> int:
        """Expected wire bytes of an [n rows x L suffix tokens] paged
        payload, from the admission plan alone: per-token KV wire bytes
        (dense template, so it never touches the device payload) times the
        refcount-trimmed extent, plus per-row metadata and the HOST_STAGED
        per-leaf quantization scales. The reconciliation oracle
        ``handoff_wire_bytes`` must match exactly."""
        total = n * _META_BYTES_PAGED
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                self._dense_template)[0]:
            per_tok = leaf.size // (self.max_batch * self.max_seq)
            total += n * L * per_tok * wire_itemsize(
                leaf.dtype, self.transfer_mode
            )
            if (self.transfer_mode is TransferMode.HOST_STAGED
                    and _quantizes(leaf.dtype)):
                total += 4
        return total

    def _new_chunk_prior(self):
        """Chunked prefill's per-request prior lives on the PREFILL pod
        slice: every chunk's suffix prefill and splice execute there, and
        only the final chunk's artifact crosses the pod boundary (through
        the same :meth:`_handoff` every admission takes)."""
        prior = super()._new_chunk_prior()
        if self.placement is not None:
            prior = jax.device_put(prior, self.placement.prefill_sharding())
        return prior

    # ------------------------------------------------------------------ #
    # prefill-side prefix store hooks (paged reuse)
    # ------------------------------------------------------------------ #
    def _store_alloc(self):
        return self._store_pool if self.prefix_reuse else self.pool.allocator

    def _store_deref(self, ids: list):
        self._store_alloc().deref(ids)

    def _prior_blocks(self):
        return self._prefix_store_blocks

    def _store_alloc_blocks(self, n: int) -> list:
        """Allocate prefill-store blocks, evicting cold index pages under
        pressure (each eviction releases BOTH payload sides)."""
        while True:
            got = self._store_pool.alloc(n)
            if got is not None:
                return got
            payload = self.prefix_index.evict_lru()
            if payload is None:
                raise RuntimeError(
                    "prefill-side prefix store exhausted with no evictable "
                    "index pages"
                )
            self._evict_index_page(payload)

    def _store_prepare(self, jobs: list, caches, L: int):
        """Scatter each job's fully-in-prompt suffix pages into the
        prefill-side store BEFORE the handoff, so future suffix prefills
        gather their prior on the prefill pod without re-crossing the
        wire. Returns job -> freshly allocated store block ids (rc=1 —
        the index's reference if the page gets created, orphan-deref'd
        otherwise in :meth:`_index_insert`)."""
        if not self.prefix_reuse:
            return None
        page = self.page
        dest = np.zeros((self.max_batch, L // page), np.int32)
        ctx: dict = {}
        for j, job in enumerate(jobs):
            n_ins = len(job.req.prompt_tokens) // page
            cpages = job.cached // page
            store_ids = self._store_alloc_blocks(max(n_ins - cpages, 0))
            ctx[id(job)] = store_ids
            for k, p in enumerate(store_ids):
                dest[j, k] = p
        self._prefix_store_blocks = self._store_scatter_jit(
            self._prefix_store_blocks, caches, jnp.asarray(dest)
        )
        return ctx

    def _index_insert(self, jobs: list, store_ctx):
        """Store-aware radix insert: page ``i``'s payload pairs the
        prefill-store block (gathered by future suffix prefills) with the
        decode-pool block (aliased into future rows' page tables).
        Created pages keep the store block's alloc-time rc=1 as the
        index's prefill-side reference and take one decode-side ref;
        orphans — the page already indexed by a same-batch sibling, or
        the row skipped after a mid-admission eviction — deref once and
        free."""
        if not self.prefix_reuse:
            return
        for job in jobs:
            store_ids = store_ctx.get(id(job), []) if store_ctx else []
            toks = job.req.prompt_tokens
            n_ins = len(toks) // self.page
            cpages = job.cached // self.page
            if n_ins == 0:
                continue
            depth = len(self.prefix_index.match(toks, n_ins, peek=True))
            if depth < cpages:
                self._store_pool.deref(store_ids)
                continue
            payloads = (
                [(job.p_ids[i], job.d_ids[i]) for i in range(cpages)]
                + [(store_ids[i - cpages], job.pt_row[i])
                   for i in range(cpages, n_ins)]
            )
            created = self.prefix_index.insert(toks, payloads, n_ins)
            created_p = set()
            for (p, d) in created:
                created_p.add(p)
                self.pool.allocator.ref([d])
            self._store_pool.deref(
                [p for p in store_ids if p not in created_p]
            )

    # ------------------------------------------------------------------ #
    def _warm_admit(self, art: Optional[PrefillArtifact]):
        """Pre-trace the handoff chain — slice, tile, collective, land —
        for EVERY (rows, prefix) extent in the grid, then splice one
        landed all-dummy artifact so the decode-side splice compiles on
        decode-slice-committed inputs. Called from :meth:`warm` with an
        artifact produced by the real prefill jit, so shapes, dtypes, and
        committed shardings all match the serving path exactly.

        Paged engines warm per suffix BUCKET (the seam is called once per
        bucket from the base warm loop): every pow2 row extent at that
        bucket width, plus the prefill-store scatter when reuse is on."""
        if art is None:  # exact-shape path: ragged per-request shapes
            return
        if self.paged:
            self._warm_admit_paged(art)
            return
        prep, move = self._xfer(self.transfer_mode)
        landed_art = None
        for n, prefix in self.handoff_extent_grid():
            key = (self.transfer_mode, n, prefix)
            if key in self._xfer_warm:
                continue
            landed = move(prep(self._wire_payload(art, n, prefix)))
            caches, meta = self._land_jit(landed["caches"], landed["meta"])
            jax.block_until_ready(caches)
            self._xfer_warm.add(key)
            landed_art = (caches, meta)
        if landed_art is not None:
            caches, meta = landed_art
            self.pool.splice(dataclasses.replace(
                art, caches=caches, slot_idx=np.asarray(meta["slot_idx"]),
                lengths=meta["lengths"], next_tokens=meta["next_tokens"],
                max_new=meta["max_new"],
            ))  # every row OOB: compiles the splice, writes nothing

    def _warm_admit_paged(self, art: PrefillArtifact):
        """Per-bucket paged extent warm: (rows_pow2 x this bucket) through
        slice/tile/collective/land, one all-dummy splice (per-bucket splice
        shapes), and the prefill-store scatter (dest 0 = sentinel drop)."""
        prep, move = self._xfer(self.transfer_mode)
        L = art.bucket
        rows = sorted({min(_next_pow2(r), self.max_batch)
                       for r in range(1, self.max_batch + 1)})
        landed_art = None
        for n in rows:
            key = (self.transfer_mode, "paged", n, L)
            if key in self._xfer_warm:
                continue
            landed = move(prep(self._wire_payload_paged(art, n)))
            caches, meta = self._land_paged_jit(
                landed["caches"], landed["meta"]
            )
            jax.block_until_ready(caches)
            self._xfer_warm.add(key)
            landed_art = (caches, meta)
        if landed_art is not None:
            caches, meta = landed_art
            self.pool.splice(dataclasses.replace(
                art, caches=caches, slot_idx=np.asarray(meta["slot_idx"]),
                lengths=meta["lengths"], next_tokens=meta["next_tokens"],
                max_new=meta["max_new"],
            ))  # every row OOB + dest 0: compiles, writes nothing
        if self.prefix_reuse:
            self._prefix_store_blocks = self._store_scatter_jit(
                self._prefix_store_blocks, art.caches,
                jnp.zeros((self.max_batch, L // self.page), jnp.int32),
            )

    # ------------------------------------------------------------------ #
    def _handoff_paged(self, art: PrefillArtifact):  # reprolint: disable=RL001 the block IS the measurement: 'transfer' wall must cover wire completion
        """Paged pod-boundary handoff: move the bucket-width SUFFIX cache
        only. Reused prefix KV already lives in decode-pool blocks (it
        crossed the wire exactly once, when first computed), so the wire
        carries ``rows_pow2 x suffix_bucket`` tokens — the refcount-
        trimmed payload — and ``handoff_wire_bytes`` drops with the hit
        rate while reconciling exactly against the host-side geometry
        oracle ``handoff_payload_bytes``."""
        n = min(_next_pow2(max(art.n_rows, 1)), len(art.slot_idx))
        L = art.bucket
        payload = self._wire_payload_paged(art, n)
        prep, move = self._xfer(self.transfer_mode)
        measured = self._measured()
        key = (self.transfer_mode, "paged", n, L)
        warm_s = 0.0
        if key not in self._xfer_warm:
            tw = time.perf_counter()
            jax.block_until_ready(move(prep(payload)))
            self._xfer_warm.add(key)
            warm_s = time.perf_counter() - tw
        tiled = prep(payload)
        jax.block_until_ready(tiled)
        t0 = time.perf_counter()
        landed = move(tiled)
        jax.block_until_ready(landed)
        wall = time.perf_counter() - t0

        wire_now = payload_wire_bytes(payload, self.transfer_mode)
        self.handoffs += 1
        self.handoff_wall_s += wall
        self.handoff_wire_bytes += wire_now
        self.handoff_payload_bytes += self._paged_geometry_bytes(n, L)
        trace.tracer().emit(
            "transfer", t0, t0 + wall, tag=self.trace_tag,
            mechanism=self.transfer_mode.name, wire_bytes=wire_now,
            requests=len(art.reqs),
            charge="measured" if measured else "modeled",
        )
        share = wall / max(len(art.reqs), 1)
        # per-request useful bytes = each row's UNCACHED suffix (its reused
        # prefix rode an earlier handoff; charging it again would double-
        # count the very bytes the prefix cache saved)
        total_lens = np.asarray(landed["meta"]["lengths"])
        req_bytes = [
            _META_BYTES_PAGED + kvc.request_cache_nbytes(
                art.caches,
                int(total_lens[j]) - int(art.cached_lens[j]),
                itemsize=self._wire_isz,
            )
            for j in range(len(art.reqs))
        ]
        tot_bytes = max(sum(req_bytes), 1)
        for req, nbytes in zip(art.reqs, req_bytes):
            rec = self._records[req.request_id]
            self.handoff_request_bytes += nbytes
            wire_share = wire_now * nbytes / tot_bytes
            rec.transfer_wall_s += wall
            rec.add(
                "transfer",
                share if measured
                else self.profile.handoff_time(self.hop, wire_share),
            )
            if self.hop is Transport.TCP:
                rec.cpu_s += wire_share * self.profile.tcp_cpu_per_byte
        caches, meta = self._land_paged_jit(landed["caches"], landed["meta"])
        # dest_blocks/cached_lens pass through untouched: host control
        # plane, aligned with the artifact's (unchanged) row order
        art = dataclasses.replace(
            art, caches=caches,
            slot_idx=np.asarray(meta["slot_idx"]), lengths=meta["lengths"],
            next_tokens=meta["next_tokens"], max_new=meta["max_new"],
        )
        return art, wall + warm_s

    # ------------------------------------------------------------------ #
    def _handoff(self, art: PrefillArtifact):  # reprolint: disable=RL001 the block IS the measurement: 'transfer' wall must cover wire completion
        """Move the prefill artifact's VALID KV PREFIX across the pod
        boundary and charge each riding request for its share.

        The prefill jit grows caches to max_seq for the single-node splice;
        here that padding is sliced back off to [rows, prefix_blocks] (plus
        the rows' slot metadata) before the collective, so the wire carries
        only live cache bytes. The landed prefix regrows to the ring width
        on the decode side, after the wire."""
        if self.paged:
            return self._handoff_paged(art)
        n, prefix = self._prefix_extent(art)
        payload = self._wire_payload(art, n, prefix)
        prep, move = self._xfer(self.transfer_mode)
        measured = self._measured()
        key = (self.transfer_mode, n, prefix)
        warm_s = 0.0
        if key not in self._xfer_warm:
            # ONCE per pow2 extent (not per handoff): compile plus one
            # throwaway out-of-band collective — jit's cache isn't
            # populated by AOT lowering — outside the timed window, and
            # hand the warm wall back to the caller so it stays out of
            # 'preprocess' too. No charged stage ever bills XLA
            # compilation, and the wall counters stay steady-state on
            # measured and modeled backends alike. warmup=True engines
            # pre-trace the whole grid at construction and never take
            # this branch.
            tw = time.perf_counter()
            jax.block_until_ready(move(prep(payload)))
            self._xfer_warm.add(key)
            warm_s = time.perf_counter() - tw
        # payload assembly (placed tiling, zero-shard residency) is prep,
        # not wire: block on it OUTSIDE the timed window so the measured
        # wall — and the per-request 'transfer' charge on accelerator
        # backends — covers exactly the collective + decode-slice landing
        tiled = prep(payload)
        jax.block_until_ready(tiled)
        t0 = time.perf_counter()
        landed = move(tiled)
        jax.block_until_ready(landed)
        wall = time.perf_counter() - t0

        wire_now = payload_wire_bytes(payload, self.transfer_mode)
        self.handoffs += 1
        self.handoff_wall_s += wall
        self.handoff_wire_bytes += wire_now
        trace.tracer().emit(
            "transfer", t0, t0 + wall, tag=self.trace_tag,
            mechanism=self.transfer_mode.name, wire_bytes=wire_now,
            requests=len(art.reqs),
            charge="measured" if measured else "modeled",
        )
        share = wall / max(len(art.reqs), 1)
        # per-request TRUE cache lengths ride the (already materialized)
        # landed metadata — for feature-carrying requests the cache extends
        # past the prompt, so len(prompt_tokens) would undercount
        true_lens = np.asarray(landed["meta"]["lengths"])
        req_bytes = [
            _META_BYTES + kvc.request_cache_nbytes(
                art.caches, int(true_lens[j]), itemsize=self._wire_isz,
            )
            for j in range(len(art.reqs))
        ]
        tot_bytes = max(sum(req_bytes), 1)
        for req, nbytes in zip(art.reqs, req_bytes):
            rec = self._records[req.request_id]
            self.handoff_request_bytes += nbytes
            # each request's prefix-proportional share of the bytes the
            # collective ACTUALLY moved (block rounding + co-rider dummy
            # rows included): modeled hop and TCP CPU both charge on this,
            # so the per-request stages sum to the real wire cost
            wire_share = wire_now * nbytes / tot_bytes
            # every co-admitted request waits the FULL collective wall
            # before its first token; the charged stage splits it (measured
            # attribution, like preprocess/inference) or models the hop on
            # this request's share of the moved bytes
            rec.transfer_wall_s += wall
            rec.add(
                "transfer",
                share if measured
                else self.profile.handoff_time(self.hop, wire_share),
            )
            if self.hop is Transport.TCP:
                # the host stack keeps the CPU on the handoff data path,
                # symmetric with the gateway's ingress/egress accounting;
                # sum(cpu_s) == wire * tcp_cpu_per_byte exactly
                rec.cpu_s += wire_share * self.profile.tcp_cpu_per_byte
        caches, meta = self._land_jit(landed["caches"], landed["meta"])
        # n_rows stays == len(reqs): the pow2-rounded wire extent is a
        # transport detail, not part of the artifact's occupancy contract
        art = dataclasses.replace(
            art, caches=caches,
            slot_idx=np.asarray(meta["slot_idx"]), lengths=meta["lengths"],
            next_tokens=meta["next_tokens"], max_new=meta["max_new"],
        )
        # warm_s rides along so the caller excludes it from 'preprocess';
        # the charged transfer wall above is the steady-state `wall` only
        return art, wall + warm_s

    def counters(self) -> dict:
        out = super().counters()
        out.update(
            handoffs=self.handoffs,
            handoff_wire_bytes=self.handoff_wire_bytes,
            handoff_request_bytes=self.handoff_request_bytes,
            handoff_payload_bytes=self.handoff_payload_bytes,
        )
        return out

    def _ttft_adjust(self, rec) -> float:
        # measured charge: the handoff wall is already inside the latency
        # stamps — adjust by 0. modeled charge (host-device runs): swap the
        # FULL non-representative collective wall the request waited for
        # out of the stamps and fold the profile-modeled hop in.
        if self._measured():
            return 0.0
        return rec.stage_s.get("transfer", 0.0) - rec.transfer_wall_s
