"""Disaggregated prefill->decode serving tier: the paper's multi-stage
pipeline study on the REAL JAX serving path.

A :class:`DisaggregatedEngine` runs admission+prefill as one stage and the
decode slot pool as another, and hands each admitted request's KV cache
across the mesh "pod" axis via ``core.transfer.kv_transfer``. The hop
mechanism is selectable per deployment and maps onto the paper's taxonomy:

  DIRECT_HBM  (GDR)  : collective permute straight into decode-pod HBM.
  DIRECT_DMA  (RDMA) : permute + one pinned-host bounce copy.
  HOST_STAGED (TCP)  : int8-requantized payload (per-source-pod scales),
                       two staging copies, CPU on the data path.

Every handoff carries per-request slot metadata (true lengths, first
tokens, slot indices, budgets) alongside the cache leaves, so the decode
pool splices a FOREIGN artifact through the same entry point a local
prefill uses. The handoff cost lands in the request's 'transfer' stage and
its TTFT: measured (``block_until_ready`` wall) on real multi-pod
hardware, or charged from the calibrated ``TransportProfile.handoff_time``
model on host-device runs — where the collective's CPU wall says nothing
about NIC mechanisms — with the non-representative measured wall swapped
out of the latency stamps.

On a multi-device backend the collective genuinely crosses the pod axis
(CI runs it on 8 forced host devices); on one device the pod axis
degenerates to an identity permute, so the full tier — tiling,
quantization, metadata round-trip, splice — still executes in tier-1
tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transfer import (
    MODE_TRANSPORT,
    TransferMode,
    kv_transfer,
    payload_wire_bytes,
    pod_take,
    pod_tile,
    wire_itemsize,
)
from repro.core.transport import Transport
from repro.models import kvcache as kvc
from repro.serving.engine import PrefillArtifact, ServingEngine

# per-row slot metadata riding the handoff: lengths/next_token/slot/max_new
_META_BYTES = 16


def make_pod_mesh(npods: Optional[int] = None):
    """('pod',)-axis mesh over the first ``npods`` devices (default 2 when
    the backend has them, else the 1-pod degenerate mesh)."""
    from jax.sharding import Mesh

    avail = jax.devices()
    npods = min(2, len(avail)) if npods is None else npods
    if npods > len(avail):
        raise ValueError(f"npods {npods} > available devices {len(avail)}")
    return Mesh(np.asarray(avail[:npods]), ("pod",))


class DisaggregatedEngine(ServingEngine):
    """ServingEngine whose prefill output crosses a pod boundary before it
    reaches the decode slot pool.

    charge: 'measured' bills the handoff's block_until_ready wall,
    'modeled' bills ``profile.handoff_time`` on the request's wire bytes,
    'auto' (default) picks measured on accelerator backends and modeled on
    host-device (CPU) runs.
    """

    def __init__(self, model, params, *,
                 transfer_mode: TransferMode = TransferMode.DIRECT_HBM,
                 mesh=None, prefill_pod: int = 0,
                 decode_pod: Optional[int] = None,
                 charge: str = "auto", **kw):
        if kw.get("legacy"):
            raise ValueError(
                "disaggregated tier requires the fast path (legacy=True "
                "keeps prefill and decode fused in one synchronous loop)"
            )
        if charge not in ("auto", "measured", "modeled"):
            raise ValueError(f"charge must be auto|measured|modeled: {charge}")
        super().__init__(model, params, **kw)
        self.mesh = mesh if mesh is not None else make_pod_mesh()
        self.npods = self.mesh.shape["pod"]
        self.transfer_mode = transfer_mode
        self.hop = MODE_TRANSPORT[transfer_mode]
        self.prefill_pod = prefill_pod
        self.decode_pod = (self.npods - 1) if decode_pod is None else decode_pod
        self.charge = charge
        self.handoffs = 0
        self.handoff_wire_bytes = 0  # bytes the collective actually moved
        self.handoff_request_bytes = 0  # useful bytes (true KV prefixes)
        self.handoff_wall_s = 0.0
        self._xfer_jit: dict = {}

    # ------------------------------------------------------------------ #
    def _measured(self) -> bool:
        if self.charge == "auto":
            return jax.default_backend() != "cpu"
        return self.charge == "measured"

    def _xfer(self, mode: TransferMode):
        """Jitted tile -> permute -> take for one mechanism (one dispatch;
        compiles once per payload shape-set)."""
        if mode not in self._xfer_jit:
            perm = ([(self.prefill_pod, self.decode_pod)]
                    if self.npods > 1 else [(0, 0)])

            def impl(payload, *, _mode=mode, _perm=perm):
                tiled = pod_tile(payload, self.npods, self.prefill_pod)
                moved = kv_transfer(tiled, self.mesh, mode=_mode, perm=_perm)
                return pod_take(moved, self.decode_pod)

            self._xfer_jit[mode] = jax.jit(impl)
        return self._xfer_jit[mode]

    def request_handoff_bytes(self, true_len: int) -> int:
        """Wire bytes one request's KV prefix + slot metadata put on the
        inter-stage hop under this deployment's mechanism."""
        return _META_BYTES + kvc.request_cache_nbytes(
            self.pool.caches, true_len, itemsize=self._wire_isz,
        )

    def _wire_isz(self, leaf) -> int:
        return wire_itemsize(leaf.dtype, self.transfer_mode)

    # ------------------------------------------------------------------ #
    def _handoff(self, art: PrefillArtifact):
        """Move the prefill artifact across the pod boundary and charge each
        riding request for its share."""
        payload = {
            "caches": art.caches,
            "meta": {
                "lengths": art.lengths,
                "next_tokens": art.next_tokens,
                "slot_idx": jnp.asarray(art.slot_idx),
                "max_new": art.max_new,
            },
        }
        t0 = time.perf_counter()
        landed = self._xfer(self.transfer_mode)(payload)
        jax.block_until_ready(landed)
        wall = time.perf_counter() - t0

        self.handoffs += 1
        self.handoff_wall_s += wall
        self.handoff_wire_bytes += payload_wire_bytes(
            payload, self.transfer_mode
        )
        measured = self._measured()
        share = wall / max(len(art.reqs), 1)
        for req in art.reqs:
            rec = self._records[req.request_id]
            nbytes = _META_BYTES + kvc.request_cache_nbytes(
                art.caches, len(req.prompt_tokens), itemsize=self._wire_isz,
            )
            self.handoff_request_bytes += nbytes
            # every co-admitted request waits the FULL collective wall
            # before its first token; the charged stage splits it (measured
            # attribution, like preprocess/inference) or models the hop on
            # this request's own wire bytes
            rec.transfer_wall_s += wall
            rec.add(
                "transfer",
                share if measured
                else self.profile.handoff_time(self.hop, nbytes),
            )
            if self.hop is Transport.TCP:
                # the host stack keeps the CPU on the handoff data path,
                # symmetric with the gateway's ingress/egress accounting
                rec.cpu_s += nbytes * self.profile.tcp_cpu_per_byte
        meta = landed["meta"]
        art = dataclasses.replace(
            art, caches=landed["caches"], lengths=meta["lengths"],
            next_tokens=meta["next_tokens"], max_new=meta["max_new"],
        )
        return art, wall

    def _ttft_adjust(self, rec) -> float:
        # measured charge: the handoff wall is already inside the latency
        # stamps — adjust by 0. modeled charge (host-device runs): swap the
        # FULL non-representative collective wall the request waited for
        # out of the stamps and fold the profile-modeled hop in.
        if self._measured():
            return 0.0
        return rec.stage_s.get("transfer", 0.0) - rec.transfer_wall_s
