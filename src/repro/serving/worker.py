"""Replica worker: the child-process half of the socket RPC control plane.

``python -m repro.serving.worker --port P`` is what
:class:`~repro.serving.ipc.ReplicaClient` spawns — one per
``backend="process"`` replica. The worker connects back to the parent's
listening socket, answers the ``hello`` clock handshake **before
importing jax** (so the offset estimate is a socket RTT, not an import
stall), then receives an ``init`` spec and builds its OWN copy of the
serving stack inside this process:

* its own XLA client over the forced host-device subset the parent put
  in this process's ``XLA_FLAGS`` (each replica process owns its devices
  the way each of the paper's stage nodes owns its accelerators);
* the model + params, rebuilt deterministically from
  ``model.init(jax.random.key(param_seed))`` — params cross the process
  boundary as a seed, not as tensors, which is why the token-identity
  check against the in-process baseline is meaningful (both sides must
  reconstruct the SAME weights from the same seed);
* a :class:`~repro.serving.engine.ServingEngine` (or
  ``DisaggregatedEngine``) wrapped in the threaded
  :class:`~repro.serving.engine.EnginePipeline`, so dispatch, device
  harvest, and detokenize/record-finalize overlap inside the replica
  while the parent's router is off doing something else entirely.

After init it is a plain RPC server: submit / harvest / load /
telemetry / drain / shutdown, each answered with one frame. Any
exception is caught and shipped back as an ``("error", {traceback})``
frame — the parent surfaces it as a :class:`~repro.serving.ipc.
ReplicaError` instead of hanging. EOF from the parent (a crashed or
impatient router) exits the process, so workers can't outlive their
cluster even if the atexit reaper never runs.
"""

from __future__ import annotations

import argparse
import socket
import sys
import time
import traceback


def _build_pipeline(spec: dict):
    """Build model -> params -> engine -> EnginePipeline from the init
    spec. Runs after the handshake; this is where jax gets imported and
    the replica's own XLA client comes up over its forced devices."""
    import jax
    import jax.numpy as jnp

    from repro.models.model import Model
    from repro.serving.engine import EnginePipeline, ServingEngine

    model = Model(spec["cfg"], dtype=getattr(jnp, spec.get("dtype", "float32")))
    # weights from the seed, not the wire: deterministic reconstruction is
    # the cheap, exact alternative to shipping tensors through the RPC
    params = model.init(jax.random.key(int(spec.get("param_seed", 0))))
    if spec.get("tracing"):
        from repro.core import trace

        # spans are stamped with THIS process's perf_counter; the parent
        # rebases on ingest and overrides the label with the replica name
        trace.enable_tracing(process=spec.get("trace_label", "worker"))
    engine_kw = dict(spec.get("engine_kw") or {})
    if spec.get("engine", "fused") == "disagg":
        from repro.serving.disagg import DisaggregatedEngine

        eng = DisaggregatedEngine(model, params, **engine_kw)
    else:
        eng = ServingEngine(model, params, **engine_kw)
    return EnginePipeline(eng, backlog=int(spec.get("backlog", 2)))


def _snapshot(pipe) -> dict:
    return pipe.load_snapshot()


def _spans() -> list:
    """Drain this process's trace buffer as wire tuples — piggybacked on
    harvest/telemetry/drain replies, rebased parent-side."""
    from repro.core import trace

    return trace.tracer().drain_wire()


def _harvest(pipe) -> dict:
    """Finished responses + their records since the last harvest, in
    completion order, plus a fresh load snapshot."""
    from repro.serving import ipc

    done = []
    for rsp in pipe.step():
        rec = pipe.engine._records[rsp.request_id]
        done.append((ipc.response_to_wire(rsp), ipc.record_to_wire(rec)))
    return {"done": done, "load": _snapshot(pipe), "spans": _spans()}


def _telemetry(pipe) -> dict:
    eng = pipe.engine
    return {
        "load": _snapshot(pipe),
        "decode_steps": eng.decode_steps,
        "useful_steps": eng.useful_steps,
        "prefill_compile_count": eng.prefill_compile_count,
        "prefill_tokens_total": eng.prefill_tokens_total,
        "prefill_tokens_uncached": eng.prefill_tokens_uncached,
        "prefix_hits": eng.prefix_hits,
        "warm_s": eng.warm_s,
        "metrics": pipe.metrics_snapshot(),
        "spans": _spans(),
    }


def _drain(pipe, deadline_s: float) -> dict:
    """Run the pipeline to idle (bounded), returning every finished pair
    harvested along the way."""
    from repro.serving import ipc

    done = []
    t_end = time.perf_counter() + float(deadline_s)
    while not pipe.idle:
        for rsp in pipe.step():
            rec = pipe.engine._records[rsp.request_id]
            done.append((ipc.response_to_wire(rsp), ipc.record_to_wire(rec)))
        if time.perf_counter() > t_end:
            raise TimeoutError(
                f"drain deadline {deadline_s}s lapsed with the pipeline "
                f"still busy: {pipe.load_snapshot()}"
            )
        time.sleep(0.0005)
    for rsp in pipe.step():  # finals surfaced by the last transition to idle
        rec = pipe.engine._records[rsp.request_id]
        done.append((ipc.response_to_wire(rsp), ipc.record_to_wire(rec)))
    pipe.trace_flush()  # close the open decode window before shipping
    return {"done": done, "load": _snapshot(pipe), "spans": _spans()}


def serve(port: int) -> int:
    # framing helpers only — repro.serving.ipc must stay importable
    # without jax side effects (it is: pure stdlib at module level)
    from repro.serving import ipc

    sock = socket.create_connection(("127.0.0.1", port), timeout=30.0)
    sock.settimeout(None)  # parent owns all deadlines; the worker blocks
    pipe = None
    try:
        while True:
            try:
                op, payload, _ = ipc.recv_msg(sock)
            except ipc.ConnectionClosed:
                return 0  # parent went away: die with it, leave no orphan
            try:
                if op == "hello":
                    # pre-jax clock sample for the parent's skew estimate
                    ipc.send_msg(sock, "ok", {"t_child": time.perf_counter()})
                elif op == "init":
                    t0 = time.perf_counter()
                    pipe = _build_pipeline(payload)
                    import jax

                    ipc.send_msg(sock, "ok", {
                        "init_s": time.perf_counter() - t0,
                        "devices": jax.device_count(),
                        "warm_s": pipe.engine.warm_s,
                    })
                elif pipe is None:
                    raise RuntimeError(f"op {op!r} before init")
                elif op == "submit":
                    req = ipc.request_from_wire(payload)
                    pipe.submit(req)
                    ipc.send_msg(sock, "ok", _snapshot(pipe))
                elif op == "harvest":
                    ipc.send_msg(sock, "ok", _harvest(pipe))
                elif op == "load":
                    ipc.send_msg(sock, "ok", _snapshot(pipe))
                elif op == "telemetry":
                    ipc.send_msg(sock, "ok", _telemetry(pipe))
                elif op == "drain":
                    ipc.send_msg(
                        sock, "ok",
                        _drain(pipe, payload.get("deadline_s", 120.0)),
                    )
                elif op == "shutdown":
                    if pipe is not None:
                        pipe.close()
                    ipc.send_msg(sock, "ok", None)
                    return 0
                else:
                    raise RuntimeError(f"unknown op {op!r}")
            except Exception:
                # ship the traceback; the parent raises it as ReplicaError
                ipc.send_msg(sock, "error",
                             {"traceback": traceback.format_exc()})
    finally:
        if pipe is not None:
            pipe.close()
        try:
            sock.close()
        except OSError:
            pass


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--port", type=int, required=True,
                    help="parent's listening port on 127.0.0.1")
    args = ap.parse_args(argv)
    return serve(args.port)


if __name__ == "__main__":
    sys.exit(main())
