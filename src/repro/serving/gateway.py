"""Gateway / proxied-connection tier (paper §IV-B).

Wraps an engine (or a downstream gateway) and adds the first-hop transport
cost plus the protocol-translation overhead. Composing
``Gateway(TCP) -> engine(GDR)`` is the paper's TCP/GDR configuration — the
"accelerate only the last hop" deployment that captures most of the benefit.
"""

from __future__ import annotations

import time

from repro.core import trace
from repro.core.transport import PAPER_A2, Transport, TransportProfile


class Gateway:
    def __init__(self, engine, *, first_hop: Transport = Transport.TCP,
                 profile: TransportProfile = PAPER_A2,
                 translation_overhead_s: float = 40e-6):
        self.engine = engine
        self.first_hop = first_hop
        self.profile = profile
        self.overhead = translation_overhead_s

    def submit(self, req, now: float):
        self.engine.submit(req, now)
        rec = self.engine._records[req.request_id]
        hop = self.profile.wire_time(self.first_hop, rec.bytes_in)
        rec.add("request", hop + self.overhead)
        # instant span: the hop cost is MODELED (profile wire time), not a
        # measured wall — the duration rides as an attr, not the interval
        trace.tracer().emit(
            "gateway.submit", now, now, request_id=req.request_id,
            hop_s=hop + self.overhead, transport=self.first_hop.name,
            bytes=rec.bytes_in, charge="modeled",
        )
        if self.first_hop is Transport.TCP:
            rec.cpu_s += rec.bytes_in * self.profile.tcp_cpu_per_byte

    def step(self):
        done = self.engine.step()
        for rsp in done:
            nbytes = 4 * len(rsp.tokens)
            hop = self.profile.wire_time(self.first_hop, nbytes) + self.overhead
            rsp.stage_s["response"] = rsp.stage_s.get("response", 0.0) + hop
            rsp.total_s += hop
            tnow = time.perf_counter()
            trace.tracer().emit(
                "gateway.response", tnow, tnow, request_id=rsp.request_id,
                hop_s=hop, transport=self.first_hop.name, bytes=nbytes,
                charge="modeled",
            )
            rec = self._records.get(rsp.request_id)
            if rec is not None:
                # charge the STORED record symmetrically with ``submit``'s
                # request hop: the returned Response alone would leave
                # ProfileStore under-reporting gateway deployments
                # (stage_s["response"] short one hop, t_done stale).
                # Request.t_done keeps the ENGINE-side completion stamp —
                # the gateway only sees Responses, so end-to-end time lives
                # on the record and the Response, not the Request.
                rec.add("response", hop)
                rec.t_done += hop
                if self.first_hop is Transport.TCP:
                    # TCP keeps the CPU on the data path on BOTH hops
                    # (paper Fig. 9)
                    rec.cpu_s += nbytes * self.profile.tcp_cpu_per_byte
        return done

    def run_until_drained(self, max_steps: int = 10_000):
        out = []
        for _ in range(max_steps):
            got = self.step()
            out.extend(got)
            if self.engine.idle:
                break
            if not got and self.async_draining:
                # downstream progress happens on its own threads or in
                # replica processes; polling harder only burns the CPU
                # the paper's TCP path is trying to account for
                time.sleep(0.001)
        return out

    @property
    def queue(self):
        return self.engine.queue

    @property
    def idle(self):
        return self.engine.idle

    @property
    def async_draining(self) -> bool:
        """True when the wrapped engine drains on its own (threaded
        pipeline / process replicas) — stepping just collects results."""
        return bool(getattr(self.engine, "async_draining", False))

    @property
    def _records(self):
        return self.engine._records

    @property
    def store(self):
        return self.engine.store

    def close(self):
        """Pass shutdown downstream (process-backed clusters reap their
        workers); no-op over plain engines."""
        down = getattr(self.engine, "close", None)
        if callable(down):
            down()

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
