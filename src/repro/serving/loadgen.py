"""Traffic generation for the serving engines and the cluster tier.

Three arrival processes, all seeded and deterministic (same seed -> the
same arrival offsets AND the same prompt token arrays, asserted in
tests):

* **Open-loop Poisson** (:func:`poisson_schedule`) — exponential
  interarrival gaps at ``rate_rps``. Open-loop means arrivals do NOT wait
  for completions; when service falls behind, backlog (the per-request
  'queue' stage) grows without bound — exactly the tail-latency regime a
  closed loop can never produce, because a closed loop throttles itself
  to the server's pace.
* **Shared-prefix Poisson** (:func:`shared_prefix_schedule`) — the same
  open-loop arrival process, but prompts share Zipf-distributed system
  prefixes (``n_prefixes`` fixed prefix arrays + fresh per-request
  suffixes), the workload shape that exercises the paged engines'
  radix prefix reuse and the router's ``prefix_cache`` policy.
* **Trace replay** (:func:`trace_schedule` / :func:`load_trace` /
  :func:`save_trace`) — explicit per-request arrival offsets, prompt
  lengths, budgets, priorities from a JSON-lines trace file or an
  in-memory list of dicts. The benchmark's skewed trace (alternating
  heavy/light budgets) is expressed this way.
* **Closed-loop baseline** (:func:`run_closed_loop_baseline`) — N
  clients, each re-submitting on completion (``serving/client.py``), the
  paper's SS-III-B workload model and the right A/B control for the open
  loop.

:func:`run_open_loop` is the wall-clock driver: it submits each request
when its arrival time comes due regardless of engine state, steps the
engine/cluster between arrivals, and returns the completion-ordered
responses (each already carrying queue/prefill/transfer/decode stage
breakdowns from the engine records).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled submission: ``t`` seconds after the run starts."""

    t: float
    request: Request


def _make_request(rng, vocab: int, prompt_len: int, max_new: int,
                  client_id: int = 0, priority: int = 0) -> Request:
    return Request(
        prompt_tokens=rng.integers(0, vocab, int(prompt_len),
                                   dtype=np.int32),
        max_new_tokens=int(max_new),
        client_id=int(client_id),
        priority=int(priority),
    )


def poisson_schedule(vocab: int, *, rate_rps: float, n_requests: int,
                     prompt_lens=(8, 16, 32, 64), max_new: int = 8,
                     seed: int = 0, client_id: int = 0) -> list:
    """Open-loop Poisson arrivals: exponential gaps at ``rate_rps``,
    prompt lengths drawn uniformly from ``prompt_lens``. Deterministic in
    ``seed`` (gaps, lengths, and token contents all come from one
    ``default_rng(seed)`` stream)."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0: {rate_rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    times = np.cumsum(gaps)
    lens = rng.choice(np.asarray(prompt_lens, np.int64), size=n_requests)
    return [
        Arrival(float(times[i]),
                _make_request(rng, vocab, lens[i], max_new, client_id))
        for i in range(n_requests)
    ]


def shared_prefix_schedule(vocab: int, *, rate_rps: float, n_requests: int,
                           n_prefixes: int = 4, prefix_len: int = 64,
                           suffix_len: int = 16, zipf_a: float = 1.1,
                           max_new: int = 8, seed: int = 0,
                           client_id: int = 0) -> list:
    """Open-loop Poisson arrivals over Zipf-distributed SHARED system
    prompts: each request's prompt is one of ``n_prefixes`` fixed prefix
    token arrays (popularity ``p(k) ∝ 1/k^zipf_a``, the few-hot-system-
    prompts shape real serving fleets see) followed by ``suffix_len``
    fresh tokens unique to the request. With a page-aligned
    ``prefix_len``, repeats of a hot prefix are exactly what the paged
    engines' radix index turns into cached pages — the achieved hit rate
    is a property of THIS schedule, which is why the prefix benchmark
    sweeps it here rather than inside the engine.

    ``prefix_len=0`` degrades to independent prompts (the 0%-hit
    control). Deterministic in ``seed``: prefix contents, Zipf draws,
    gaps, and suffixes all come from one ``default_rng(seed)`` stream.
    """
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0: {rate_rps}")
    if n_prefixes < 1:
        raise ValueError(f"n_prefixes must be >= 1: {n_prefixes}")
    if suffix_len < 1:
        raise ValueError(
            f"suffix_len must be >= 1 (a request needs at least one "
            f"uncached token to produce first logits): {suffix_len}"
        )
    rng = np.random.default_rng(seed)
    prefixes = [
        rng.integers(0, vocab, int(prefix_len), dtype=np.int32)
        for _ in range(n_prefixes)
    ]
    weights = 1.0 / np.arange(1, n_prefixes + 1, dtype=np.float64) ** zipf_a
    weights /= weights.sum()
    which = rng.choice(n_prefixes, size=n_requests, p=weights)
    gaps = rng.exponential(1.0 / rate_rps, n_requests)
    times = np.cumsum(gaps)
    out = []
    for i in range(n_requests):
        suffix = rng.integers(0, vocab, int(suffix_len), dtype=np.int32)
        prompt = np.concatenate([prefixes[which[i]], suffix])
        out.append(Arrival(
            float(times[i]),
            Request(prompt_tokens=prompt, max_new_tokens=int(max_new),
                    client_id=int(client_id)),
        ))
    return out


def trace_schedule(entries, vocab: int, *, seed: int = 0) -> list:
    """Arrival schedule from trace entries (dicts with ``t`` seconds,
    ``prompt_len``, and optional ``max_new``/``client_id``/``priority``).
    Prompt token contents are drawn from ``seed``; the entries provide
    timing and shape, so a saved trace replays identically."""
    rng = np.random.default_rng(seed)
    out = []
    for e in entries:
        out.append(Arrival(
            float(e["t"]),
            _make_request(rng, vocab, e["prompt_len"], e.get("max_new", 8),
                          e.get("client_id", 0), e.get("priority", 0)),
        ))
    if any(out[i].t > out[i + 1].t for i in range(len(out) - 1)):
        raise ValueError("trace arrival times must be non-decreasing")
    return out


def load_trace(path: str) -> list:
    """Read a JSON-lines trace file (one entry dict per line; blank lines
    and ``#`` comment lines skipped)."""
    entries = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                entries.append(json.loads(line))
    return entries


def save_trace(path: str, entries) -> None:
    """Write trace entries as JSON lines (the :func:`load_trace` format)."""
    with open(path, "w") as f:
        for e in entries:
            f.write(json.dumps(e) + "\n")


def run_open_loop(engine, schedule: list, *, max_steps: int = 1_000_000,
                  poll_s: float = 0.002) -> list:
    """Drive ``engine`` (a ServingEngine, DisaggregatedEngine,
    ServingCluster, or a Gateway over any of them) with wall-clock
    open-loop arrivals.

    Each request is submitted when its offset comes due — never gated on
    completions — and the engine steps continuously in between, so
    pre-admission backlog lands in the 'queue' stage of each record.
    Returns responses in completion order; raises if the drain exceeds
    ``max_steps`` (a stuck engine, not a slow one).
    """
    sched = sorted(schedule, key=lambda a: a.t)
    out = []
    i = 0
    steps = 0
    t0 = time.perf_counter()
    while i < len(sched) or not engine.idle:
        now = time.perf_counter() - t0
        while i < len(sched) and sched[i].t <= now:
            engine.submit(sched[i].request, time.perf_counter())
            i += 1
        if engine.idle and i < len(sched):
            # nothing to step: sleep up to the next arrival (capped so a
            # long gap still polls the clock)
            time.sleep(min(max(sched[i].t - now, 0.0), poll_s))
            continue
        got = engine.step()
        out.extend(got)
        if not got and getattr(engine, "async_draining", False):
            # asynchronously-draining engines (threaded pipeline, process
            # replicas) make progress on their own — spinning here would
            # charge pure polling to the driver host's CPU (and pollute
            # the modeled-host cpu_s comparisons). Sleep until the next
            # arrival is due, capped at poll_s so completions are still
            # collected promptly.
            now = time.perf_counter() - t0
            wait = poll_s if i >= len(sched) else min(
                max(sched[i].t - now, 0.0), poll_s
            )
            if wait > 0.0:
                time.sleep(wait)
        steps += 1
        if steps > max_steps:
            raise RuntimeError(
                f"open-loop drain exceeded {max_steps} steps with "
                f"{len(out)}/{len(sched)} responses"
            )
    return out


def run_closed_loop_baseline(engine, vocab: int, *, n_clients: int = 4,
                             requests_per_client: int = 4,
                             prompt_len: int = 32, max_new_tokens: int = 8,
                             seed: int = 0) -> list:
    """Closed-loop control: ``n_clients`` clients, each submitting its
    next request only when the previous completes (``serving/client.py``).
    Returns the flat completion list across clients. Concurrency is
    capped at ``n_clients`` by construction — the backlog an open loop
    measures cannot form here, which is exactly why the paper's
    tail-latency story needs the open loop."""
    from repro.serving.client import ClosedLoopClient, run_closed_loop

    clients = [
        ClosedLoopClient(i, vocab, prompt_len=prompt_len,
                         max_new_tokens=max_new_tokens, seed=seed)
        for i in range(n_clients)
    ]
    run_closed_loop(engine, clients, requests_per_client)
    return [r for c in clients for r in c.completed]
