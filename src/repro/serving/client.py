"""Closed-loop load generator (paper §III-B: each client sends requests in a
closed loop)."""

from __future__ import annotations

import time

import numpy as np

from repro.serving.request import Request


class ClosedLoopClient:
    def __init__(self, client_id: int, vocab: int, *, prompt_len: int = 32,
                 max_new_tokens: int = 8, priority: int = 0, seed: int = 0):
        self.client_id = client_id
        self.vocab = vocab
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.priority = priority
        self.rng = np.random.default_rng(seed + client_id)
        self.inflight = None
        self.completed = []

    def make_request(self) -> Request:
        toks = self.rng.integers(0, self.vocab, self.prompt_len, dtype=np.int32)
        req = Request(
            prompt_tokens=toks,
            max_new_tokens=self.max_new_tokens,
            priority=self.priority,
            client_id=self.client_id,
        )
        self.inflight = req.request_id
        return req

    def complete(self, response):
        assert response.request_id == self.inflight
        self.inflight = None
        self.completed.append(response)


def run_closed_loop(engine, clients, requests_per_client: int):
    """Drive the engine with closed-loop clients until all finish."""
    remaining = {c.client_id: requests_per_client for c in clients}
    by_req = {}
    for c in clients:
        req = c.make_request()
        by_req[req.request_id] = c
        engine.submit(req, time.perf_counter())
        remaining[c.client_id] -= 1
    while True:
        done = engine.step()
        for rsp in done:
            c = by_req.pop(rsp.request_id)
            c.complete(rsp)
            if remaining[c.client_id] > 0:
                req = c.make_request()
                by_req[req.request_id] = c
                engine.submit(req, time.perf_counter())
                remaining[c.client_id] -= 1
        if not by_req and not engine.queue:
            break
    return clients
