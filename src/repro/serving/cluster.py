"""Multi-replica serving cluster: a load-balancing router over engine
replicas, each committed to its own pod slice of a cluster mesh.

The paper frames model serving as a multi-stage pipeline "across multiple
compute nodes and proxies" with dynamic load-balancing requirements; its
latency breakdowns are about where time goes once a request enters that
fabric. This module is the layer that makes those quantities measurable on
the real serving path: N independent :class:`~repro.serving.engine.
ServingEngine` / :class:`~repro.serving.disagg.DisaggregatedEngine`
replicas behind a :class:`Router`, with per-request 'queue' accounting and
warmup-aware TTFT/TPOT/E2E percentile telemetry
(``core.metrics.slo_summary``). Composing ``Gateway(TCP) ->
ServingCluster -> GDR replicas`` reproduces the paper's proxied deployment
shape end to end: TCP first hop, router admission, hardware-accelerated
last hop inside each replica.

**Replicas.** :meth:`ServingCluster.build` carves a
``launch.mesh.make_cluster_mesh`` pod axis into per-replica slices
(``pods_per_replica`` 1 for fused engines, 2 for disaggregated
prefill/decode pairs). A fused replica's params and decode-pool state are
committed to its slice via the ``sharding.partition`` helpers
(``place_on_slice`` / ``slice_sharding``), so its jits provably execute
there; a disaggregated replica receives its slice as its own 2-pod mesh
(``pod_slice_mesh`` keeps the axis name) and applies its usual per-stage
:class:`~repro.serving.disagg.PodPlacement` WITHIN the slice. On a
backend with fewer devices than slices, slices overlap modulo the pod
axis — the single-CPU degenerate case that keeps the tier in tier-1
tests.

**Router policies** (:class:`Router`):

  round_robin  : static rotation — the baseline every queueing result is
                 held against.
  jsq          : join-shortest-queue — fewest requests in system (queued
                 + occupying a decode slot), ties broken by outstanding
                 work then index.
  least_loaded : fewest outstanding TOKENS (queued budgets + live slots'
                 remaining budgets + free-slot headroom) — work-FIRST
                 where jsq is count-first, so one long-budget decode
                 outweighs several 2-token requests.
  affinity     : pow2-bucket stickiness — same-prefill-bucket admissions
                 co-locate on one replica (new buckets go to the replica
                 with the fewest sticky buckets, then least loaded), so
                 each replica compiles/warms a fraction of the bucket
                 grid and same-bucket bursts batch into one padded
                 prefill.
  prefix_cache : prefix-hit-probability routing for paged engines with
                 shared-prefix reuse — each replica is scored by its own
                 radix index's longest cached-prefix match against the
                 request's prompt (``engine.prefix_lookup_tokens``, an
                 LRU-neutral peek), and the request goes where the most
                 prompt tokens are already resident (ties break by
                 outstanding tokens). A request no replica has seen
                 (all-zero scores) falls back to sticky first-page
                 placement, so the NEXT request sharing its system
                 prompt scores a hit on the replica that indexed this
                 one instead of re-prefilling the prefix elsewhere.

Routing happens at submit: the request joins the chosen replica's
admission queue immediately, so the engine-level 'queue' stage (submit ->
admission pick) measures exactly the backlog the policy created — the
quantity the benchmark's skewed-trace comparison pins (jsq/least_loaded
beat round_robin on p99 TTFT, and the queue stage accounts for the
difference).

**Telemetry.** :meth:`ServingCluster.telemetry` merges every replica's
records and reports SLO percentiles (TTFT/TPOT/E2E/queue p50/p95/p99),
per-replica routed counts and mean occupancy, and Jain balance indices
over busy-slot time and routed counts. ``warmup=k`` drops the first k
completions (cold-start compiles) from the percentiles.

Driven open-loop (Poisson or trace arrivals) or closed-loop by
``serving/loadgen.py``; swept policy x arrival rate x transfer mechanism
by ``benchmarks/cluster.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.metrics import jain_index, slo_summary
from repro.core.profiler import ProfileStore
from repro.serving.engine import ServingEngine


def replica_pod_slices(n_pods: int, n_replicas: int,
                       pods_per_replica: int) -> list:
    """Pod-index tuple for each replica: replica i owns pods
    [i*ppr, (i+1)*ppr), wrapped modulo the mesh's pod axis (and deduped)
    when the backend has fewer devices than the cluster asked for."""
    out = []
    for i in range(n_replicas):
        pods = {
            (i * pods_per_replica + j) % n_pods
            for j in range(pods_per_replica)
        }
        out.append(tuple(sorted(pods)))
    return out


@dataclasses.dataclass
class Replica:
    """One serving engine bound to its pod slice, plus the router-visible
    load counters the admission policies read."""

    index: int
    engine: object
    pods: tuple = ()
    routed: int = 0  # requests the router sent here
    steps: int = 0  # cluster steps taken (occupancy sample count)
    busy_slot_steps: int = 0  # sum over steps of occupied slots

    @property
    def queue_depth(self) -> int:
        """Requests waiting for admission (not yet in a decode slot)."""
        return len(self.engine.queue)

    @property
    def occupancy(self) -> int:
        """Decode slots currently occupied."""
        return self.engine.max_batch - len(self.engine.pool.free_slots())

    @property
    def free_slots(self) -> int:
        return len(self.engine.pool.free_slots())

    @property
    def jobs(self) -> int:
        """Requests in system: queued + in a decode slot (the jsq metric)."""
        return self.queue_depth + self.occupancy

    @property
    def outstanding_tokens(self) -> int:
        """Token-budget view of load: queued requests' full budgets plus
        live slots' remaining budgets (the least_loaded metric — a
        48-token request weighs 24x a 2-token one where ``jobs`` counts
        them the same)."""
        queued = sum(r.max_new_tokens for r in self.engine.queue)
        live = sum(
            r.max_new_tokens - len(r.generated)
            for r in self.engine.pool.slots if r is not None
        )
        return queued + live

    @property
    def occupancy_mean(self) -> float:
        """Mean occupied-slot fraction over the cluster steps so far."""
        denom = self.steps * self.engine.max_batch
        return self.busy_slot_steps / denom if denom else 0.0


class Router:
    """Pluggable admission policy: maps a request to a replica index.

    Stateless reads of the replicas' load counters plus two bits of
    router-local state (the round-robin cursor and the affinity
    bucket->replica map); every tie breaks toward the lowest replica
    index, so routing is deterministic given the submission sequence.
    """

    POLICIES = ("round_robin", "jsq", "least_loaded", "affinity",
                "prefix_cache")

    def __init__(self, policy: str = "least_loaded"):
        if policy not in self.POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; pick one of {self.POLICIES}"
            )
        self.policy = policy
        self._rr = 0
        self._affinity: dict = {}  # prefill bucket/shape key -> replica
        self._prefix_home: dict = {}  # first prompt page -> replica

    def pick(self, req, replicas: list) -> int:
        if self.policy == "round_robin":
            i = self._rr % len(replicas)
            self._rr += 1
            return i
        if self.policy == "jsq":
            # shortest queue = fewest requests in system; ties break by
            # outstanding work (two replicas with one job each are NOT
            # equal when one job is a 2-token request and the other a
            # 192-token decode), then index — so ties stay deterministic
            # without blindly parking work behind a long decode
            return min(
                range(len(replicas)),
                key=lambda i: (replicas[i].jobs,
                               replicas[i].outstanding_tokens, i),
            )
        if self.policy == "least_loaded":
            # outstanding work first, then spare slot headroom
            return min(
                range(len(replicas)),
                key=lambda i: (replicas[i].outstanding_tokens,
                               -replicas[i].free_slots, i),
            )
        if self.policy == "prefix_cache":
            return self._pick_prefix_cache(req, replicas)
        # affinity: sticky pow2-bucket placement
        key = self._bucket_key(req, replicas[0].engine)
        if key not in self._affinity:
            counts = [0] * len(replicas)
            for r in self._affinity.values():
                counts[r] += 1
            self._affinity[key] = min(
                range(len(replicas)),
                key=lambda i: (counts[i], replicas[i].jobs, i),
            )
        return self._affinity[key]

    def _pick_prefix_cache(self, req, replicas: list) -> int:
        """Estimated prefix-hit routing: score each replica by how many
        prompt tokens its radix index already holds (a peek — no LRU or
        hit/miss distortion) and send the request to the deepest match;
        among equally-deep matches, the least-loaded replica wins. When
        no replica has any of the prompt (a cold system prompt, or
        engines without prefix reuse scoring a flat 0), fall back to a
        sticky map keyed on the prompt's FIRST page, so repeats of the
        same system prompt converge on one replica and turn its future
        lookups into hits instead of spraying cold prefills."""
        scores = [
            rep.engine.prefix_lookup_tokens(req.prompt_tokens)
            if hasattr(rep.engine, "prefix_lookup_tokens") else 0
            for rep in replicas
        ]
        if max(scores) > 0:
            return min(
                range(len(replicas)),
                key=lambda i: (-scores[i],
                               replicas[i].outstanding_tokens, i),
            )
        page = getattr(replicas[0].engine, "page", 16)
        key = tuple(int(t) for t in req.prompt_tokens[:page])
        if key not in self._prefix_home:
            self._prefix_home[key] = min(
                range(len(replicas)),
                key=lambda i: (replicas[i].outstanding_tokens,
                               replicas[i].jobs, i),
            )
        return self._prefix_home[key]

    def _bucket_key(self, req, engine):
        """The prefill shape the request admits into: its pow2 bucket on
        the bucketed path, or its exact (length, features) shape on the
        exact path — either way, co-locating equal keys means co-located
        requests share one compiled prefill."""
        if engine.bucketed_prefill and req.features is None:
            return ("bucket", engine._bucket(len(req.prompt_tokens)))
        feat = None if req.features is None else tuple(req.features.shape)
        return ("exact", len(req.prompt_tokens), feat)


class _MergedRecords:
    """Read-only mapping view over the replicas' per-request record dicts
    (what ``Gateway`` reaches through ``engine._records``)."""

    def __init__(self, dicts):
        self._dicts = dicts

    def get(self, key, default=None):
        for d in self._dicts:
            if key in d:
                return d[key]
        return default

    def __getitem__(self, key):
        rec = self.get(key)
        if rec is None:
            raise KeyError(key)
        return rec

    def __contains__(self, key) -> bool:
        return any(key in d for d in self._dicts)


class ServingCluster:
    """N engine replicas behind a :class:`Router`.

    The public surface matches a single engine — :meth:`submit`,
    :meth:`step`, :meth:`run_until_drained`, ``queue``, ``store``,
    ``idle`` — so ``Gateway``, the load generators, and the closed-loop
    client drive a cluster exactly like one engine. :meth:`step` steps
    every replica once (replicas are independent; a real deployment steps
    them in parallel processes) and samples per-replica occupancy for the
    balance telemetry.
    """

    def __init__(self, replicas: list, *, policy: str = "least_loaded",
                 router: Optional[Router] = None):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        self.replicas = [
            r if isinstance(r, Replica) else Replica(i, r)
            for i, r in enumerate(replicas)
        ]
        self.router = router if router is not None else Router(policy)
        self.responses: list = []  # completion-ordered, for telemetry
        self._where: dict = {}  # request_id -> replica index

    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, model, params, *, n_replicas: int = 2,
              engine: str = "fused", mesh=None,
              pods_per_replica: Optional[int] = None,
              policy: str = "least_loaded", router: Optional[Router] = None,
              warmup: bool = False, **engine_kw) -> "ServingCluster":
        """Construct a cluster of ``n_replicas`` engines on a cluster mesh.

        engine: 'fused' (single-stage :class:`ServingEngine` per replica,
        1 pod each by default) or 'disagg'
        (:class:`~repro.serving.disagg.DisaggregatedEngine` per replica, 2
        pods each by default — prefill and decode stages placed on their
        own pod WITHIN the replica's slice, the KV handoff crossing
        between them under ``engine_kw['transfer_mode']``).

        mesh: a ('pod',)-axis mesh to carve up; default
        ``launch.mesh.make_cluster_mesh(n_replicas, pods_per_replica)``.
        Remaining ``engine_kw`` (max_batch, max_seq, transfer_mode,
        temperature, ...) pass through to every replica's engine
        constructor; ``warmup`` pre-traces each replica after its state is
        committed to its slice.
        """
        from repro.launch.mesh import make_cluster_mesh
        from repro.sharding.partition import (
            place_on_slice,
            pod_slice_mesh,
            slice_sharding,
        )

        if engine not in ("fused", "disagg"):
            raise ValueError(f"engine must be 'fused' or 'disagg': {engine}")
        ppr = (1 if engine == "fused" else 2) \
            if pods_per_replica is None else pods_per_replica
        if mesh is None:
            mesh = make_cluster_mesh(n_replicas, ppr)
        slices = replica_pod_slices(mesh.shape["pod"], n_replicas, ppr)

        replicas = []
        for i, pods in enumerate(slices):
            if engine == "fused":
                eng = ServingEngine(
                    model, place_on_slice(params, mesh, pods),
                    warmup=False, **engine_kw,
                )
                eng.pool.place(slice_sharding(mesh, pods))
                if warmup:
                    eng.warmup, eng.warm_s = True, eng.warm()
            else:
                from repro.serving.disagg import DisaggregatedEngine

                eng = DisaggregatedEngine(
                    model, params, mesh=pod_slice_mesh(mesh, pods),
                    warmup=warmup, **engine_kw,
                )
            replicas.append(Replica(i, eng, pods))
        out = cls(replicas, policy=policy, router=router)
        out.mesh = mesh
        return out

    # ------------------------------------------------------------------ #
    def submit(self, req, now: Optional[float] = None) -> int:
        """Route ``req`` to a replica and join its admission queue; the
        replica's engine stamps arrival and charges the modeled ingress.
        Returns the replica index (recorded for telemetry)."""
        i = self.router.pick(req, self.replicas)
        rep = self.replicas[i]
        rep.engine.submit(req, now)
        rep.routed += 1
        self._where[req.request_id] = i
        return i

    def step(self) -> list:
        """One cluster iteration: step every replica once, harvest
        finished responses, sample occupancy for the balance index."""
        done = []
        for rep in self.replicas:
            done.extend(rep.engine.step())
            rep.steps += 1
            rep.busy_slot_steps += rep.occupancy
        self.responses.extend(done)
        return done

    @property
    def idle(self) -> bool:
        return all(rep.engine.idle for rep in self.replicas)

    def run_until_drained(self, max_steps: int = 10_000) -> list:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if self.idle:
                break
        return out

    # ------------------------------------------------------------------ #
    # single-engine-compatible surface (Gateway, loadgen, closed loop)
    # ------------------------------------------------------------------ #
    @property
    def queue(self) -> list:
        """All queued (unadmitted) requests across replicas."""
        return [r for rep in self.replicas for r in rep.engine.queue]

    @property
    def _records(self) -> _MergedRecords:
        return _MergedRecords([rep.engine._records for rep in self.replicas])

    @property
    def store(self) -> ProfileStore:
        """Merged ProfileStore over every replica's records (rebuilt per
        access; records are shared, not copied)."""
        s = ProfileStore()
        for rep in self.replicas:
            s.records.extend(rep.engine.store.records)
        return s

    def replica_of(self, request_id: int) -> Optional[int]:
        return self._where.get(request_id)

    # ------------------------------------------------------------------ #
    def telemetry(self, *, warmup: int = 0) -> dict:
        """SLO + balance snapshot: warmup-aware TTFT/TPOT/E2E/queue
        percentiles over the completions so far, per-replica load
        counters, and Jain balance indices (busy-slot time and routed
        counts; 1.0 = perfectly balanced, 1/n = one replica took all)."""
        busy = [rep.busy_slot_steps for rep in self.replicas]
        return {
            "policy": self.router.policy,
            "n_replicas": len(self.replicas),
            "slo": slo_summary(self.responses, warmup=warmup),
            "per_replica": [
                {
                    "pods": list(rep.pods),
                    "routed": rep.routed,
                    "busy_slot_steps": rep.busy_slot_steps,
                    "occupancy_mean": round(rep.occupancy_mean, 4),
                }
                for rep in self.replicas
            ],
            "balance_index_busy": round(jain_index(busy), 4),
            "balance_index_routed": round(
                jain_index([rep.routed for rep in self.replicas]), 4
            ),
        }
