"""Multi-replica serving cluster: a load-balancing router over engine
replicas, each committed to its own pod slice of a cluster mesh.

The paper frames model serving as a multi-stage pipeline "across multiple
compute nodes and proxies" with dynamic load-balancing requirements; its
latency breakdowns are about where time goes once a request enters that
fabric. This module is the layer that makes those quantities measurable on
the real serving path: N independent :class:`~repro.serving.engine.
ServingEngine` / :class:`~repro.serving.disagg.DisaggregatedEngine`
replicas behind a :class:`Router`, with per-request 'queue' accounting and
warmup-aware TTFT/TPOT/E2E percentile telemetry
(``core.metrics.slo_summary``). Composing ``Gateway(TCP) ->
ServingCluster -> GDR replicas`` reproduces the paper's proxied deployment
shape end to end: TCP first hop, router admission, hardware-accelerated
last hop inside each replica.

**Replicas.** :meth:`ServingCluster.build` carves a
``launch.mesh.make_cluster_mesh`` pod axis into per-replica slices
(``pods_per_replica`` 1 for fused engines, 2 for disaggregated
prefill/decode pairs). A fused replica's params and decode-pool state are
committed to its slice via the ``sharding.partition`` helpers
(``place_on_slice`` / ``slice_sharding``), so its jits provably execute
there; a disaggregated replica receives its slice as its own 2-pod mesh
(``pod_slice_mesh`` keeps the axis name) and applies its usual per-stage
:class:`~repro.serving.disagg.PodPlacement` WITHIN the slice. On a
backend with fewer devices than slices, slices overlap modulo the pod
axis — the single-CPU degenerate case that keeps the tier in tier-1
tests.

**Router policies** (:class:`Router`):

  round_robin  : static rotation — the baseline every queueing result is
                 held against.
  jsq          : join-shortest-queue — fewest requests in system (queued
                 + occupying a decode slot), ties broken by outstanding
                 work then index.
  least_loaded : fewest outstanding TOKENS (queued budgets + live slots'
                 remaining budgets + free-slot headroom) — work-FIRST
                 where jsq is count-first, so one long-budget decode
                 outweighs several 2-token requests.
  affinity     : pow2-bucket stickiness — same-prefill-bucket admissions
                 co-locate on one replica (new buckets go to the replica
                 with the fewest sticky buckets, then least loaded), so
                 each replica compiles/warms a fraction of the bucket
                 grid and same-bucket bursts batch into one padded
                 prefill.
  prefix_cache : prefix-hit-probability routing for paged engines with
                 shared-prefix reuse — each replica is scored by its own
                 radix index's longest cached-prefix match against the
                 request's prompt (``engine.prefix_lookup_tokens``, an
                 LRU-neutral peek), and the request goes where the most
                 prompt tokens are already resident (ties break by
                 outstanding tokens). A request no replica has seen
                 (all-zero scores) falls back to sticky first-page
                 placement, so the NEXT request sharing its system
                 prompt scores a hit on the replica that indexed this
                 one instead of re-prefilling the prefix elsewhere.

Routing happens at submit: the request joins the chosen replica's
admission queue immediately, so the engine-level 'queue' stage (submit ->
admission pick) measures exactly the backlog the policy created — the
quantity the benchmark's skewed-trace comparison pins (jsq/least_loaded
beat round_robin on p99 TTFT, and the queue stage accounts for the
difference).

**Telemetry.** :meth:`ServingCluster.telemetry` merges every replica's
records and reports SLO percentiles (TTFT/TPOT/E2E/queue p50/p95/p99),
per-replica routed counts and mean occupancy, and Jain balance indices
over busy-slot time and routed counts. ``warmup=k`` drops the first k
completions (cold-start compiles) from the percentiles.

Driven open-loop (Poisson or trace arrivals) or closed-loop by
``serving/loadgen.py``; swept policy x arrival rate x transfer mechanism
by ``benchmarks/cluster.py``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.core import trace
from repro.core.metrics import jain_index, merge_record_streams, slo_summary
from repro.core.obs import Registry, Sampler
from repro.core.profiler import ProfileStore, RequestRecord
from repro.serving.engine import ServingEngine, _next_pow2


def replica_pod_slices(n_pods: int, n_replicas: int,
                       pods_per_replica: int) -> list:
    """Pod-index tuple for each replica: replica i owns pods
    [i*ppr, (i+1)*ppr), wrapped modulo the mesh's pod axis (and deduped)
    when the backend has fewer devices than the cluster asked for."""
    out = []
    for i in range(n_replicas):
        pods = {
            (i * pods_per_replica + j) % n_pods
            for j in range(pods_per_replica)
        }
        out.append(tuple(sorted(pods)))
    return out


@dataclasses.dataclass
class Replica:
    """One serving engine bound to its pod slice, plus the router-visible
    load counters the admission policies read."""

    index: int
    engine: object
    pods: tuple = ()
    routed: int = 0  # requests the router sent here
    steps: int = 0  # cluster steps taken (occupancy sample count)
    busy_slot_steps: int = 0  # sum over steps of occupied slots

    @property
    def queue_depth(self) -> int:
        """Requests waiting for admission (not yet in a decode slot)."""
        return len(self.engine.queue)

    @property
    def occupancy(self) -> int:
        """Decode slots currently occupied."""
        return self.engine.max_batch - len(self.engine.pool.free_slots())

    @property
    def free_slots(self) -> int:
        return len(self.engine.pool.free_slots())

    @property
    def jobs(self) -> int:
        """Requests in system: queued + in a decode slot (the jsq metric)."""
        return self.queue_depth + self.occupancy

    @property
    def outstanding_tokens(self) -> int:
        """Token-budget view of load: queued requests' full budgets plus
        live slots' remaining budgets (the least_loaded metric — a
        48-token request weighs 24x a 2-token one where ``jobs`` counts
        them the same)."""
        queued = sum(r.max_new_tokens for r in self.engine.queue)
        live = sum(
            r.max_new_tokens - len(r.generated)
            for r in self.engine.pool.slots if r is not None
        )
        return queued + live

    @property
    def occupancy_mean(self) -> float:
        """Mean occupied-slot fraction over the cluster steps so far."""
        denom = self.steps * self.engine.max_batch
        return self.busy_slot_steps / denom if denom else 0.0

    # ------------------------------------------------------------------ #
    # backend seam: ServingCluster drives replicas only through these, so
    # in-process and process-backed replicas are interchangeable
    # ------------------------------------------------------------------ #
    @property
    def idle(self) -> bool:
        return self.engine.idle

    @property
    def queued_requests(self) -> list:
        return list(self.engine.queue)

    @property
    def records(self):
        """request_id -> RequestRecord (what Gateway mutates in place)."""
        return self.engine._records

    @property
    def clock_offset(self) -> float:
        """Per-process perf_counter skew vs the router's clock (0 for an
        in-process replica: same interpreter, same clock)."""
        return 0.0

    def submit(self, req, now: Optional[float] = None) -> None:
        self.engine.submit(req, now)

    def step(self) -> list:
        return self.engine.step()

    def sample_occupancy(self) -> None:
        """One occupancy sample per cluster step (the balance metric)."""
        self.steps += 1
        self.busy_slot_steps += self.occupancy

    def store_records(self) -> list:
        return list(self.engine.store.records)

    def metrics_snapshot(self) -> dict:
        return self.engine.metrics_snapshot()

    def trace_flush(self) -> None:
        """Close the engine's open decode window (drain-end hook)."""
        tf = getattr(self.engine, "trace_flush", None)
        if callable(tf):
            tf()

    def drain(self, deadline_s: float = 120.0) -> list:
        """Step to idle (bounded); returns the finished responses."""
        out = []
        t_end = time.perf_counter() + deadline_s
        while not self.idle:
            out.extend(self.step())
            self.sample_occupancy()
            if time.perf_counter() > t_end:
                raise RuntimeError(
                    f"replica {self.index} drain exceeded {deadline_s}s"
                )
        out.extend(self.step())
        self.trace_flush()
        return out

    def close(self) -> None:
        eng_close = getattr(self.engine, "close", None)
        if callable(eng_close):
            eng_close()


class _RemoteEngineFacade:
    """The slice of the single-engine surface the :class:`Router`'s
    policies touch, backed by a :class:`ProcessReplica`'s cached load
    snapshot instead of a live engine. Deliberately does NOT expose
    ``prefix_lookup_tokens`` — a remote radix index can't be peeked
    without an RPC per replica per request, so the ``prefix_cache``
    policy's scores degrade to its sticky first-page fallback (same
    contract as engines without prefix reuse)."""

    def __init__(self, replica: "ProcessReplica", spec: dict):
        self._replica = replica
        kw = spec.get("engine_kw") or {}
        self.bucketed_prefill = bool(kw.get("bucketed_prefill", True))
        self.min_bucket = int(kw.get("min_bucket", 16))
        self.max_seq = int(kw.get("max_seq", 256))
        self.max_batch = int(kw.get("max_batch", 8))
        self.page = int(kw.get("page_size", 16))
        self.packed = bool(kw.get("packed", False))
        self.prefill_chunk = int(kw.get("prefill_chunk", 0))

    def _bucket(self, s: int) -> int:
        return min(max(_next_pow2(s), self.min_bucket), self.max_seq)

    @property
    def queue(self) -> list:
        """Depth-only placeholder: the queued Request objects live in the
        worker process; router policies only ever len() this."""
        return [None] * self._replica.queue_depth


class ProcessReplica:
    """One replica living in its own OS process, driven over the socket
    RPC control plane (``serving/ipc.py`` / ``serving/worker.py``).

    Duck-types :class:`Replica`'s backend seam (submit / step /
    sample_occupancy / idle / records / store_records / drain / close plus
    the router-visible load counters), so the Router and ServingCluster
    drive both kinds identically. Differences that matter:

    * **Load counters are snapshots.** Every submit/harvest RPC reply
      carries the worker's fresh ``load_snapshot()``; between RPCs the
      counters are as stale as the last exchange — exactly the staleness
      a distributed router lives with.
    * **Records merge at harvest.** The parent keeps a stub
      ``RequestRecord`` per submit (the object ``Gateway`` mutates); when
      the child's finished record arrives it is folded INTO the stub in
      place — stage/cpu charges summed, ``t_done`` rebased from the
      child's perf_counter epoch onto the parent's via the handshake
      ``clock_offset`` — so record identity is stable across the
      request's whole life (see ``core.metrics.merge_record_streams``
      for the skew rationale).
    * **Occupancy is sampled child-side.** The worker's pipeline counts
      its own steps/busy-slot-steps; :meth:`sample_occupancy` is a no-op
      and the balance telemetry reads the snapshot.
    """

    def __init__(self, index: int, client, spec: dict, pods: tuple = ()):
        self.index = index
        self.client = client  # ipc.ReplicaClient
        self.pods = pods
        self.routed = 0
        # debug-mode stamp validation after every cross-clock rebase (the
        # engines' own debug_stamps knob checks the same stamps child-side
        # BEFORE the rebase; this catches a bad offset sign/staleness)
        self.debug_stamps = bool(
            (spec.get("engine_kw") or {}).get("debug_stamps")
        )
        self.engine = _RemoteEngineFacade(self, spec)
        self._load = {
            "queue_depth": 0, "occupancy": 0,
            "free_slots": self.engine.max_batch, "outstanding_tokens": 0,
            "steps": 0, "busy_slot_steps": 0, "submitted": 0, "emitted": 0,
            "submitted_bytes": 0, "idle": True,
        }
        self._records_local: dict = {}  # request_id -> merged/stub record
        self._store = ProfileStore()

    # -------------------------- load counters ------------------------- #
    @property
    def queue_depth(self) -> int:
        return self._load["queue_depth"]

    @property
    def occupancy(self) -> int:
        return self._load["occupancy"]

    @property
    def free_slots(self) -> int:
        return self._load["free_slots"]

    @property
    def jobs(self) -> int:
        return self.queue_depth + self.occupancy

    @property
    def outstanding_tokens(self) -> int:
        return self._load["outstanding_tokens"]

    @property
    def steps(self) -> int:
        return self._load["steps"]

    @property
    def busy_slot_steps(self) -> int:
        return self._load["busy_slot_steps"]

    @property
    def occupancy_mean(self) -> float:
        denom = self.steps * self.engine.max_batch
        return self.busy_slot_steps / denom if denom else 0.0

    @property
    def clock_offset(self) -> float:
        return self.client.clock_offset

    # --------------------------- backend seam ------------------------- #
    @property
    def idle(self) -> bool:
        """Fresh check (one load RPC): drain loops poll this, and a stale
        snapshot would end them early or never."""
        self._load = self.client.load()
        return bool(self._load["idle"])

    @property
    def queued_requests(self) -> list:
        return self.engine.queue

    @property
    def records(self) -> dict:
        return self._records_local

    def submit(self, req, now: Optional[float] = None) -> None:
        # the stub is what Gateway mutates between submit and harvest;
        # engine-side ingress charges happen in the WORKER's engine and
        # fold in at harvest, so nothing is double-charged here
        self._records_local[req.request_id] = RequestRecord(
            request_id=req.request_id, client_id=req.client_id,
            priority=req.priority, t_issue=time.perf_counter(),
            bytes_in=req.payload_bytes, bytes_out=4 * req.max_new_tokens,
        )
        self._load = self.client.submit(req)

    def _merge(self, pairs) -> list:
        """Fold harvested child records into their parent-side stubs (in
        place — Gateway holds references) and return the responses."""
        out = []
        for rsp, child in pairs:
            stub = self._records_local.get(child.request_id)
            if stub is None:  # submitted out-of-band; adopt as-is, rebased
                stub = dataclasses.replace(
                    child,
                    t_issue=child.t_issue - self.clock_offset,
                    stage_s=dict(child.stage_s),
                )
                self._records_local[child.request_id] = stub
                stub.t_done = child.t_done - self.clock_offset
            else:
                for k, v in child.stage_s.items():
                    stub.add(k, v)
                stub.cpu_s += child.cpu_s
                stub.transfer_wall_s += child.transfer_wall_s
                stub.t_done = child.t_done - self.clock_offset
            if self.debug_stamps:
                # rebased completion must stay after the parent-side issue
                # stamp (tolerating the RTT/2 handshake estimate error) —
                # an inversion here means the offset sign flipped or went
                # stale, exactly the bug this mode exists to catch
                trace.validate_stamps(
                    stub.t_issue, 0.0, stub.t_done, tol=0.05,
                    where=f"replica{self.index} record {stub.request_id} "
                          f"after clock rebase",
                )
            self._store.add(stub)
            out.append(rsp)
        return out

    def step(self) -> list:
        pairs, self._load = self.client.harvest()
        return self._merge(pairs)

    def sample_occupancy(self) -> None:
        pass  # the worker's pipeline samples its own occupancy

    def store_records(self) -> list:
        return list(self._store.records)

    def drain(self, deadline_s: float = 120.0) -> list:
        """One blocking drain RPC: the worker runs its pipeline to idle
        and ships everything it finished along the way."""
        pairs = self.client.drain(deadline_s)
        self._load = self.client.load()
        return self._merge(pairs)

    def telemetry(self) -> dict:
        return self.client.telemetry()

    def metrics_snapshot(self) -> dict:
        return self.client.telemetry().get("metrics", {})

    def trace_flush(self) -> None:
        pass  # the worker flushes its own windows at drain

    def close(self) -> None:
        self.client.close()


class Router:
    """Pluggable admission policy: maps a request to a replica index.

    Stateless reads of the replicas' load counters plus two bits of
    router-local state (the round-robin cursor and the affinity
    bucket->replica map); every tie breaks toward the lowest replica
    index, so routing is deterministic given the submission sequence.
    """

    POLICIES = ("round_robin", "jsq", "least_loaded", "affinity",
                "prefix_cache")

    def __init__(self, policy: str = "least_loaded"):
        if policy not in self.POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; pick one of {self.POLICIES}"
            )
        self.policy = policy
        self._rr = 0
        self._affinity: dict = {}  # prefill bucket/shape key -> replica
        self._prefix_home: dict = {}  # first prompt page -> replica

    def pick(self, req, replicas: list) -> int:
        if self.policy == "round_robin":
            i = self._rr % len(replicas)
            self._rr += 1
            return i
        if self.policy == "jsq":
            # shortest queue = fewest requests in system; ties break by
            # outstanding work (two replicas with one job each are NOT
            # equal when one job is a 2-token request and the other a
            # 192-token decode), then index — so ties stay deterministic
            # without blindly parking work behind a long decode
            return min(
                range(len(replicas)),
                key=lambda i: (replicas[i].jobs,
                               replicas[i].outstanding_tokens, i),
            )
        if self.policy == "least_loaded":
            # outstanding work first, then spare slot headroom
            return min(
                range(len(replicas)),
                key=lambda i: (replicas[i].outstanding_tokens,
                               -replicas[i].free_slots, i),
            )
        if self.policy == "prefix_cache":
            return self._pick_prefix_cache(req, replicas)
        # affinity: sticky pow2-bucket placement
        key = self._bucket_key(req, replicas[0].engine)
        if key not in self._affinity:
            counts = [0] * len(replicas)
            for r in self._affinity.values():
                counts[r] += 1
            self._affinity[key] = min(
                range(len(replicas)),
                key=lambda i: (counts[i], replicas[i].jobs, i),
            )
        return self._affinity[key]

    def _pick_prefix_cache(self, req, replicas: list) -> int:
        """Estimated prefix-hit routing: score each replica by how many
        prompt tokens its radix index already holds (a peek — no LRU or
        hit/miss distortion) and send the request to the deepest match;
        among equally-deep matches, the least-loaded replica wins. When
        no replica has any of the prompt (a cold system prompt, or
        engines without prefix reuse scoring a flat 0), fall back to a
        sticky map keyed on the prompt's FIRST page, so repeats of the
        same system prompt converge on one replica and turn its future
        lookups into hits instead of spraying cold prefills."""
        scores = [
            rep.engine.prefix_lookup_tokens(req.prompt_tokens)
            if hasattr(rep.engine, "prefix_lookup_tokens") else 0
            for rep in replicas
        ]
        if max(scores) > 0:
            return min(
                range(len(replicas)),
                key=lambda i: (-scores[i],
                               replicas[i].outstanding_tokens, i),
            )
        page = getattr(replicas[0].engine, "page", 16)
        key = tuple(int(t) for t in req.prompt_tokens[:page])
        if key not in self._prefix_home:
            self._prefix_home[key] = min(
                range(len(replicas)),
                key=lambda i: (replicas[i].outstanding_tokens,
                               replicas[i].jobs, i),
            )
        return self._prefix_home[key]

    def _bucket_key(self, req, engine):
        """The prefill shape the request admits into: its pow2 bucket on
        the bucketed path, or its exact (length, features) shape on the
        exact path — either way, co-locating equal keys means co-located
        requests share one compiled prefill."""
        if engine.bucketed_prefill and req.features is None:
            if getattr(engine, "packed", False):
                # packed engines compile per pow2 PACKED width (the sum of
                # an admission's true lengths); keying on the per-request
                # bucket still co-locates similar lengths, keeping each
                # replica's packed widths stable without funneling every
                # request to one replica
                return ("packed", engine._bucket(len(req.prompt_tokens)))
            return ("bucket", engine._bucket(len(req.prompt_tokens)))
        feat = None if req.features is None else tuple(req.features.shape)
        return ("exact", len(req.prompt_tokens), feat)


class _MergedRecords:
    """Read-only mapping view over the replicas' per-request record dicts
    (what ``Gateway`` reaches through ``engine._records``)."""

    def __init__(self, dicts):
        self._dicts = dicts

    def get(self, key, default=None):
        for d in self._dicts:
            if key in d:
                return d[key]
        return default

    def __getitem__(self, key):
        rec = self.get(key)
        if rec is None:
            raise KeyError(key)
        return rec

    def __contains__(self, key) -> bool:
        return any(key in d for d in self._dicts)


class ServingCluster:
    """N engine replicas behind a :class:`Router`.

    The public surface matches a single engine — :meth:`submit`,
    :meth:`step`, :meth:`run_until_drained`, ``queue``, ``store``,
    ``idle`` — so ``Gateway``, the load generators, and the closed-loop
    client drive a cluster exactly like one engine. :meth:`step` steps
    every replica once (replicas are independent; a real deployment steps
    them in parallel processes) and samples per-replica occupancy for the
    balance telemetry.
    """

    def __init__(self, replicas: list, *, policy: str = "least_loaded",
                 router: Optional[Router] = None):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        self.replicas = [
            r if isinstance(r, (Replica, ProcessReplica)) else Replica(i, r)
            for i, r in enumerate(replicas)
        ]
        self.router = router if router is not None else Router(policy)
        self.responses: list = []  # completion-ordered, for telemetry
        self._where: dict = {}  # request_id -> replica index
        self._closed = False
        # cluster-level observability: the sampler polls per-replica
        # queue depth / occupancy into this registry's histograms while a
        # drain runs; telemetry() embeds its snapshot
        self.registry = Registry()
        self._sampler: Optional[Sampler] = None

    # ------------------------------------------------------------------ #
    @classmethod
    def build(cls, model, params, *, n_replicas: int = 2,
              engine: str = "fused", mesh=None,
              pods_per_replica: Optional[int] = None,
              policy: str = "least_loaded", router: Optional[Router] = None,
              warmup: bool = False, backend: str = "inprocess",
              devices_per_replica: Optional[int] = None, param_seed: int = 0,
              backlog: int = 2, rpc_timeout_s: float = 120.0,
              init_timeout_s: float = 600.0, **engine_kw) -> "ServingCluster":
        """Construct a cluster of ``n_replicas`` engines on a cluster mesh.

        engine: 'fused' (single-stage :class:`ServingEngine` per replica,
        1 pod each by default) or 'disagg'
        (:class:`~repro.serving.disagg.DisaggregatedEngine` per replica, 2
        pods each by default — prefill and decode stages placed on their
        own pod WITHIN the replica's slice, the KV handoff crossing
        between them under ``engine_kw['transfer_mode']``).

        backend: 'inprocess' (the A/B baseline and test default — every
        replica is an object in this interpreter, stepped sequentially by
        :meth:`step`) or 'process' (each replica is its OWN OS process
        with its own XLA client over ``devices_per_replica`` forced host
        devices, spoken to over the socket RPC control plane — real
        concurrency, the deployment shape the paper measures). The
        process backend rebuilds each worker's params deterministically
        from ``model.init(jax.random.key(param_seed))``; pass params
        built from the SAME seed for in-process-vs-process A/B identity.
        Worker startup is overlapped across replicas; ``with`` the
        cluster (or call :meth:`close`) so worker processes are reaped on
        every exit path.

        mesh: a ('pod',)-axis mesh to carve up (in-process backend only);
        default ``launch.mesh.make_cluster_mesh(n_replicas,
        pods_per_replica)``. Remaining ``engine_kw`` (max_batch, max_seq,
        transfer_mode, temperature, ...) pass through to every replica's
        engine constructor; ``warmup`` pre-traces each replica after its
        state is committed to its slice.
        """
        from repro.launch.mesh import make_cluster_mesh
        from repro.sharding.partition import (
            place_on_slice,
            pod_slice_mesh,
            slice_sharding,
        )

        if engine not in ("fused", "disagg"):
            raise ValueError(f"engine must be 'fused' or 'disagg': {engine}")
        if backend not in ("inprocess", "process"):
            raise ValueError(
                f"backend must be 'inprocess' or 'process': {backend}"
            )
        if backend == "process":
            return cls._build_process(
                model, n_replicas=n_replicas, engine=engine,
                policy=policy, router=router, warmup=warmup,
                devices_per_replica=devices_per_replica,
                param_seed=param_seed, backlog=backlog,
                rpc_timeout_s=rpc_timeout_s,
                init_timeout_s=init_timeout_s, **engine_kw,
            )
        ppr = (1 if engine == "fused" else 2) \
            if pods_per_replica is None else pods_per_replica
        if mesh is None:
            mesh = make_cluster_mesh(n_replicas, ppr)
        slices = replica_pod_slices(mesh.shape["pod"], n_replicas, ppr)

        replicas = []
        for i, pods in enumerate(slices):
            # per-replica trace tag: in-process replicas share MainThread,
            # so the tag is what keeps their process-level spans (decode
            # windows, handoffs) on distinct trace lanes
            kw_i = dict(engine_kw)
            kw_i.setdefault("trace_tag", f"replica{i}")
            if engine == "fused":
                eng = ServingEngine(
                    model, place_on_slice(params, mesh, pods),
                    warmup=False, **kw_i,
                )
                eng.pool.place(slice_sharding(mesh, pods))
                if warmup:
                    eng.warmup, eng.warm_s = True, eng.warm()
            else:
                from repro.serving.disagg import DisaggregatedEngine

                eng = DisaggregatedEngine(
                    model, params, mesh=pod_slice_mesh(mesh, pods),
                    warmup=warmup, **kw_i,
                )
            replicas.append(Replica(i, eng, pods))
        out = cls(replicas, policy=policy, router=router)
        out.mesh = mesh
        return out

    @classmethod
    def _build_process(cls, model, *, n_replicas: int, engine: str,
                       policy: str, router: Optional[Router], warmup: bool,
                       devices_per_replica: Optional[int], param_seed: int,
                       backlog: int, rpc_timeout_s: float,
                       init_timeout_s: float, **engine_kw) -> "ServingCluster":
        """Process backend: spawn ``n_replicas`` worker processes (each
        its own XLA client over its forced host-device subset), overlap
        their init (jax import + deterministic param rebuild + optional
        warmup), and wrap each in a :class:`ProcessReplica`."""
        import numpy as np

        from repro.serving.ipc import ReplicaClient

        devices = (1 if engine == "fused" else 2) \
            if devices_per_replica is None else int(devices_per_replica)
        spec = {
            "cfg": model.cfg,
            "dtype": model.dtype if isinstance(model.dtype, str)
            else np.dtype(model.dtype).name,
            "param_seed": int(param_seed),
            "engine": engine,
            "engine_kw": dict(engine_kw, warmup=warmup),
            "backlog": int(backlog),
            # workers inherit the parent's tracing state at build time;
            # their spans ship back on harvest/telemetry/drain replies and
            # are rebased + relabeled by the ReplicaClient at ingest
            "tracing": trace.tracing_enabled(),
        }
        clients, replicas = [], []
        try:
            for i in range(n_replicas):
                clients.append(ReplicaClient(
                    devices=devices, label=f"replica{i}",
                    call_timeout_s=rpc_timeout_s,
                    init_timeout_s=init_timeout_s,
                ))
            for c in clients:  # overlapped: all workers build concurrently
                c.start_init(spec)
            for i, c in enumerate(clients):
                c.wait_init()
                replicas.append(ProcessReplica(i, c, spec, pods=(i,)))
        except Exception:
            for c in clients:
                c.close(timeout_s=2.0)
            raise
        return cls(replicas, policy=policy, router=router)

    # ------------------------------------------------------------------ #
    def submit(self, req, now: Optional[float] = None) -> int:
        """Route ``req`` to a replica and join its admission queue; the
        replica's engine stamps arrival and charges the modeled ingress.
        Returns the replica index (recorded for telemetry)."""
        t0 = time.perf_counter()
        i = self.router.pick(req, self.replicas)
        trace.tracer().emit(
            "router.pick", t0, time.perf_counter(),
            request_id=req.request_id, policy=self.router.policy, replica=i,
        )
        rep = self.replicas[i]
        rep.submit(req, now)
        rep.routed += 1
        self._where[req.request_id] = i
        return i

    def step(self) -> list:
        """One cluster iteration: step every replica once (an in-process
        replica runs admit/dispatch/harvest; a process replica harvests
        whatever its worker finished since last time), collect finished
        responses, sample occupancy for the balance index."""
        done = []
        for rep in self.replicas:
            done.extend(rep.step())
            rep.sample_occupancy()
        self.responses.extend(done)
        return done

    @property
    def idle(self) -> bool:
        return all(rep.idle for rep in self.replicas)

    @property
    def async_draining(self) -> bool:
        """True when stepping is not what makes progress (process-backed
        replicas drain in their own processes) — the open-loop driver's
        cue that it may sleep instead of spin."""
        return any(
            isinstance(rep, ProcessReplica) or
            getattr(rep.engine, "async_draining", False)
            for rep in self.replicas
        )

    def run_until_drained(self, max_steps: int = 10_000) -> list:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if self.idle:
                for rep in self.replicas:
                    rep.trace_flush()
                break
        return out

    # ------------------------------------------------------------------ #
    # single-engine-compatible surface (Gateway, loadgen, closed loop)
    # ------------------------------------------------------------------ #
    @property
    def queue(self) -> list:
        """All queued (unadmitted) requests across replicas (process
        replicas contribute depth-only placeholders — their Request
        objects live in the worker)."""
        return [r for rep in self.replicas for r in rep.queued_requests]

    @property
    def _records(self) -> _MergedRecords:
        return _MergedRecords([rep.records for rep in self.replicas])

    @property
    def store(self) -> ProfileStore:
        """Merged ProfileStore over every replica's records, on ONE
        timeline: process replicas' records were rebased onto the
        parent's clock at harvest, so the streams merge with zero
        offsets and sort by completion (see ``core.metrics.
        merge_record_streams`` for the skew rationale). Rebuilt per
        access; records are shared, not copied."""
        s = ProfileStore()
        s.records.extend(merge_record_streams(
            [rep.store_records() for rep in self.replicas]
        ))
        return s

    def replica_of(self, request_id: int) -> Optional[int]:
        return self._where.get(request_id)

    # ------------------------------------------------------------------ #
    @property
    def parallelism(self) -> str:
        """How replicas actually execute: ``"process-per-replica"`` (real
        OS-process concurrency) or ``"sequential-in-process"`` (stepped
        one after another in this interpreter — queueing effects are
        real, parallel capacity is not). Recorded in telemetry and in
        ``BENCH_cluster.json`` meta so the two regimes' numbers can't be
        conflated."""
        if any(isinstance(r, ProcessReplica) for r in self.replicas):
            return "process-per-replica"
        return "sequential-in-process"

    def drain(self, deadline_s: float = 120.0) -> list:
        """Drain every replica to idle. Process replicas drain INSIDE
        their workers (one blocking RPC each — tight timing, no parent
        poll loop); in-process replicas step here."""
        done = []
        for rep in self.replicas:
            done.extend(rep.drain(deadline_s))
        self.responses.extend(done)
        return done

    # ------------------------------------------------------------------ #
    # background observability sampler
    # ------------------------------------------------------------------ #
    def start_sampler(self, interval_s: float = 0.005) -> Sampler:
        """Start the background queue-depth / slot-occupancy sampler:
        every ``interval_s`` it observes each replica's counters into
        same-named histograms in :attr:`registry` (process replicas read
        the last RPC load snapshot — no extra wire traffic). Pair with
        :meth:`stop_sampler`; sources that raise are captured and
        re-raised there, never swallowed."""
        if self._sampler is not None:
            raise RuntimeError("sampler already running")
        sources = {}
        for rep in self.replicas:
            sources[f"replica{rep.index}.queue_depth"] = (
                lambda r=rep: r.queue_depth
            )
            sources[f"replica{rep.index}.occupancy"] = (
                lambda r=rep: r.occupancy
            )
        self._sampler = Sampler(
            self.registry, sources, interval_s=interval_s
        ).start()
        return self._sampler

    def stop_sampler(self, *, check: bool = True) -> None:
        if self._sampler is not None:
            s, self._sampler = self._sampler, None
            s.stop(check=check)

    def close(self) -> None:
        """Shut replicas down (terminate worker processes for the
        process backend). Idempotent; safe on error paths — always
        ``close()`` (or ``with``) a process-backed cluster, or its
        workers outlive the router until the atexit reaper."""
        if self._closed:
            return
        self._closed = True
        self.stop_sampler(check=False)
        for rep in self.replicas:
            try:
                rep.close()
            except Exception:
                pass  # reap the rest regardless

    def __enter__(self) -> "ServingCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ #
    def telemetry(self, *, warmup: int = 0) -> dict:
        """SLO + balance snapshot: warmup-aware TTFT/TPOT/E2E/queue
        percentiles over the completions so far, per-replica load
        counters, and Jain balance indices (busy-slot time and routed
        counts; 1.0 = perfectly balanced, 1/n = one replica took all)."""
        busy = [rep.busy_slot_steps for rep in self.replicas]
        out = {
            "policy": self.router.policy,
            "n_replicas": len(self.replicas),
            "parallelism": self.parallelism,
            "slo": slo_summary(self.responses, warmup=warmup),
            "per_replica": [
                {
                    "pods": list(rep.pods),
                    "routed": rep.routed,
                    "busy_slot_steps": rep.busy_slot_steps,
                    "occupancy_mean": round(rep.occupancy_mean, 4),
                }
                for rep in self.replicas
            ],
            "balance_index_busy": round(jain_index(busy), 4),
            "balance_index_routed": round(
                jain_index([rep.routed for rep in self.replicas]), 4
            ),
            # unified metrics surface: each replica's engine counters
            # through the obs.Registry (process replicas ship theirs over
            # the telemetry RPC), plus the cluster-level sampler registry
            # and this process's trace-buffer health
            "metrics": [rep.metrics_snapshot() for rep in self.replicas],
            "obs": self.registry.snapshot(),
            "trace": trace.tracer().stats(),
        }
        if self.parallelism == "process-per-replica":
            # control-plane conservation counters: what each worker
            # acknowledged vs what the router sent it, plus raw RPC wire
            # volume — the process-backend analogue of the engines'
            # handoff byte reconciliation
            out["ipc"] = [
                {
                    "replica": rep.index,
                    "rpc_bytes_sent": rep.client.bytes_sent,
                    "rpc_bytes_recv": rep.client.bytes_recv,
                    "request_payload_bytes":
                        rep.client.request_payload_bytes,
                    "submitted": rep._load["submitted"],
                    "emitted": rep._load["emitted"],
                    "submitted_bytes": rep._load["submitted_bytes"],
                    "clock_offset_s": round(rep.clock_offset, 6),
                }
                for rep in self.replicas
                if isinstance(rep, ProcessReplica)
            ]
        return out
