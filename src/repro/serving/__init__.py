from repro.serving.client import ClosedLoopClient, run_closed_loop
from repro.serving.engine import ServingEngine
from repro.serving.gateway import Gateway
from repro.serving.request import Request, Response

__all__ = ["ServingEngine", "Gateway", "Request", "Response",
           "ClosedLoopClient", "run_closed_loop"]
