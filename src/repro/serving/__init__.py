from repro.serving.client import ClosedLoopClient, run_closed_loop
from repro.serving.cluster import Replica, Router, ServingCluster
from repro.serving.disagg import (
    DisaggregatedEngine,
    PodPlacement,
    make_pod_mesh,
)
from repro.serving.engine import DecodePool, PrefillArtifact, ServingEngine
from repro.serving.gateway import Gateway
from repro.serving.loadgen import (
    Arrival,
    load_trace,
    poisson_schedule,
    run_closed_loop_baseline,
    run_open_loop,
    save_trace,
    trace_schedule,
)
from repro.serving.request import Request, Response

__all__ = ["ServingEngine", "DisaggregatedEngine", "DecodePool",
           "PrefillArtifact", "PodPlacement", "Gateway", "Request",
           "Response", "ClosedLoopClient", "run_closed_loop",
           "make_pod_mesh", "ServingCluster", "Router", "Replica",
           "Arrival", "poisson_schedule", "trace_schedule", "load_trace",
           "save_trace", "run_open_loop", "run_closed_loop_baseline"]
