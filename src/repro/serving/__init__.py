from repro.serving.client import ClosedLoopClient, run_closed_loop
from repro.serving.disagg import (
    DisaggregatedEngine,
    PodPlacement,
    make_pod_mesh,
)
from repro.serving.engine import DecodePool, PrefillArtifact, ServingEngine
from repro.serving.gateway import Gateway
from repro.serving.request import Request, Response

__all__ = ["ServingEngine", "DisaggregatedEngine", "DecodePool",
           "PrefillArtifact", "PodPlacement", "Gateway", "Request",
           "Response", "ClosedLoopClient", "run_closed_loop",
           "make_pod_mesh"]
