"""Socket RPC control plane for the process-per-replica serving cluster.

The paper's deployment is a multi-stage pipeline across *separate
processes and hosts* — client -> TCP proxy -> stage pools over a fabric —
where every hop is a real wire with real serialization. This module is
that control plane in miniature: a small length-prefixed RPC protocol the
parent-process :class:`~repro.serving.cluster.Router` speaks to each
replica worker process (``serving/worker.py``), so the cluster tier's
replicas become genuinely concurrent OS processes with their own XLA
clients instead of objects stepped sequentially in one interpreter.

**Wire format.** Every message is one frame: a 4-byte big-endian length
prefix followed by a pickled ``(op, payload)`` pair. Ops:

  hello     : clock handshake (pre-jax, so import time never skews it) —
              the parent estimates the child-vs-parent ``perf_counter``
              offset from one RTT, the skew term ``core.metrics.
              merge_record_streams`` rebases per-process records with.
  init      : engine spec (model config, dtype, param seed, engine kind +
              kwargs) -> the worker builds its model/params/engine and a
              threaded :class:`~repro.serving.engine.EnginePipeline`.
  submit    : one serialized Request joins the worker's admission queue;
              the reply carries a fresh load snapshot for the router.
  harvest   : finished (Response, RequestRecord) pairs since the last
              harvest, plus the load snapshot.
  load      : load snapshot only (router policies, idle checks).
  telemetry : load snapshot + engine counters (prefill/decode/prefix).
  drain     : block until the worker's pipeline is idle (bounded by a
              deadline), returning every remaining finished pair.
  shutdown  : stop the pipeline threads and exit 0.

**Serialization** reuses ``serving/request.py``: requests/responses/
records cross as plain field dicts (numpy prompt arrays pickle natively),
reconstructed with their original ``request_id`` so parent- and
child-side bookkeeping key identically. Both endpoints count wire bytes
and submitted request-payload bytes — the conservation invariant
(parent's sent == child's received, and == the in-process baseline's
routed payload bytes) that the cluster benchmark asserts.

**Process management.** :class:`ReplicaClient` spawns the worker with
``python -m repro.serving.worker``, forcing the child's OWN XLA client
over ``--xla_force_host_platform_device_count=<devices>`` (the
forced-device subset per process), waits for the socket handshake, and
maps every failure mode to a :class:`ReplicaError` instead of a hang:
RPC timeouts kill the child; an EOF mid-reply reports the child's exit
code. Live workers are tracked in a module registry reaped at
interpreter exit, so a crashed parent never leaks orphan processes.
"""

from __future__ import annotations

import atexit
import os
import pickle
import socket
import struct
import subprocess
import sys
import time
from typing import Optional

from repro.core import trace
from repro.core.profiler import RequestRecord
from repro.serving.request import Request, Response

_HDR = struct.Struct("!I")
_MAX_FRAME = 1 << 30  # 1 GiB sanity bound on a single frame


class ConnectionClosed(RuntimeError):
    """Peer closed the socket mid-protocol (EOF before a full frame)."""


class ReplicaError(RuntimeError):
    """A replica worker process failed (died, timed out, or raised)."""


# --------------------------------------------------------------------------- #
# framing
# --------------------------------------------------------------------------- #
def send_msg(sock: socket.socket, op: str, payload=None) -> int:
    """Send one length-prefixed frame; returns bytes put on the wire."""
    body = pickle.dumps((op, payload), protocol=pickle.HIGHEST_PROTOCOL)
    frame = _HDR.pack(len(body)) + body
    sock.sendall(frame)
    return len(frame)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionClosed(
                f"peer closed the connection ({len(buf)}/{n} bytes of the "
                f"current frame received)"
            )
        buf.extend(chunk)
    return bytes(buf)


def recv_msg(sock: socket.socket):
    """Receive one frame; returns ``(op, payload, wire_bytes)``."""
    hdr = _recv_exact(sock, _HDR.size)
    (n,) = _HDR.unpack(hdr)
    if n > _MAX_FRAME:
        raise ValueError(f"frame length {n} exceeds sanity bound {_MAX_FRAME}")
    op, payload = pickle.loads(_recv_exact(sock, n))
    return op, payload, _HDR.size + n


# --------------------------------------------------------------------------- #
# request / response / record serialization (serving/request.py types)
# --------------------------------------------------------------------------- #
def request_to_wire(req: Request) -> dict:
    return {
        "prompt_tokens": req.prompt_tokens,
        "max_new_tokens": req.max_new_tokens,
        "priority": req.priority,
        "client_id": req.client_id,
        "request_id": req.request_id,
        "features": req.features,
    }


def request_from_wire(d: dict) -> Request:
    # explicit request_id: the wire preserves the submitter's id stream, so
    # parent- and child-side bookkeeping (records, responses, router map)
    # key identically
    return Request(**d)


def response_to_wire(rsp: Response) -> dict:
    return {
        "request_id": rsp.request_id,
        "tokens": list(rsp.tokens),
        "ttft_s": rsp.ttft_s,
        "total_s": rsp.total_s,
        "stage_s": dict(rsp.stage_s),
    }


def response_from_wire(d: dict) -> Response:
    return Response(**d)


def record_to_wire(rec: RequestRecord) -> dict:
    return {
        "request_id": rec.request_id,
        "client_id": rec.client_id,
        "priority": rec.priority,
        "t_issue": rec.t_issue,
        "t_done": rec.t_done,
        "stage_s": dict(rec.stage_s),
        "cpu_s": rec.cpu_s,
        "bytes_in": rec.bytes_in,
        "bytes_out": rec.bytes_out,
        "transfer_wall_s": rec.transfer_wall_s,
    }


def record_from_wire(d: dict) -> RequestRecord:
    return RequestRecord(**d)


# --------------------------------------------------------------------------- #
# orphan reaping: every live worker is registered here and terminated at
# interpreter exit, so error paths (or a crashed parent) never leak
# replica processes
# --------------------------------------------------------------------------- #
_LIVE_WORKERS: set = set()
_ATEXIT_ARMED = False


def _register_worker(proc) -> None:
    global _ATEXIT_ARMED
    _LIVE_WORKERS.add(proc)
    if not _ATEXIT_ARMED:
        atexit.register(_reap_all_workers)
        _ATEXIT_ARMED = True


def _unregister_worker(proc) -> None:
    _LIVE_WORKERS.discard(proc)


def _reap_all_workers() -> None:
    for proc in list(_LIVE_WORKERS):
        if proc.poll() is None:
            proc.terminate()
    deadline = time.monotonic() + 2.0
    for proc in list(_LIVE_WORKERS):
        while proc.poll() is None and time.monotonic() < deadline:
            time.sleep(0.02)
        if proc.poll() is None:
            proc.kill()
        _LIVE_WORKERS.discard(proc)


# --------------------------------------------------------------------------- #
# parent-side client
# --------------------------------------------------------------------------- #
class ReplicaClient:
    """Parent-side handle on one replica worker process.

    Construction spawns the worker and completes the pre-jax clock
    handshake; :meth:`start_init` / :meth:`wait_init` ship the engine
    spec and collect the (slow: jax import + model build + optional
    warmup) acknowledgement — split so a cluster can overlap N workers'
    initialization instead of paying it serially. All RPC failure modes
    raise :class:`ReplicaError`; a timeout hard-kills the worker first so
    a wedged replica can never hang the router.
    """

    def __init__(self, *, devices: int = 1, label: str = "replica",
                 spawn_timeout_s: float = 60.0, call_timeout_s: float = 120.0,
                 init_timeout_s: float = 600.0):
        self.label = label
        self.devices = int(devices)
        self.call_timeout_s = call_timeout_s
        self.init_timeout_s = init_timeout_s
        self.clock_offset = 0.0  # child perf_counter - parent perf_counter
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.request_payload_bytes = 0  # sum of submitted req.payload_bytes
        self._closed = False
        self._init_pending = False

        lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        lsock.bind(("127.0.0.1", 0))
        lsock.listen(1)
        port = lsock.getsockname()[1]

        env = os.environ.copy()
        # the child's OWN XLA client over its own forced host-device
        # subset; any parent-side forcing must not leak through
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={self.devices}"
        )
        # the worker imports repro before (deliberately) importing jax
        src_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (src_root, env.get("PYTHONPATH", "")) if p
        )
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.serving.worker",
             "--port", str(port)],
            env=env,
        )
        _register_worker(self.proc)
        try:
            lsock.settimeout(spawn_timeout_s)
            self.sock, _ = lsock.accept()
            self.sock.settimeout(call_timeout_s)
            # clock handshake: offset = t_child - midpoint(parent RTT).
            # Runs before the worker imports jax, so the sample is a
            # socket round-trip, not an import stall.
            t0 = time.perf_counter()
            t_child = self._call("hello", None,
                                 timeout_s=spawn_timeout_s)["t_child"]
            t1 = time.perf_counter()
            self.clock_offset = t_child - 0.5 * (t0 + t1)
        except Exception as e:
            self._kill()
            raise ReplicaError(
                f"{self.label}: worker failed during spawn/handshake: {e}"
            ) from e
        finally:
            lsock.close()

    # ------------------------------------------------------------------ #
    def _kill(self) -> None:
        if self.proc.poll() is None:
            self.proc.kill()
            try:
                self.proc.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                pass
        _unregister_worker(self.proc)

    def _dead_error(self, context: str) -> ReplicaError:
        code = self.proc.poll()
        state = (f"exited with code {code}" if code is not None
                 else "still running but unresponsive")
        return ReplicaError(
            f"{self.label}: worker process {state} during {context!r} — "
            f"replica is lost (its queued/in-flight requests with it)"
        )

    def _call(self, op: str, payload, *, timeout_s: Optional[float] = None):
        if self._closed:
            raise ReplicaError(f"{self.label}: client already closed")
        t0 = time.perf_counter()
        sent = 0
        try:
            if timeout_s is not None:
                self.sock.settimeout(timeout_s)
            sent = send_msg(self.sock, op, payload)
            self.bytes_sent += sent
            rop, rpayload, n = recv_msg(self.sock)
        except socket.timeout as e:
            # a wedged worker must never hang the router: kill + surface
            self._kill()
            raise ReplicaError(
                f"{self.label}: RPC {op!r} timed out after "
                f"{timeout_s or self.call_timeout_s}s; worker killed"
            ) from e
        except (ConnectionClosed, ConnectionError, BrokenPipeError) as e:
            self._kill()
            raise self._dead_error(op) from e
        finally:
            if timeout_s is not None and not self._closed:
                try:
                    self.sock.settimeout(self.call_timeout_s)
                except OSError:
                    pass
        self.bytes_recv += n
        trace.tracer().emit(
            f"rpc.{op}", t0, time.perf_counter(), tag=self.label,
            bytes_sent=sent, bytes_recv=n,
        )
        if rop == "error":
            raise ReplicaError(
                f"{self.label}: worker raised during {op!r}:\n"
                f"{rpayload['traceback']}"
            )
        return rpayload

    # ------------------------------------------------------------------ #
    # protocol ops
    # ------------------------------------------------------------------ #
    def start_init(self, spec: dict) -> None:
        """Ship the engine spec without waiting for the ack (overlapped
        multi-replica construction); pair with :meth:`wait_init`."""
        self.bytes_sent += send_msg(self.sock, "init", spec)
        self._init_pending = True

    def wait_init(self) -> dict:
        try:
            self.sock.settimeout(self.init_timeout_s)
            rop, rpayload, n = recv_msg(self.sock)
            self.sock.settimeout(self.call_timeout_s)
        except socket.timeout as e:
            self._kill()
            raise ReplicaError(
                f"{self.label}: init timed out after {self.init_timeout_s}s "
                f"(jax import + model build + warmup); worker killed"
            ) from e
        except (ConnectionClosed, ConnectionError) as e:
            self._kill()
            raise self._dead_error("init") from e
        self._init_pending = False
        self.bytes_recv += n
        if rop == "error":
            raise ReplicaError(
                f"{self.label}: worker failed to initialize:\n"
                f"{rpayload['traceback']}"
            )
        return rpayload

    def init(self, spec: dict) -> dict:
        self.start_init(spec)
        return self.wait_init()

    def submit(self, req: Request) -> dict:
        """Submit one request; returns the worker's fresh load snapshot."""
        self.request_payload_bytes += req.payload_bytes
        return self._call("submit", request_to_wire(req))

    def _ingest_spans(self, out: dict) -> None:
        """Fold worker-emitted spans (piggybacked on the reply frame) into
        the parent's trace buffer, rebased onto the parent clock via the
        handshake ``clock_offset`` and relabeled with this replica's
        label so the merged timeline names the process."""
        spans = out.get("spans")
        if spans:
            trace.tracer().ingest_wire(
                spans, offset=self.clock_offset, process=self.label
            )

    def harvest(self):
        """Finished (Response, RequestRecord) pairs + the load snapshot."""
        out = self._call("harvest", None)
        self._ingest_spans(out)
        pairs = [
            (response_from_wire(r), record_from_wire(rec))
            for r, rec in out["done"]
        ]
        return pairs, out["load"]

    def load(self) -> dict:
        return self._call("load", None)

    def telemetry(self) -> dict:
        out = self._call("telemetry", None)
        self._ingest_spans(out)
        return out

    def drain(self, deadline_s: float = 120.0):
        """Block until the worker's pipeline is idle (or the deadline
        lapses worker-side); returns the remaining finished pairs."""
        out = self._call("drain", {"deadline_s": deadline_s},
                         timeout_s=deadline_s + 10.0)
        self._ingest_spans(out)
        return [
            (response_from_wire(r), record_from_wire(rec))
            for r, rec in out["done"]
        ]

    # ------------------------------------------------------------------ #
    def close(self, timeout_s: float = 10.0) -> None:
        """Graceful shutdown: RPC shutdown -> wait -> terminate -> kill.
        Idempotent; never raises (close runs on error paths)."""
        if self._closed:
            return
        self._closed = True
        try:
            self.sock.settimeout(timeout_s)
            send_msg(self.sock, "shutdown", None)
            recv_msg(self.sock)
        except Exception:
            pass
        try:
            self.sock.close()
        except OSError:
            pass
        try:
            self.proc.wait(timeout=timeout_s)
        except subprocess.TimeoutExpired:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                self.proc.kill()
        _unregister_worker(self.proc)

    def __enter__(self) -> "ReplicaClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
