"""jit-able step functions shared by the trainer, server, and dry-run."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, adamw_update


def make_train_step(model, opt_cfg: Optional[AdamWConfig] = None, shard_ctx=None):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, shard_ctx=shard_ctx)
        )(params)
        new_params, new_state, gnorm = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(model, shard_ctx=None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, shard_ctx=shard_ctx)

    return prefill_step


def make_serve_step(model, shard_ctx=None):
    """Decode: ONE token against the KV cache."""

    def serve_step(params, caches, tokens, lengths):
        return model.decode_step(
            params, caches, tokens, lengths, shard_ctx=shard_ctx
        )

    return serve_step
