"""AdamW in pure JAX (bf16 params, fp32 moments), plus LR schedules.

Moments are kept in fp32 regardless of param dtype — the standard
mixed-precision training memory layout (2 bytes weight + 8 bytes optimizer
state per parameter), which is what the dry-run memory analysis must reflect.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_init_specs(param_specs) -> AdamWState:
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(zeros, param_specs),
        v=jax.tree.map(zeros, param_specs),
    )


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm
