from repro.training.data import DataConfig, make_dataset
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.training.steps import make_prefill_step, make_serve_step, make_train_step
from repro.training.trainer import TrainConfig, train

__all__ = ["DataConfig", "make_dataset", "AdamWConfig", "AdamWState",
           "adamw_init", "adamw_update", "make_train_step", "make_prefill_step",
           "make_serve_step", "TrainConfig", "train"]
