"""Checkpointing: flat-keyed npz of the (params, opt_state, step) pytrees.

Path-keyed so restores are structure-checked; atomic via temp-file rename;
keeps the last ``keep`` checkpoints.
"""

from __future__ import annotations

import os
import re

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = prefix + "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no bf16; fp32 is lossless
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state, keep: int = 3):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(params, "params/")
    flat.update(_flatten(opt_state, "opt/"))
    flat["step"] = np.asarray(step)
    tmp = os.path.join(ckpt_dir, f".tmp_ckpt_{step}.npz")
    final = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    np.savez(tmp, **flat)
    os.replace(tmp, final)
    # prune
    ckpts = sorted(
        f for f in os.listdir(ckpt_dir) if re.match(r"ckpt_\d+\.npz$", f)
    )
    for old in ckpts[:-keep]:
        os.remove(os.path.join(ckpt_dir, old))
    return final


def latest_checkpoint(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(
        f for f in os.listdir(ckpt_dir) if re.match(r"ckpt_\d+\.npz$", f)
    )
    return os.path.join(ckpt_dir, ckpts[-1]) if ckpts else None


def restore_checkpoint(path: str, params_like, opt_like):
    """Restore into the given pytree structures (shape/dtype checked)."""
    data = np.load(path)

    def fill(tree, prefix):
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for p, leaf in leaves_p:
            key = prefix + "/".join(
                str(getattr(q, "key", getattr(q, "idx", q))) for q in p
            )
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef.treedef if hasattr(treedef, "treedef") else treedef, out)

    params = fill(params_like, "params/")
    opt = fill(opt_like, "opt/")
    return params, opt, int(data["step"])
