"""Training loop: data pipeline -> jitted train_step -> metrics/checkpoints."""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.training.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.training.data import DataConfig, make_dataset
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.steps import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    log_every: int = 10
    ckpt_every: int = 0  # 0 = no checkpointing
    ckpt_dir: str = "checkpoints"
    seed: int = 0


def train(model: Model, data_cfg: DataConfig, train_cfg: TrainConfig,
          opt_cfg: Optional[AdamWConfig] = None, shard_ctx=None,
          params=None, log_fn=print):
    opt_cfg = opt_cfg or AdamWConfig(total_steps=train_cfg.steps)
    if params is None:
        params = model.init(jax.random.key(train_cfg.seed))
    opt_state = adamw_init(params)
    start_step = 0
    if train_cfg.ckpt_every:
        ck = latest_checkpoint(train_cfg.ckpt_dir)
        if ck:
            params, opt_state, start_step = restore_checkpoint(ck, params, opt_state)
            log_fn(f"restored {ck} at step {start_step}")

    step_fn = jax.jit(make_train_step(model, opt_cfg, shard_ctx), donate_argnums=(0, 1))
    ds = make_dataset(data_cfg).batches()
    history = []
    t0 = time.perf_counter()
    for step in range(start_step, train_cfg.steps):
        batch = {k: jnp.asarray(v) for k, v in next(ds).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % train_cfg.log_every == 0 or step == start_step:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = time.perf_counter() - t0
            history.append({"step": step + 1, "loss": loss, "grad_norm": gn, "t": dt})
            log_fn(f"step {step+1:5d} loss {loss:.4f} |g| {gn:.3f} ({dt:.1f}s)")
        if train_cfg.ckpt_every and (step + 1) % train_cfg.ckpt_every == 0:
            save_checkpoint(train_cfg.ckpt_dir, step + 1, params, opt_state)
    return params, opt_state, history
