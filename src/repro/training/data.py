"""Token data pipeline: deterministic synthetic LM streams + file-backed bins.

Synthetic corpus: a mixture of Zipf-distributed unigrams and short Markov
motifs, so a ~100M model shows a real falling loss within a few hundred
steps (pure-uniform tokens would leave nothing to learn).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64
    motif_prob: float = 0.35
    path: Optional[str] = None  # .bin file of uint16/uint32 tokens


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self.motifs = self.rng.integers(
            0, v, (cfg.n_motifs, cfg.motif_len), dtype=np.int64
        )
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self.p = p / p.sum()

    def _sequence(self, n: int) -> np.ndarray:
        out = np.empty(n + 1, dtype=np.int64)
        i = 0
        while i <= n:
            if self.rng.random() < self.cfg.motif_prob:
                m = self.motifs[self.rng.integers(self.cfg.n_motifs)]
                k = min(len(m), n + 1 - i)
                out[i : i + k] = m[:k]
                i += k
            else:
                out[i] = self.rng.choice(self.cfg.vocab_size, p=self.p)
                i += 1
        return out

    def batches(self) -> Iterator[dict]:
        cfg = self.cfg
        while True:
            seqs = np.stack([self._sequence(cfg.seq_len) for _ in range(cfg.batch_size)])
            yield {
                "tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32),
            }


class BinTokenFile:
    """Memory-mapped flat token file -> LM batches (production-style)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path is not None
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.rng = np.random.default_rng(cfg.seed)

    def batches(self) -> Iterator[dict]:
        cfg = self.cfg
        n = len(self.tokens) - cfg.seq_len - 1
        while True:
            starts = self.rng.integers(0, n, cfg.batch_size)
            seqs = np.stack([self.tokens[s : s + cfg.seq_len + 1] for s in starts])
            yield {
                "tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32),
            }


def make_dataset(cfg: DataConfig):
    return BinTokenFile(cfg) if cfg.path else SyntheticLM(cfg)
