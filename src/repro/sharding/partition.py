"""Logical-axis -> mesh-axis rules and the ShardCtx passed through models.

Two layers live here:

* :class:`ShardCtx` + :func:`make_rules` — the logical-axis system model
  code uses to express tensor parallelism (per-arch, divisibility-aware).
* Slice-scoped helpers (:func:`pod_slice_mesh`, :func:`slice_sharding`,
  :func:`place_on_slice`) — carve a sub-mesh out of one axis of an
  existing mesh and commit arrays to it. The disaggregated serving tier
  uses these to pin prefill and decode compute to their own "pod" slices
  (see ``serving/disagg.PodPlacement``): params/state committed to a
  slice make every jit that consumes them execute on exactly that
  slice's devices.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Everything model code needs to express distributed ops.

    ``rules`` maps logical axis names (see models/schema.py) to a mesh axis
    name, a tuple of mesh axis names, or None (replicated).
    """

    mesh: Mesh
    rules: dict

    @property
    def shards_vocab(self) -> bool:
        return self.rules.get("vocab") is not None

    @property
    def kv_seq_axes(self):
        return self.rules.get("kv_seq")

    @property
    def batch_axes(self):
        return self.rules.get("batch")

    def axis_size(self, logical: str) -> int:
        ax = self.rules.get(logical)
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            size = 1
            for a in ax:
                size *= self.mesh.shape[a]
            return size
        return self.mesh.shape[ax]

    def activation_pspec(self, ndim: int, batch_dim: int = 0) -> P:
        parts = [None] * ndim
        parts[batch_dim] = self.rules.get("batch")
        return P(*parts)

    def spec(self, *logical) -> P:
        return P(*[self.rules.get(a) if a is not None else None for a in logical])

    def sharding(self, *logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))

    def constrain(self, x, *logical):
        return jax.lax.with_sharding_constraint(x, self.sharding(*logical))


def pod_slice_mesh(mesh: Mesh, pods, axis: str = "pod") -> Mesh:
    """Sub-mesh over the ``pods`` indices of ``mesh``'s ``axis``.

    Keeps every other mesh axis (and all axis names) intact, so shardings
    built on the slice compose with the existing logical-axis rules. Two
    calls with the same mesh/indices produce EQUAL meshes (Mesh equality
    is by device array), so NamedShardings built per call still hit the
    same jit cache entries.
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no {axis!r} axis: {mesh.axis_names}")
    pods = tuple(pods)
    if not pods:
        raise ValueError("empty pod slice")
    size = mesh.shape[axis]
    bad = [p for p in pods if not 0 <= p < size]
    if bad:
        raise ValueError(f"pod indices {bad} out of range for {axis}={size}")
    ax = mesh.axis_names.index(axis)
    devs = np.take(np.asarray(mesh.devices), np.asarray(pods), axis=ax)
    return Mesh(devs, mesh.axis_names)


def slice_sharding(mesh: Mesh, pods, spec: P = P(),
                   axis: str = "pod") -> NamedSharding:
    """NamedSharding scoped to the ``pods`` slice of ``mesh``'s ``axis``.

    ``spec=P()`` (default) replicates across the slice's devices — the
    placement the serving tier wants for per-stage params and pool state;
    any other spec shards within the slice as usual.
    """
    return NamedSharding(pod_slice_mesh(mesh, pods, axis), spec)


def place_on_slice(tree, mesh: Mesh, pods, spec: P = P(), axis: str = "pod"):
    """``device_put`` every leaf of ``tree`` onto the pod slice.

    The result is COMMITTED: jits consuming these leaves compile for (and
    execute on) exactly the slice's devices, which is what makes per-pod
    stage placement provable — a computation's output arrays report the
    slice as their device set.
    """
    return jax.device_put(tree, slice_sharding(mesh, pods, spec, axis))


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def make_rules(cfg, mesh: Mesh, shape=None, overrides: Optional[dict] = None) -> dict:
    """Per-(arch, mesh, input-shape) logical->mesh rules.

    Divisibility-aware: any logical axis whose size does not divide the mesh
    axis is replicated (e.g. GQA kv_heads=8 on model=16 is replicated, which
    is exactly what Megatron-style TP does).
    """
    axes = dict(mesh.shape)
    model = "model" if "model" in axes else None
    msize = axes.get("model", 1)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    bsize = 1
    for a in batch_axes:
        bsize *= axes[a]

    from repro.models.layers import pad_vocab  # local import to avoid cycle

    rules: dict = {
        "embed": None,
        "seq": None,
        "head_dim": None,
        "state": None,
        "conv": None,
        "layers": None,
        "lora": None,
        "rope": None,
        "kv_seq": None,
        "frontend": None,
    }

    gb = shape.global_batch if shape is not None else None
    if gb is None or _div(gb, bsize):
        rules["batch"] = batch_axes if batch_axes else None
        if shape is not None and shape.is_decode and model is not None:
            # Decode: KV caches are per-token state that GQA/MLA cannot head-
            # shard across model=16, so shard the cache's SEQUENCE dim over
            # "model" and flash-decode within each model group.
            rules["kv_seq"] = (model,)
    else:
        # long_500k (batch=1): batch replicated; KV cache sequence-sharded
        # over the ENTIRE mesh instead (flash-decoding across all chips:
        # 524288 slots / 256 = 2048 per chip).
        rules["batch"] = None
        if shape is not None and shape.is_decode:
            seq_axes = batch_axes + ((model,) if model else ())
            rules["kv_seq"] = seq_axes if seq_axes else None

    # --- training: FSDP (weights/opt sharded over "data") + sequence
    # parallelism (activations seq-sharded over "model" between layers) ------ #
    dsize = axes.get("data", 1)
    if shape is not None and shape.kind == "train" and _div(cfg.d_model, dsize):
        rules["embed"] = "data"
    if (
        shape is not None
        and shape.kind in ("train", "prefill")
        and model is not None
        and _div(shape.seq_len, msize)
    ):
        rules["act_seq"] = model
    else:
        rules["act_seq"] = None

    rules["vocab"] = model if _div(pad_vocab(cfg.vocab_size), msize) else None
    rules["heads"] = model if cfg.n_heads and _div(cfg.n_heads, msize) else None
    rules["kv_heads"] = (
        model if cfg.n_kv_heads and _div(cfg.n_kv_heads, msize) else None
    )
    rules["ffn"] = model if cfg.d_ff and _div(cfg.d_ff, msize) else None

    if cfg.moe is not None:
        if _div(cfg.moe.n_experts, msize):
            rules["experts"] = model
            rules["expert_ffn"] = None
        else:  # e.g. grok-1: 8 experts on model=16 -> TP inside each expert
            rules["experts"] = None
            rules["expert_ffn"] = model if _div(cfg.moe.d_ff, msize) else None
        # second shard dim for expert weights + dispatch capacity over "data"
        rules["expert_embed"] = (
            "data" if "data" in axes and _div(cfg.d_model, axes["data"]) else None
        )
        rules["moe_cap"] = "data" if "data" in axes else None
        # flattened [B*S] token dim of the dispatch tensors: keep it sharded
        # the way the residual stream is (batch x seq axes)
        tok_axes = tuple(
            a for a in (batch_axes + ((model,) if rules.get("act_seq") else ()))
            if a
        )
        rules["moe_tokens"] = tok_axes if tok_axes else None
    else:
        rules["experts"] = None
        rules["expert_ffn"] = None
        rules["expert_embed"] = None
        rules["moe_cap"] = None

    if cfg.ssm is not None:
        nh = cfg.ssm.n_heads(cfg.d_model)
        ok = _div(nh, msize)
        rules["ssm_heads"] = model if ok else None
        rules["ssm_in"] = model if ok else None
    else:
        rules["ssm_heads"] = None
        rules["ssm_in"] = None

    if overrides:
        rules.update(overrides)
    return rules


def make_ctx(cfg, mesh, shape=None, overrides=None) -> ShardCtx:
    return ShardCtx(mesh=mesh, rules=make_rules(cfg, mesh, shape, overrides))
