"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Prefill decompresses the latent into per-head K/V (FLOP-efficient for long
query blocks). Decode uses the *absorbed* formulation: W_UK is folded into the
query and W_UV into the output projection, so attention runs directly against
the compressed latent cache (kv_lora_rank + rope_dim per token) — this is the
natively-small serving payload highlighted in DESIGN.md §4.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention
from repro.models.layers import apply_rope, rmsnorm
from repro.models.schema import ParamSpec

NEG_INF = -1e30


def mla_schema(cfg) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    m = cfg.mla
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    s = {
        "wkv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "lora")),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("lora",), init="ones"),
        "wk_b": ParamSpec((m.kv_lora_rank, h, m.qk_nope_dim), ("lora", "heads", None)),
        "wv_b": ParamSpec((m.kv_lora_rank, h, m.v_head_dim), ("lora", "heads", None)),
        "wo": ParamSpec((h * m.v_head_dim, d), ("heads", "embed")),
    }
    if m.q_lora_rank:
        s["wq_a"] = ParamSpec((d, m.q_lora_rank), ("embed", "lora"))
        s["q_norm"] = ParamSpec((m.q_lora_rank,), ("lora",), init="ones")
        s["wq_b"] = ParamSpec((m.q_lora_rank, h * qk_dim), ("lora", "heads"))
    else:
        s["wq"] = ParamSpec((d, h * qk_dim), ("embed", "heads"))
    return s


def _project_q(p, cfg, x):
    m = cfg.mla
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    if m.q_lora_rank:
        q = rmsnorm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(x.shape[:-1] + (cfg.n_heads, qk_dim))
    return q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]


def latent_kv(p, cfg, x, positions):
    """x -> (c_kv [B,S,r], k_rope [B,S,rope]) — the cache entries."""
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c_kv = rmsnorm(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank :]
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_prefill(p, cfg, x, positions, *, q_chunk=1024, window=0, shard_ctx=None):
    """Decompressed MLA attention for training/prefill. Returns (out, cache)."""
    m = cfg.mla
    q_nope, q_rope = _project_q(p, cfg, x)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = latent_kv(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["wk_b"]).astype(x.dtype)
    v = jnp.einsum("bsr,rhd->bshd", c_kv, p["wv_b"]).astype(x.dtype)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[..., None, :], k_nope.shape[:-1] + (m.qk_rope_dim,))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    if shard_ctx is not None:
        # the broadcast+concat of the shared RoPE key must not re-replicate
        # the decompressed K/V over the head axis (134 GB/device if it does)
        q = shard_ctx.constrain(q, "batch", None, "heads", None)
        k = shard_ctx.constrain(k, "batch", None, "heads", None)
        v = shard_ctx.constrain(v, "batch", None, "heads", None)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    # pad v's head_dim up to k's so chunked_attention can run one einsum
    out = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=q_chunk, scale=scale, shard_ctx=shard_ctx)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * m.v_head_dim) @ p["wo"]
    return out, {"ckv": c_kv, "krope": k_rope}


def mla_decode_update(p, cfg, x, cache, lengths, positions, *, valid_len=None,
                      shard_ctx=None):
    """Fused latent-cache ring-write + absorbed-matmul decode.

    x: [B,1,d]; cache: {"ckv": [B,W,r], "krope": [B,W,rope]}; lengths: [B].
    Returns (out [B,1,d], new_cache). Math:
      score = q_nope^T W_kb c + q_rope^T k_rope ; out_h = W_vb^T (sum p_t c_t)
    Like decode_attention_update, the write happens INSIDE the shard_map when
    the latent cache is sequence-sharded.
    """
    m = cfg.mla
    B = x.shape[0]
    q_nope, q_rope = _project_q(p, cfg, x)  # [B,1,H,*]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, p["wk_b"]).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    ckv_new, krope_new = latent_kv(p, cfg, x, positions)  # [B,1,r], [B,1,rope]

    def attend(q_abs_l, q_rope_l, ckv_l, krope_l, valid):
        s_lat = jnp.einsum(
            "bqhr,bkr->bhqk", q_abs_l, ckv_l, preferred_element_type=jnp.float32
        )
        s_rope = jnp.einsum(
            "bqhd,bkd->bhqk", q_rope_l, krope_l, preferred_element_type=jnp.float32
        )
        scores = (s_lat + s_rope) * scale  # [B,H,1,W]
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        mx = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - mx)
        e = jnp.where(valid[:, None, None, :], e, 0.0)
        l = jnp.sum(e, axis=-1, keepdims=True)
        ctx = jnp.einsum("bhqk,bkr->bqhr", e.astype(ckv_l.dtype), ckv_l)
        return ctx, mx, l

    W = cache["ckv"].shape[1]
    if shard_ctx is None or shard_ctx.kv_seq_axes is None:
        from repro.models import kvcache as kvc

        ckv = kvc.ring_write(cache["ckv"], ckv_new, lengths)
        krope = kvc.ring_write(cache["krope"], krope_new, lengths)
        if valid_len is None:
            valid = jnp.ones((B, W), bool)
        else:
            valid = jnp.arange(W)[None, :] < valid_len[:, None]
        ctx, _, l = attend(q_abs, q_rope, ckv, krope, valid)
        ctx = ctx / jnp.maximum(
            l[..., 0].transpose(0, 2, 1)[..., None], 1e-30
        ).astype(ctx.dtype)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        from jax.sharding import PartitionSpec as P

        from repro.models.attention import _local_ring_write, _shard_index

        axes = shard_ctx.kv_seq_axes
        axes_t = axes if isinstance(axes, tuple) else (axes,)
        mesh = shard_ctx.mesh
        vlen = valid_len if valid_len is not None else jnp.full((B,), W, jnp.int32)

        def shard_fn(q_abs_l, q_rope_l, ckvn_l, kropen_l, ckv_l, krope_l,
                     lens_l, vl):
            W_l = ckv_l.shape[1]
            start = _shard_index(mesh, axes_t) * W_l
            ckv_l = _local_ring_write(ckv_l, ckvn_l, lens_l, start, W_l, W)
            krope_l = _local_ring_write(krope_l, kropen_l, lens_l, start, W_l, W)
            slot = start + jnp.arange(W_l)
            valid = slot[None, :] < vl[:, None]
            ctx, mx, l = attend(q_abs_l, q_rope_l, ckv_l, krope_l, valid)
            m_g = jax.lax.pmax(mx, axes)
            corr = jnp.exp(mx - m_g)  # [B,H,1,1]
            corr_ctx = corr[..., 0].transpose(0, 2, 1)[..., None]  # [B,1,H,1]
            num = jax.lax.psum(ctx * corr_ctx, axes)
            den = jax.lax.psum(l * corr, axes)  # [B,H,1,1]
            den_ctx = den[..., 0].transpose(0, 2, 1)[..., None]
            out = (num / jnp.maximum(den_ctx, 1e-30)).astype(q_abs_l.dtype)
            return out, ckv_l, krope_l

        batch_ax = shard_ctx.rules.get("batch")
        q4 = P(batch_ax, None, None, None)
        n3 = P(batch_ax, None, None)
        kvspec = P(batch_ax, axes, None)
        b1 = P(batch_ax)
        ctx, ckv, krope = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(q4, q4, n3, n3, kvspec, kvspec, b1, b1),
            out_specs=(q4, kvspec, kvspec),
        )(q_abs, q_rope, ckv_new, krope_new, cache["ckv"], cache["krope"],
          lengths, vlen)
        new_cache = {"ckv": ckv, "krope": krope}

    out = jnp.einsum("bqhr,rhd->bqhd", ctx, p["wv_b"]).astype(x.dtype)
    out = out.reshape(B, 1, cfg.n_heads * m.v_head_dim) @ p["wo"]
    return out, new_cache
