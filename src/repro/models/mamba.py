"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Forward uses the chunked SSD algorithm (intra-chunk dense attention-like MXU
work + inter-chunk state recurrence). ``repro.kernels.ssd_scan`` implements
the same chunk computation as a Pallas kernel; ``ssd_chunked`` here is the
pure-jnp path used for dry-runs and as the kernel oracle's counterpart.
The decode step is the O(1) state recurrence — the constant-size serving
payload called out in DESIGN.md §4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm
from repro.models.schema import ParamSpec


def mamba_schema(cfg) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = d * s.expand
    nh = s.n_heads(d)
    g = 1  # B/C groups
    conv_ch = d_in + 2 * g * s.d_state
    return {
        "in_proj": ParamSpec(
            (d, 2 * d_in + 2 * g * s.d_state + nh), ("embed", "ssm_in")
        ),
        "conv_w": ParamSpec((s.d_conv, conv_ch), ("conv", "ssm_in")),
        "conv_b": ParamSpec((conv_ch,), ("ssm_in",), init="zeros"),
        "A_log": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "gate_norm": ParamSpec((d_in,), ("ssm_in",), init="ones"),
        "out_proj": ParamSpec((d_in, d), ("ssm_in", "embed")),
    }


def _split_zxbcdt(cfg, zxbcdt):
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    g = 1
    z, xBC, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in + 2 * g * s.d_state], axis=-1
    )
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d. xBC: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(K):
        out = out + pad[:, i : i + xBC.shape[1], :].astype(jnp.float32) * w[i]
    return jax.nn.silu(out + b).astype(xBC.dtype)


def segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: [b, S, nh, hd]; dt: [b, S, nh] (post-softplus); A: [nh] (negative);
    B, C: [b, S, g, d_state] (g == 1 here). Returns (y [b,S,nh,hd],
    final_state [b, nh, hd, d_state]).
    """
    b, S, nh, hd = x.shape
    g, ds = B.shape[2], B.shape[3]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)

    xa = (x * dt[..., None]).reshape(b, nc, chunk, nh, hd)
    dA = (dt * A[None, None, :]).reshape(b, nc, chunk, nh)  # [b,nc,L,nh]
    Bc = jnp.broadcast_to(B[:, :, :, None, :], (b, S, g, nh, ds)).reshape(
        b, nc, chunk, nh, ds
    )
    Cc = jnp.broadcast_to(C[:, :, :, None, :], (b, S, g, nh, ds)).reshape(
        b, nc, chunk, nh, ds
    )

    dA_cum = jnp.cumsum(dA, axis=2)  # [b,nc,L,nh]

    # pass 1 — chunk-final state contributions (no L x L tensors)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # [b,nc,L,nh]
    states = jnp.einsum(
        "bclhs,bclh,bclhd->bchds", Bc, decay_to_end.astype(jnp.float32),
        xa.astype(jnp.float32),
    )  # [b,nc,nh,hd,ds]

    # pass 2 — inter-chunk recurrence over chunk summaries
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])  # [b,nc,nh]
    init = (
        jnp.zeros((b, nh, hd, ds), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def scan_fn(carry, inp):
        st, dec = inp  # [b,nh,hd,ds], [b,nh]
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit the state ENTERING this chunk

    _, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    # final state = state entering last chunk, decayed, plus last chunk's sum
    final_state = (
        prev_states[-1] * chunk_decay[:, -1][:, :, None, None] + states[:, -1]
    )

    # pass 3 — per-chunk outputs, streamed (one chunk's L x L decay kernel
    # alive at a time; checkpointed so backward doesn't stack them)
    def chunk_out(ci):
        dAc = dA[:, ci]  # [b,L,nh]
        cumsc = jnp.cumsum(dAc, axis=1)
        Lmat = jnp.exp(segsum(jnp.moveaxis(dAc, 2, 1)))  # [b,nh,L,L]
        sc = jnp.einsum("blhs,bmhs->bhlm", Cc[:, ci], Bc[:, ci]) * Lmat
        y_in = jnp.einsum("bhlm,bmhd->blhd", sc.astype(x.dtype), xa[:, ci])
        y_x = jnp.einsum(
            "blhs,bhds,blh->blhd", Cc[:, ci], prev_states[ci], jnp.exp(cumsc)
        )
        return (y_in + y_x).astype(x.dtype)  # [b,L,nh,hd]

    if nc == 1:
        y = chunk_out(0)[:, None]
    else:
        y = jax.lax.map(jax.checkpoint(chunk_out), jnp.arange(nc))  # [nc,b,L,..]
        y = jnp.moveaxis(y, 0, 1)
    y = y.reshape(b, S, nh, hd).astype(x.dtype)
    return y, final_state


def ssd_decode_step(x, dt, A, B, C, state):
    """One-token SSD recurrence.

    x: [b,1,nh,hd]; dt: [b,1,nh]; B, C: [b,1,g,ds]; state: [b,nh,hd,ds].
    """
    dA = jnp.exp(dt[:, 0, :] * A[None, :])  # [b,nh]
    xa = (x * dt[..., None])[:, 0]  # [b,nh,hd]
    Bx = jnp.einsum("bgs,bhd->bhds", B[:, 0].astype(jnp.float32), xa.astype(jnp.float32))
    new_state = state * dA[:, :, None, None] + Bx
    y = jnp.einsum("bhds,bgs->bhd", new_state, C[:, 0].astype(jnp.float32))
    return y[:, None].astype(x.dtype), new_state


def mamba_forward(p, cfg, u, *, state=None, conv_state=None, decode=False):
    """Full Mamba-2 block. u: [B,S,d].

    Returns (out [B,S,d], new_cache {"conv": [B,K-1,C], "state": ...}).
    """
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    nh = s.n_heads(cfg.d_model)
    hd = s.head_dim
    g = 1

    zxbcdt = u @ p["in_proj"]
    z, xBC, dt = _split_zxbcdt(cfg, zxbcdt)

    K = s.d_conv
    if decode:
        # conv over rolling window [B, K, C]
        window = jnp.concatenate([conv_state, xBC], axis=1)  # [B,K,C]
        conv_out = jnp.sum(
            window.astype(jnp.float32) * p["conv_w"], axis=1, keepdims=True
        )
        xBC_c = jax.nn.silu(conv_out + p["conv_b"]).astype(xBC.dtype)
        new_conv = window[:, 1:]
    else:
        if conv_state is not None:
            xBC_in = jnp.concatenate([conv_state, xBC], axis=1)
            xBC_c = _causal_conv(xBC_in, p["conv_w"], p["conv_b"])[:, K - 1 :]
        else:
            xBC_c = _causal_conv(xBC, p["conv_w"], p["conv_b"])
        new_conv = xBC[:, -(K - 1) :, :] if xBC.shape[1] >= K - 1 else jnp.pad(
            xBC, ((0, 0), (K - 1 - xBC.shape[1], 0), (0, 0))
        )

    x = xBC_c[..., :d_in].reshape(u.shape[0], -1, nh, hd)
    B_ = xBC_c[..., d_in : d_in + g * s.d_state].reshape(u.shape[0], -1, g, s.d_state)
    C_ = xBC_c[..., d_in + g * s.d_state :].reshape(u.shape[0], -1, g, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if decode:
        y, new_state = ssd_decode_step(x, dt, A, B_, C_, state)
    else:
        S = x.shape[1]
        chunk = min(s.chunk, S)
        if S % chunk != 0:  # pad to a chunk multiple
            padlen = chunk - S % chunk
            x = jnp.pad(x, ((0, 0), (0, padlen), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, padlen), (0, 0)))
            B_ = jnp.pad(B_, ((0, 0), (0, padlen), (0, 0), (0, 0)))
            C_ = jnp.pad(C_, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        y, new_state = ssd_chunked(x, dt, A, B_, C_, chunk, initial_state=state)
        y = y[:, :S]
        x = x[:, :S]
        dt = dt[:, :S]

    y = (y + x * p["D"][None, None, :, None].astype(y.dtype)).astype(u.dtype)
    y = y.reshape(u.shape[0], -1, d_in)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"conv": new_conv, "state": new_state}
