"""Mixture-of-Experts: top-k router + capacity-based gather/scatter dispatch.

Dispatch is GShard/Switch-style positions-via-cumsum — it never materializes
the [T, E, C] dispatch tensor and its expert GEMMs carry exactly
T*top_k*capacity_factor worth of real FLOPs, so the roofline compute term
stays honest. Experts are sharded over the "model" mesh axis (expert
parallelism) when divisible, else each expert's hidden dim is TP-sharded
(grok-1: 8 experts on model=16).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.schema import ParamSpec


def moe_schema(cfg) -> dict:
    d = cfg.d_model
    m = cfg.moe
    # expert weights are 2D-sharded: experts (or their hidden dim) over
    # "model" AND their embed dim over "data" — MoE weights are the largest
    # tensors in the system and replicating them over either axis blows HBM.
    s = {
        "router": ParamSpec((d, m.n_experts), ("embed", "experts"), init="small_normal"),
        "w_gate": ParamSpec((m.n_experts, d, m.d_ff), ("experts", "expert_embed", "expert_ffn")),
        "w_up": ParamSpec((m.n_experts, d, m.d_ff), ("experts", "expert_embed", "expert_ffn")),
        "w_down": ParamSpec((m.n_experts, m.d_ff, d), ("experts", "expert_ffn", "expert_embed")),
    }
    if m.n_shared_experts:
        ff = m.n_shared_experts * m.d_ff
        s["shared"] = {
            "w_gate": ParamSpec((d, ff), ("embed", "ffn")),
            "w_up": ParamSpec((d, ff), ("embed", "ffn")),
            "w_down": ParamSpec((ff, d), ("ffn", "embed")),
        }
    return s


def capacity(n_tokens: int, cfg_moe) -> int:
    c = math.ceil(n_tokens * cfg_moe.top_k * cfg_moe.capacity_factor / cfg_moe.n_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for layout friendliness


def router_topk(logits, top_k: int):
    """fp32 softmax-then-topk (DeepSeek style): returns (weights, ids)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    return w, ids


def load_balance_loss(logits, ids, n_experts: int):
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_mean = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    onehot = jax.nn.one_hot(ids.reshape(-1), n_experts, dtype=jnp.float32)
    f_mean = jnp.mean(onehot, axis=0) * ids.shape[-1]
    return n_experts * jnp.sum(p_mean * f_mean)


def moe_apply(p, cfg, x2d, shard_ctx=None):
    """x2d: [T, d] -> ([T, d], aux_loss). Capacity-dropping top-k dispatch."""
    m = cfg.moe
    T, d = x2d.shape
    E, K = m.n_experts, m.top_k
    C = capacity(T, m)

    if shard_ctx is not None:
        x2d = shard_ctx.constrain(x2d, "moe_tokens", None)
    logits = x2d @ p["router"].astype(x2d.dtype)  # [T, E]
    if shard_ctx is not None:
        logits = shard_ctx.constrain(logits, "moe_tokens", None)
    weights, ids = router_topk(logits, K)  # [T, K]
    aux = load_balance_loss(logits, ids, E)

    # --- positions: sequential cumsum over the K slots (GShard) ----------- #
    pos_list, keep_list = [], []
    counts = jnp.zeros((E,), jnp.int32)
    for k in range(K):
        onehot = jax.nn.one_hot(ids[:, k], E, dtype=jnp.int32)  # [T, E]
        pos_in_e = jnp.cumsum(onehot, axis=0) - 1 + counts[None, :]
        pos_k = jnp.sum(pos_in_e * onehot, axis=-1)  # [T]
        counts = counts + jnp.sum(onehot, axis=0)
        keep_list.append(pos_k < C)
        pos_list.append(pos_k)
    pos = jnp.stack(pos_list, axis=1)  # [T, K]
    keep = jnp.stack(keep_list, axis=1)  # [T, K]

    # --- gather tokens into [E, C, d] -------------------------------------- #
    # scatter token indices into per-expert slot tables (sentinel T = empty)
    flat_e = ids.reshape(-1)
    flat_pos = jnp.where(keep.reshape(-1), pos.reshape(-1), C)  # overflow -> C
    slot_tok_ext = jnp.full((E, C + 1), T, jnp.int32)
    tok_ids = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K)).reshape(-1)
    slot_tok_ext = slot_tok_ext.at[flat_e, flat_pos].set(tok_ids, mode="drop")
    slot_tok = slot_tok_ext[:, :C]

    # clip-gather instead of a concat-padded source: the concat forced XLA to
    # materialize an unsharded [T+1, d] copy of every token on every device
    empty = slot_tok >= T  # [E, C]
    xe = jnp.take(x2d, jnp.minimum(slot_tok, T - 1), axis=0)  # [E, C, d]
    xe = jnp.where(empty[..., None], 0, xe)
    # Dispatch layout switches with capacity: training (C huge) shards the
    # capacity dim over "data"; decode (C tiny) instead shards xe's embed dim
    # to MATCH the 2D-sharded expert weights — otherwise XLA all-gathers the
    # expert weights (the largest tensors in the system) every layer.
    decode_like = C < 1024
    if shard_ctx is not None:
        if decode_like:
            xe = shard_ctx.constrain(xe, "experts", None, "expert_embed")
        else:
            xe = shard_ctx.constrain(xe, "experts", "moe_cap", None)

    # --- expert GEMMs ------------------------------------------------------- #
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # [E, C, d]
    if shard_ctx is not None:
        if decode_like:
            ye = shard_ctx.constrain(ye, "experts", None, "expert_embed")
        else:
            ye = shard_ctx.constrain(ye, "experts", "moe_cap", None)

    # --- combine ------------------------------------------------------------ #
    out = jnp.zeros((T, d), x2d.dtype)
    for k in range(K):
        safe_pos = jnp.minimum(pos[:, k], C - 1)
        val = ye[ids[:, k], safe_pos]  # [T, d]
        w_k = (weights[:, k] * keep[:, k]).astype(x2d.dtype)
        out = out + val * w_k[:, None]

    if m.n_shared_experts:
        sp = p["shared"]
        hs = jax.nn.silu(x2d @ sp["w_gate"]) * (x2d @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out, aux
