"""KV / state caches as plain pytrees, with ring-buffer write semantics.

Cache kinds per layer signature:
  attn       -> {"k": [B,W,Hkv,hd], "v": [B,W,Hkv,hd]}
  attn+cross -> + {"xk": [B,Senc,H,hd], "xv": [B,Senc,H,hd]} (static)
  mla        -> {"ckv": [B,W,r], "krope": [B,W,rope]}
  ssm        -> {"conv": [B,K-1,C], "state": [B,nh,hd,ds]}

Ring semantics: slot = length % W, so a prefill of ``true_len <= W``
tokens occupies exactly ring slots ``[0, true_len)``. In steady-state
decode (dry-run shapes) every slot is valid, which also models
sliding-window caches exactly (W = window).

Shape surgery contract (the serving tier's handoff is built on it; see
docs/architecture.md):

  slice_cache(c, rows, prefix)      # valid extent only -> the wire
  pad_cache_rows(. , max_batch)     # row inverse, decode side
  grow_cache(. , max_seq)           # ring inverse, decode side

``slice_cache`` then ``pad_cache_rows`` + ``grow_cache`` round-trips a
pooled tree bit-exactly whenever ``prefix >= max true length`` among the
kept rows (ring writes above never touch slots past ``true_len`` during
prefill). Seq-keyed leaves (k/v/ckv/krope) ring-slice on their W dim;
static per-row leaves (SSM conv/state, cross-attn xk/xv) always move in
full. The serving tier rounds ``rows``/``prefix`` up to powers of two
(prefix floored at its ``handoff_block`` knob) before calling these, so
the jitted surgery compiles O(log max_batch x log max_seq) shapes.
``request_cache_nbytes`` prices ONE row's live prefix for the same tree
— the per-request "useful bytes" counter.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ring_write(cache_kv, new, lengths):
    """cache_kv: [B, W, ...]; new: [B, 1, ...]; lengths: [B] int32."""
    W = cache_kv.shape[1]
    idx = (lengths % W).astype(jnp.int32)

    def upd(c, n, i):
        return jax.lax.dynamic_update_slice_in_dim(c, n, i, axis=0)

    return jax.vmap(upd)(cache_kv, new.astype(cache_kv.dtype), idx)


def attn_cache_shapes(cfg, B: int, W: int, enc_len: int = 0) -> dict:
    if cfg.mla is not None:
        m = cfg.mla
        s = {"ckv": (B, W, m.kv_lora_rank), "krope": (B, W, m.qk_rope_dim)}
    else:
        s = {
            "k": (B, W, cfg.n_kv_heads, cfg.head_dim),
            "v": (B, W, cfg.n_kv_heads, cfg.head_dim),
        }
    if cfg.is_encdec and enc_len:
        s["xk"] = (B, enc_len, cfg.n_kv_heads, cfg.head_dim)
        s["xv"] = (B, enc_len, cfg.n_kv_heads, cfg.head_dim)
    return s


def ssm_cache_shapes(cfg, B: int) -> dict:
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    nh = s.n_heads(cfg.d_model)
    conv_ch = d_in + 2 * s.d_state
    return {
        "conv": (B, s.d_conv - 1, conv_ch),
        "state": (B, nh, s.head_dim, s.d_state),
    }


def layer_cache_shapes(cfg, sig, B: int, W: int, enc_len: int = 0) -> dict:
    kind, _ = sig
    if kind == "attn":
        return attn_cache_shapes(cfg, B, W, enc_len)
    return ssm_cache_shapes(cfg, B)


_F32_KEYS = ("state",)  # SSM state carries fp32 for numerical stability


def _dtype_for(key, dtype):
    return jnp.float32 if key in _F32_KEYS else dtype


def layer_cache_specs(cfg, sig, B, W, enc_len=0, dtype=jnp.bfloat16):
    shapes = layer_cache_shapes(cfg, sig, B, W, enc_len)
    return {k: jax.ShapeDtypeStruct(v, _dtype_for(k, dtype)) for k, v in shapes.items()}


def init_layer_cache(cfg, sig, B, W, enc_len=0, dtype=jnp.bfloat16):
    shapes = layer_cache_shapes(cfg, sig, B, W, enc_len)
    return {k: jnp.zeros(v, _dtype_for(k, dtype)) for k, v in shapes.items()}


_SEQ_KEYS = ("k", "v", "ckv", "krope")


def _leaf_key(path) -> str:
    """Cache-entry key ('k', 'conv', ...) from a tree_map_with_path path."""
    last = path[-1]
    return last.key if hasattr(last, "key") else str(last)


def grow_cache(caches, new_w: int):
    """Pad the ring dimension of a prefill cache so decode can append.

    Works on the full nested cache tree (grouped, possibly scan-stacked:
    the seq dim is axis 1 for unstacked, axis 2 for stacked leaves).
    """

    def grow(path, leaf):
        key = _leaf_key(path)
        if key not in _SEQ_KEYS:
            return leaf
        axis = leaf.ndim - 3 if key in ("k", "v") else leaf.ndim - 2
        w = leaf.shape[axis]
        if w >= new_w:
            return leaf
        pad = [(0, 0)] * leaf.ndim
        pad[axis] = (0, new_w - w)
        return jnp.pad(leaf, pad)

    return jax.tree_util.tree_map_with_path(grow, caches)


# per-key leaf rank WITHOUT scan-stacking; leading extra axes (stacked layer
# dims) precede the batch dim, so batch axis = leaf.ndim - _BASE_NDIM[key]
_BASE_NDIM = {"k": 4, "v": 4, "xk": 4, "xv": 4, "ckv": 3, "krope": 3,
              "conv": 3, "state": 4}


def slice_cache(caches, n_rows: int, prefix_len: int):
    """Slice a pooled/padded cache tree down to its valid extent.

    Keeps the first ``n_rows`` batch rows of every leaf and, for ring-dim
    (seq-keyed) leaves, the first ``prefix_len`` ring slots — both clamped
    to the leaf's actual extent. Static per-row leaves (SSM conv/state,
    cross-attn xk/xv) keep their full payload; scan-stacked leading layer
    axes are untouched. This is what a disaggregated handoff should put on
    the wire: the prefill's valid KV prefix, not the max_batch x max_seq
    pool padding (ring semantics write prefill tokens at slots
    ``[0, true_len)``, so a ``prefix_len >= max true_len`` slice loses
    nothing). The inverse is :func:`pad_cache_rows` + :func:`grow_cache`
    on the far side.
    """

    def visit(path, leaf):
        key = _leaf_key(path)
        base = _BASE_NDIM.get(key)
        if base is None:
            return leaf
        b_ax = leaf.ndim - base
        idx = [slice(None)] * leaf.ndim
        idx[b_ax] = slice(0, min(n_rows, leaf.shape[b_ax]))
        if key in _SEQ_KEYS:
            idx[b_ax + 1] = slice(0, min(prefix_len, leaf.shape[b_ax + 1]))
        return leaf[tuple(idx)]

    return jax.tree_util.tree_map_with_path(visit, caches)


def pad_cache_rows(caches, n_rows: int):
    """Zero-pad the batch dim of a (row-sliced) cache tree back to
    ``n_rows`` — the row inverse of :func:`slice_cache`; the ring dim is
    grown separately by :func:`grow_cache`."""

    def visit(path, leaf):
        key = _leaf_key(path)
        base = _BASE_NDIM.get(key)
        if base is None:
            return leaf
        b_ax = leaf.ndim - base
        if leaf.shape[b_ax] >= n_rows:
            return leaf
        pad = [(0, 0)] * leaf.ndim
        pad[b_ax] = (0, n_rows - leaf.shape[b_ax])
        return jnp.pad(leaf, pad)

    return jax.tree_util.tree_map_with_path(visit, caches)


def unpack_segments(caches, seg_starts, out_w: int):
    """Un-pack a packed-prefill cache tree into per-segment rows.

    ``caches`` come from :meth:`Model.prefill_packed`: every seq-keyed leaf
    is [.., 1, T, rest] with segment ``j``'s KV occupying packed slots
    ``[seg_starts[j], seg_starts[j] + len_j)``. Each leaf becomes
    [.., N, out_w, rest] (N = len(seg_starts)): row ``j`` reads ``out_w``
    consecutive packed slots from its start (clipped at T-1, so short/dummy
    segments trail neighbor garbage — masked downstream by valid_len
    exactly like bucketed-prefill pad garbage). Static per-row leaves
    can't appear (packed prefill is attention-only, like the paged pool).
    """
    N = seg_starts.shape[0]
    win = seg_starts[:, None] + jnp.arange(out_w)[None, :]  # [N, out_w]

    def unpack(leaf, b_ax):
        # leaf [.., 1, T, rest] -> drop the packed batch axis, gather rows
        sq = jnp.squeeze(leaf, axis=b_ax)  # [.., T, rest]
        g = jnp.take(sq, win, axis=b_ax, mode="clip")  # [.., N, out_w, rest]
        return g

    return _seq_visit(caches, unpack)


def splice_suffix(prior, suffix, offset):
    """Write a suffix cache tree into a same-rank prior at ring ``offset``.

    prior/suffix: seq leaves [.., B, W, rest] / [.., B, C, rest] with
    C + max(offset) <= W; ``offset`` is a traced scalar — one jit serves
    every chunk of a chunked prefill. Non-seq leaves are passed through
    from ``prior`` (chunked prefill is attention-only, so none appear).
    """

    def visit(path, prior_leaf):
        key = _leaf_key(path)
        base = _BASE_NDIM.get(key)
        if base is None or key not in _SEQ_KEYS:
            return prior_leaf
        seq_ax = prior_leaf.ndim - base + 1
        suf = _tree_get(suffix, path).astype(prior_leaf.dtype)
        return jax.lax.dynamic_update_slice_in_dim(
            prior_leaf, suf, offset, axis=seq_ax
        )

    return jax.tree_util.tree_map_with_path(visit, prior)


def request_cache_nbytes(caches, true_len: int, *, itemsize=None) -> int:
    """Bytes of ONE sequence's live cache in a pooled/padded tree.

    Seq-keyed leaves (ring dim) contribute per-token bytes * ``true_len``
    (clamped to the ring width); static leaves (SSM conv/state, cross-attn
    xk/xv) count in full. This is what a disaggregated handoff actually puts
    on the wire for one request — the pool's batch and ring padding is
    excluded. ``itemsize``: optional fn(leaf) -> bytes/element override for
    wire formats (e.g. int8 host staging).
    """
    total = 0.0

    def visit(path, leaf):
        nonlocal total
        key = _leaf_key(path)
        base = _BASE_NDIM.get(key)
        if base is None:
            return
        isz = itemsize(leaf) if itemsize else jnp.dtype(leaf.dtype).itemsize
        nelem = 1
        for d in leaf.shape:
            nelem *= d
        b_ax = leaf.ndim - base
        B = leaf.shape[b_ax]
        if key in _SEQ_KEYS:
            W = leaf.shape[b_ax + 1]
            total += nelem / (B * W) * min(true_len, W) * isz
        else:
            total += nelem / B * isz

    jax.tree_util.tree_map_with_path(visit, caches)
    return math.ceil(total)


# --------------------------------------------------------------------------- #
# Paged KV pool: fixed-size blocks + per-request page tables + refcounts.
#
# A paged tree has the SAME leaf ranks as a dense pooled tree, with the
# (batch, ring) leading axes replaced by (num_blocks, page_size): a dense
# seq leaf [.., B, W, rest] becomes [.., N, page, rest] (scan-stacked layer
# axes stay in front). Block 0 is a permanently-zero sentinel: page tables
# initialize to it, gathers through it read zeros (masked by valid_len
# downstream), and writes targeting it are redirected out of bounds so JAX's
# scatter drops them — freed/empty slots therefore never corrupt the pool.
# --------------------------------------------------------------------------- #
def _seq_visit(caches, fn):
    """Map ``fn(leaf, block_ax)`` over seq-keyed leaves (others must not
    appear in a paged tree — the serving tier gates archs accordingly)."""

    def visit(path, leaf):
        key = _leaf_key(path)
        base = _BASE_NDIM.get(key)
        if base is None or key not in _SEQ_KEYS:
            raise ValueError(
                f"paged KV pool only supports seq-keyed cache leaves, got "
                f"{key!r} (attention-only / MLA stacks)"
            )
        return fn(leaf, leaf.ndim - base)

    return jax.tree_util.tree_map_with_path(visit, caches)


def paged_specs(dense_specs, num_blocks: int, page_size: int):
    """ShapeDtypeStruct tree for the block pool backing ``dense_specs``
    (a dense [.., B, W, ..] cache-spec tree)."""

    def respec(s, b_ax):
        shape = list(s.shape)
        shape[b_ax] = num_blocks
        shape[b_ax + 1] = page_size
        return jax.ShapeDtypeStruct(tuple(shape), s.dtype)

    return _seq_visit(dense_specs, respec)


def init_paged(dense_specs, num_blocks: int, page_size: int):
    """Zero-initialized block pool tree (block 0 = the zero sentinel)."""
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        paged_specs(dense_specs, num_blocks, page_size),
    )


def gather_pages(paged, page_table):
    """Materialize per-request dense caches from the block pool.

    paged: seq leaves [.., N, page, rest]; page_table: [B, n_pages] int32.
    Returns the dense tree [.., B, n_pages*page, rest] — rows gather their
    pages in order, unallocated entries (block 0) read zeros.
    """
    B, n_pages = page_table.shape

    def gather(leaf, b_ax):
        # page tables only hold in-range ids; clip like the ring kernel's
        # length clamp so a padded table can never read garbage
        g = jnp.take(leaf, page_table, axis=b_ax, mode="clip")
        shape = (g.shape[: b_ax + 1] + (n_pages * leaf.shape[b_ax + 1],)
                 + g.shape[b_ax + 3:])
        return g.reshape(shape)

    return _seq_visit(paged, gather)


def scatter_pages(paged, dense, dest_blocks):
    """Write a dense [.., B, L, rest] tree into the pool page-wise.

    dest_blocks: [B, L/page] int32 destination block per page; entries that
    are out of bounds (>= num_blocks) OR the zero sentinel are dropped, so
    dummy admission rows and beyond-extent pages vanish without a separate
    code path. Returns the updated pool tree.
    """
    B, n_pages = dest_blocks.shape

    def do(blocks_leaf, dense_leaf, b_ax, page):
        L = dense_leaf.shape[b_ax + 1]
        pages = dense_leaf.reshape(
            dense_leaf.shape[: b_ax + 1] + (n_pages, page)
            + dense_leaf.shape[b_ax + 2:]
        )
        # flatten (B, n_pages) -> one scatter axis at b_ax
        pages = jnp.moveaxis(pages, (b_ax, b_ax + 1), (0, 1))
        pages = pages.reshape((B * n_pages,) + pages.shape[2:])
        pages = jnp.moveaxis(pages, 0, b_ax)
        nb = blocks_leaf.shape[b_ax]
        dest = dest_blocks.reshape(-1)
        dest = jnp.where(dest == 0, nb, dest)  # never write the sentinel
        idx = (slice(None),) * b_ax + (dest,)
        return blocks_leaf.at[idx].set(pages.astype(blocks_leaf.dtype))

    def paired(path, blocks_leaf):
        key = _leaf_key(path)
        base = _BASE_NDIM[key]
        b_ax = blocks_leaf.ndim - base
        dense_leaf = _tree_get(dense, path)
        return do(blocks_leaf, dense_leaf, b_ax, blocks_leaf.shape[b_ax + 1])

    return jax.tree_util.tree_map_with_path(paired, paged)


def _tree_get(tree, path):
    node = tree
    for p in path:
        node = node[p.key if hasattr(p, "key") else p.idx]
    return node


def scatter_token(paged, dense, lengths, page_table):
    """Write back the ONE ring slot a decode step touched per row.

    ``dense`` is the gathered tree AFTER ``Model.decode_step`` ring-wrote
    the new token at slot ``lengths % W`` (lengths = pre-step values). The
    written value lands at (block = page_table[b, slot/page], offset =
    slot % page); rows whose page-table entry is the zero sentinel (freed
    or never-admitted slots) redirect out of bounds and drop.
    """
    B, n_pages = page_table.shape

    def put(blocks_leaf, dense_leaf, b_ax):
        page = blocks_leaf.shape[b_ax + 1]
        W = n_pages * page
        slot = (lengths % W).astype(jnp.int32)  # [B]
        blk = jnp.take_along_axis(
            page_table, (slot // page)[:, None], axis=1
        )[:, 0]
        nb = blocks_leaf.shape[b_ax]
        blk = jnp.where(blk == 0, nb, blk)  # sentinel rows: OOB, dropped
        off = slot % page
        # one written row per b: [.., B, rest]
        val = jnp.take_along_axis(
            dense_leaf,
            slot.reshape((1,) * b_ax + (B, 1) + (1,) * (dense_leaf.ndim
                                                        - b_ax - 2)),
            axis=b_ax + 1,
        )
        val = jnp.squeeze(val, axis=b_ax + 1)
        idx = (slice(None),) * b_ax + (blk, off)
        return blocks_leaf.at[idx].set(val.astype(blocks_leaf.dtype))

    def paired(path, blocks_leaf):
        key = _leaf_key(path)
        b_ax = blocks_leaf.ndim - _BASE_NDIM[key]
        return put(blocks_leaf, _tree_get(dense, path), b_ax)

    return jax.tree_util.tree_map_with_path(paired, paged)


class PagedKVPool:
    """Host-side allocator for a block pool: refcounts + free list.

    The device block tree itself lives wherever the owner keeps it (the
    decode pool threads it through donated jits; the disaggregated prefix
    store pins it to the prefill slice) — this class owns only the
    bookkeeping that makes shared prefixes safe: a block is reusable only
    when its refcount reaches zero, so an evicting cache index can never
    free a block a live request still reads. Block 0 is reserved as the
    permanent zero sentinel and is never handed out.
    """

    def __init__(self, num_blocks: int, page_size: int):
        if num_blocks < 2:
            raise ValueError(f"num_blocks must be >= 2: {num_blocks}")
        self.num_blocks = int(num_blocks)
        self.page = int(page_size)
        self.blocks = None  # optional owner-managed device tree
        self.reset()

    def reset(self):
        import numpy as np

        self.refs = np.zeros((self.num_blocks,), np.int32)
        self.refs[0] = 1  # sentinel: permanently live
        # pop() from the end -> ascending allocation order (deterministic)
        self._free = list(range(self.num_blocks - 1, 0, -1))

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        """Blocks currently referenced (excluding the sentinel)."""
        return int((self.refs[1:] > 0).sum())

    def alloc(self, n: int):
        """Claim ``n`` blocks (refcount 1 each) or None if short."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self.refs[b] = 1
        return out

    def ref(self, ids):
        for b in ids:
            if b == 0:
                continue
            if self.refs[b] <= 0:
                raise RuntimeError(f"ref of a free block {b}")
            self.refs[b] += 1

    def deref(self, ids) -> list:
        """Drop one reference per id; returns the ids that became free."""
        freed = []
        for b in ids:
            if b == 0:
                continue
            if self.refs[b] <= 0:
                raise RuntimeError(f"deref of a free block {b}")
            self.refs[b] -= 1
            if self.refs[b] == 0:
                self._free.append(int(b))
                freed.append(int(b))
        return freed


def cache_logical_axes(cfg, sig, kv_seq_sharded: bool) -> dict:
    """Logical axes per cache entry (mirrors layer_cache_shapes)."""
    kind, _ = sig
    seq_ax = "kv_seq" if kv_seq_sharded else "seq"
    # when the cache seq dim is sharded over "model", heads must stay local
    kvh = None if kv_seq_sharded else "kv_heads"
    if kind == "attn":
        if cfg.mla is not None:
            ax = {"ckv": ("batch", seq_ax, None), "krope": ("batch", seq_ax, None)}
        else:
            ax = {
                "k": ("batch", seq_ax, kvh, None),
                "v": ("batch", seq_ax, kvh, None),
            }
        if cfg.is_encdec:
            ax["xk"] = ("batch", None, "kv_heads", None)
            ax["xv"] = ("batch", None, "kv_heads", None)
        return ax
    return {
        "conv": ("batch", None, "ssm_in"),
        "state": ("batch", "ssm_heads", None, None),
    }
