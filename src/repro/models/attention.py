"""GQA attention: chunked prefill (memory-bounded), ring-buffer decode, and
flash-decoding across chips for sequence-sharded KV caches.

The pure-jnp paths here are the reference/dry-run implementation; the Pallas
kernels in ``repro.kernels`` implement the same math for TPU and are verified
against these in tests.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import apply_rope, rmsnorm
from repro.models.schema import ParamSpec

NEG_INF = -1e30


def attn_schema(cfg) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": ParamSpec((d, h * hd), ("embed", "heads")),
        "wk": ParamSpec((d, hk * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, hk * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((h * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        s["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return s


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def project_qkv(p, cfg, x, positions):
    """x: [B,S,d] -> q [B,S,H,hd], k/v [B,S,Hkv,hd] with qk-norm + RoPE."""
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(x @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# --------------------------------------------------------------------------- #
# Prefill / training attention: chunked over query blocks.
# --------------------------------------------------------------------------- #
def expand_kv(k, G: int, shard_ctx=None):
    """Megatron-style GQA under TP: repeat each KV head G times so the head
    dim matches q and STAYS shardable over "model" (Hkv=8 cannot shard over
    model=16; H=32 can). Each shard only materializes its own heads' copies.
    """
    if G == 1:
        return k
    k = jnp.repeat(k, G, axis=2)
    if shard_ctx is not None:
        k = shard_ctx.constrain(k, "batch", None, "heads", None)
    return k


def chunked_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    scale: Optional[float] = None,
    shard_ctx=None,
    prior_k=None,
    prior_v=None,
    prior_valid=None,
    segment_ids=None,
):
    """Memory-bounded attention: O(q_chunk * S_kv) live scores.

    q: [B, Sq, H, hd]; k, v: [B, Skv, Hkv, hd]. GQA via KV-head expansion
    (see expand_kv). ``window`` > 0 restricts attention to the trailing
    ``window`` positions (sliding-window variant for long-context dense).

    ``prior_k``/``prior_v`` ([B, Pp, Hkv, hd], already RoPE'd at their
    absolute positions) prepend a cached context the queries attend to but
    never re-compute: row ``b`` treats its first ``prior_valid[b]`` prior
    slots as valid history at absolute positions ``[0, prior_valid[b])``
    and its own queries as positions ``prior_valid[b] + i`` — the
    suffix-prefill path of the paged KV pool's prefix reuse.

    ``segment_ids`` ([B, Sq] int32, requires Sq == Skv) marks each token's
    packed-prefill segment: token i may attend to token j only when their
    ids match (on top of causal/window). Pad tokens carry id -1 — they
    match only each other, so no real token reads a pad and no segment
    reads across a boundary. NEG_INF masking makes the packed SCORES of a
    segment's rows exactly the scores of that segment prefixed alone
    (masked terms contribute exp(-1e30 - m) == 0.0 to the softmax), so a
    segment's rows are bitwise invariant to whatever else shares the
    packed buffer — tests/test_packing.py pins that law, plus the
    engine-level token identity with the bucketed path. Mutually
    exclusive with the prior-KV path.
    """
    B, Sq, H, hd = q.shape
    if segment_ids is not None:
        if prior_k is not None:
            raise ValueError(
                "segment_ids cannot combine with prior KV: packed prefill "
                "has no per-segment cached prefix"
            )
        if segment_ids.shape != (B, k.shape[1]):
            raise ValueError(
                f"segment_ids must be [B, Skv]={B, k.shape[1]}: "
                f"{segment_ids.shape}"
            )
    Hkv = k.shape[2]
    G = H // Hkv
    hd_v = v.shape[-1]  # may differ from hd (MLA: qk_dim != v_head_dim)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    k = expand_kv(k, G, shard_ctx)
    v = expand_kv(v, G, shard_ctx)
    Pp = 0
    if prior_k is not None:
        Pp = prior_k.shape[1]
        if Pp:
            k = jnp.concatenate(
                [expand_kv(prior_k.astype(k.dtype), G, shard_ctx), k], axis=1
            )
            v = jnp.concatenate(
                [expand_kv(prior_v.astype(v.dtype), G, shard_ctx), v], axis=1
            )
    if shard_ctx is not None:
        q = shard_ctx.constrain(q, "batch", None, "heads", None)

    q_chunk = min(q_chunk, Sq)
    n_chunks = max(1, math.ceil(Sq / q_chunk))
    pad = n_chunks * q_chunk - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc_all = q.reshape(B, n_chunks, q_chunk, H, hd)
    seg_all = None
    if segment_ids is not None:
        seg_q = segment_ids.astype(jnp.int32)
        if pad:
            # pad query rows get id -2: matches nothing, not even kv pads
            seg_q = jnp.pad(seg_q, ((0, 0), (0, pad)), constant_values=-2)
        seg_all = seg_q.reshape(B, n_chunks, q_chunk)
    kv_idx = jnp.arange(k.shape[1])

    def one_chunk(ci):
        qc = qc_all[:, ci]  # [B, Cq, H, hd]
        scores = (
            jnp.einsum("bqhd,bkhd->bhqk", qc, k, preferred_element_type=jnp.float32)
            * scale
        )
        q_idx = ci * q_chunk + jnp.arange(q_chunk)
        if Pp:
            # per-row mask [B, Cq, K]: prior cols valid below prior_valid[b]
            # (always causally visible); suffix cols use suffix-relative
            # causality; window uses per-row absolute positions.
            pv = prior_valid[:, None, None].astype(jnp.int32)  # [B,1,1]
            col = kv_idx[None, None, :]
            qi = q_idx[None, :, None]
            is_prior = col < Pp
            rel = col - Pp
            mask = jnp.where(is_prior, col < pv, True)
            if causal:
                mask &= jnp.where(is_prior, True, qi >= rel)
            if window > 0:
                abs_kv = jnp.where(is_prior, col, pv + rel)
                mask &= abs_kv > (pv + qi) - window
            scores = jnp.where(mask[:, None], scores, NEG_INF)
        else:
            mask = jnp.ones((q_chunk, k.shape[1]), bool)
            if causal:
                mask &= q_idx[:, None] >= kv_idx[None, :]
            if window > 0:
                # packed-index distance: within a contiguous segment this IS
                # the in-segment distance, and cross-segment pairs are
                # already masked below, so the window composes with packing.
                mask &= kv_idx[None, :] > q_idx[:, None] - window
            if seg_all is not None:
                smask = seg_all[:, ci][:, :, None] == segment_ids[:, None, :]
                scores = jnp.where((mask[None] & smask)[:, None],
                                   scores, NEG_INF)
            else:
                scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
        return out  # [B, Cq, H, hd_v]

    if n_chunks == 1:
        out = one_chunk(0)[:, None]
    else:
        # checkpoint the chunk body: backward-of-while otherwise STACKS every
        # chunk's [B,H,Cq,Skv] scores/probs residuals (n_chunks x GB).
        out = jax.lax.map(jax.checkpoint(one_chunk), jnp.arange(n_chunks))
        out = jnp.moveaxis(out, 0, 1)  # [B,n,Cq,H,hd_v]
    out = out.reshape(B, n_chunks * q_chunk, H, hd_v)
    if pad:
        out = out[:, :Sq]
    return out


# --------------------------------------------------------------------------- #
# Decode attention: one new token against a ring-buffer KV cache.
# --------------------------------------------------------------------------- #
def _partial_decode(q, k, v, valid, scale):
    """q: [B,1,H,hd]; k,v: [B,W,Hkv,hd]; valid: [B,W] bool.

    Returns partial-softmax triple (out [B,1,H,hd], m [B,1,H,1], l [B,1,H,1])
    so sequence shards can be merged flash-decoding style.
    """
    B, _, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, hd)
    scores = (
        jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
        * scale
    )  # [B,Hkv,G,1,W]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)  # [B,Hkv,G,1]
    e = jnp.exp(scores - m[..., None])
    e = jnp.where(valid[:, None, None, None, :], e, 0.0)
    l = jnp.sum(e, axis=-1)  # [B,Hkv,G,1]
    out = jnp.einsum("bhgqk,bkhd->bqhgd", e.astype(v.dtype), v)
    out = out.reshape(B, 1, H, hd)
    m = m[..., 0].reshape(B, 1, H, 1)
    l = l[..., 0].reshape(B, 1, H, 1)
    return out, m, l


def decode_attention(q, k, v, *, valid_len=None, shard_ctx=None, scale=None):
    """Single-token attention over a fully-materialized (local) cache."""
    B, _, H, hd = q.shape
    W = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if valid_len is None:
        valid = jnp.ones((B, W), bool)
    else:
        valid = jnp.arange(W)[None, :] < valid_len[:, None]
    out, _, l = _partial_decode(q, k, v, valid, scale)
    return (out / jnp.maximum(l, 1e-30)).astype(q.dtype)


def _shard_index(mesh, axes_t):
    shard = jax.lax.axis_index(axes_t[0])
    for a in axes_t[1:]:
        shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
    return shard


def _local_ring_write(cache_l, new, lengths, start, W_l, W_total):
    """Write new [B,1,...] into this shard's slice of the ring buffer.

    A masked select (not scatter): XLA SPMD turns a scatter onto a
    seq-sharded operand into a full replication of the cache, so each shard
    instead selects between its cache and the (broadcast) new entry.
    """
    slot = lengths % W_total  # [B] global ring slot
    idx = slot - start  # local slot (may be out of this shard's range)
    onehot = jnp.arange(W_l)[None, :] == idx[:, None]  # [B, W_l]
    extra = (1,) * (cache_l.ndim - 2)
    oh = onehot.reshape(onehot.shape + extra)
    return jnp.where(oh, new.astype(cache_l.dtype), cache_l)


def decode_attention_update(q, k_new, v_new, k_cache, v_cache, lengths, *,
                            valid_len=None, shard_ctx=None, scale=None):
    """Fused ring-write + flash-decoding attention.

    q, k_new, v_new: [B,1,H/Hkv,hd]; caches: [B,W,Hkv,hd]; lengths: [B].
    Returns (out [B,1,H,hd], k_cache', v_cache').

    When the cache is sequence-sharded (shard_ctx.kv_seq_axes), BOTH the
    ring write and the partial-softmax attention run inside one shard_map —
    the cache never crosses shards and is updated in place.
    """
    B, _, H, hd = q.shape
    W = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    if shard_ctx is None or shard_ctx.kv_seq_axes is None:
        from repro.models import kvcache as kvc

        k_cache = kvc.ring_write(k_cache, k_new, lengths)
        v_cache = kvc.ring_write(v_cache, v_new, lengths)
        out = decode_attention(
            q, k_cache, v_cache, valid_len=valid_len, scale=scale
        )
        return out, k_cache, v_cache

    axes = shard_ctx.kv_seq_axes
    axes_t = axes if isinstance(axes, tuple) else (axes,)
    mesh = shard_ctx.mesh
    vlen = valid_len if valid_len is not None else jnp.full((B,), W, jnp.int32)

    def local(q_l, kn_l, vn_l, kc_l, vc_l, lens_l, vl_l):
        W_l = kc_l.shape[1]
        start = _shard_index(mesh, axes_t) * W_l
        kc_l = _local_ring_write(kc_l, kn_l, lens_l, start, W_l, W)
        vc_l = _local_ring_write(vc_l, vn_l, lens_l, start, W_l, W)
        slot = start + jnp.arange(W_l)
        valid = slot[None, :] < vl_l[:, None]
        out, m, l = _partial_decode(q_l, kc_l, vc_l, valid, scale)
        m_g = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - m_g)
        num = jax.lax.psum(out * corr, axes)
        den = jax.lax.psum(l * corr, axes)
        return (num / jnp.maximum(den, 1e-30)).astype(q_l.dtype), kc_l, vc_l

    batch_ax = shard_ctx.rules.get("batch")
    q_spec = P(batch_ax, None, None, None)
    kv_spec = P(batch_ax, axes, None, None)
    b_spec = P(batch_ax)
    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(q_spec, q_spec, q_spec, kv_spec, kv_spec, b_spec, b_spec),
        out_specs=(q_spec, kv_spec, kv_spec),
    )(q, k_new, v_new, k_cache, v_cache, lengths, vlen)
