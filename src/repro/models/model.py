"""Top-level Model: schema, init, train/prefill/decode entry points, and
``input_specs`` (ShapeDtypeStruct stand-ins) for every (arch x input-shape).

Frontend carve-out (DESIGN.md §4): for vlm/audio archs the modality encoder
is a stub — ``input_specs`` supplies precomputed patch/frame embeddings of
the right shape and the model consumes them through a linear projector.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import kvcache as kvc
from repro.models import schema as sch
from repro.models.layers import (
    cross_entropy,
    embed_lookup,
    embed_schema,
    lm_head,
    pad_vocab,
    rmsnorm,
    rmsnorm_schema,
    vocab_parallel_nll,
)
from repro.models.transformer import (
    encoder_apply,
    encoder_schema,
    layer_groups,
    stack_apply_decode,
    stack_apply_full,
    stack_schema,
)

FRONTEND_DIM = 1024  # stubbed ViT / speech-encoder feature width


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    dtype: jnp.dtype = jnp.bfloat16
    remat: bool = False
    q_chunk: int = 1024
    unroll: bool = False  # inline scan groups (dry-run cost measurement)
    remat_policy: str = "full"  # full | dots | none (see transformer.py)

    # ------------------------------------------------------------------ #
    # Schema / params
    # ------------------------------------------------------------------ #
    def schema(self) -> dict:
        cfg = self.cfg
        s = {
            "embed": embed_schema(pad_vocab(cfg.vocab_size), cfg.d_model),
            "final_norm": rmsnorm_schema(cfg.d_model),
            "decoder": stack_schema(cfg, cross=cfg.is_encdec),
        }
        if cfg.is_encdec:
            s["encoder"] = encoder_schema(cfg)
            s["enc_norm"] = rmsnorm_schema(cfg.d_model)
        if cfg.frontend:
            s["frontend_proj"] = sch.ParamSpec(
                (FRONTEND_DIM, cfg.d_model), ("frontend", "embed")
            )
        return s

    def init(self, rng) -> dict:
        return sch.init_params(rng, self.schema(), self.dtype)

    def param_specs(self) -> dict:
        return sch.abstract_params(self.schema(), self.dtype)

    def param_pspecs(self, rules: dict) -> dict:
        return sch.partition_specs(self.schema(), rules)

    @property
    def groups(self):
        return layer_groups(self.cfg)

    # ------------------------------------------------------------------ #
    # Input embedding (tokens and/or stub-frontend features)
    # ------------------------------------------------------------------ #
    def _embed_inputs(self, params, batch, shard_ctx=None):
        cfg = self.cfg
        parts = []
        if cfg.frontend and "features" in batch:
            proj = batch["features"].astype(self.dtype) @ params["frontend_proj"]
            parts.append(proj)
        if "tokens" in batch and batch["tokens"] is not None:
            parts.append(
                embed_lookup(params["embed"], batch["tokens"], shard_ctx)
            )
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        return x.astype(self.dtype)

    # ------------------------------------------------------------------ #
    # Forward passes
    # ------------------------------------------------------------------ #
    def _encode(self, params, batch, shard_ctx=None):
        feats = batch["features"].astype(self.dtype) @ params["frontend_proj"]
        pos = jnp.arange(feats.shape[1])[None, :]
        enc = encoder_apply(
            params["encoder"], self.cfg, feats, pos,
            shard_ctx=shard_ctx, remat=self.remat, unroll=self.unroll,
            remat_policy=self.remat_policy,
        )
        return rmsnorm(enc, params["enc_norm"], self.cfg.norm_eps)

    def backbone(self, params, batch, *, shard_ctx=None, want_cache=False):
        """Embed + decoder stack + final norm. Returns (x, aux, caches)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch, shard_ctx) if cfg.is_encdec else None
        if cfg.is_encdec:
            x = embed_lookup(params["embed"], batch["tokens"], shard_ctx).astype(
                self.dtype
            )
        else:
            x = self._embed_inputs(params, batch, shard_ctx)
        if shard_ctx is not None and shard_ctx.rules.get("act_seq"):
            x = shard_ctx.constrain(x, "batch", "act_seq", None)
        pos = jnp.arange(x.shape[1])[None, :]
        x, aux, caches = stack_apply_full(
            params["decoder"], cfg, x, pos,
            causal=True, want_cache=want_cache, enc_out=enc_out,
            shard_ctx=shard_ctx, remat=self.remat, groups=self.groups,
            q_chunk=self.q_chunk, unroll=self.unroll,
            remat_policy=self.remat_policy,
        )
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, aux, caches

    def forward(self, params, batch, *, shard_ctx=None, want_cache=False):
        """Full-sequence pass. Returns (logits, aux, caches)."""
        x, aux, caches = self.backbone(
            params, batch, shard_ctx=shard_ctx, want_cache=want_cache
        )
        logits = lm_head(params["embed"], x, self.cfg.vocab_size)
        if shard_ctx is not None:
            logits = shard_ctx.constrain(logits, "batch", None, "vocab")
        return logits, aux, caches

    def loss(self, params, batch, *, shard_ctx=None):
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        aux_w = self.cfg.moe.router_aux_weight if self.cfg.moe else 0.0
        x, aux, _ = self.backbone(params, batch, shard_ctx=shard_ctx)
        if self.cfg.frontend and not self.cfg.is_encdec and "features" in batch:
            x = x[:, -labels.shape[1] :]  # VLM: loss only over the text suffix
        if shard_ctx is not None and shard_ctx.shards_vocab:
            nll = vocab_parallel_nll(
                x, params["embed"], labels, shard_ctx, self.cfg.vocab_size
            )
            if mask is None:
                nll_mean = jnp.mean(nll)
            else:
                m = mask.astype(jnp.float32)
                nll_mean = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
            return nll_mean + aux_w * aux
        logits = lm_head(params["embed"], x, self.cfg.vocab_size)
        return cross_entropy(logits, labels, mask) + aux_w * aux

    def prefill(self, params, batch, *, shard_ctx=None):
        """Returns (last_logits [B,V], caches, lengths [B]).

        The LM head runs on the LAST position only — prefill never pays the
        [B, S, vocab] logits cost.
        """
        x, _, caches = self.backbone(
            params, batch, shard_ctx=shard_ctx, want_cache=True
        )
        B, S = x.shape[:2]
        logits = lm_head(params["embed"], x[:, -1:], self.cfg.vocab_size)
        lengths = jnp.full((B,), S, jnp.int32)
        return logits[:, 0], caches, lengths

    def prefill_bucketed(self, params, batch, lengths, *, shard_ctx=None):
        """Padded-bucket prefill: tokens [B, L] right-padded, lengths [B] real.

        ATTENTION-ONLY stacks. Causal attention makes trailing pad invisible
        to real positions, so only the LM-head gather differs from
        :meth:`prefill`: logits are read at each row's last *real* position
        (``lengths - 1``), not at L-1. Returns (last_logits [B,V], caches,
        lengths). Pad positions do write garbage KV, but decode masks them
        (valid_len) and the next real token overwrites slot ``lengths % W``
        — so the cache splices straight into a ring pool.

        SSM/hybrid stacks must NOT use this: pad tokens flow through the
        conv window and SSD recurrence, so the returned recurrent state
        would differ from exact prefill even though the gathered logits are
        causal-correct (the engine routes those archs to the exact path).
        """
        x, _, caches = self.backbone(
            params, batch, shard_ctx=shard_ctx, want_cache=True
        )
        S = x.shape[1]
        idx = jnp.clip(lengths - 1, 0, S - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)  # [B,1,d]
        logits = lm_head(params["embed"], x_last, self.cfg.vocab_size)
        return logits[:, 0], caches, lengths.astype(jnp.int32)

    def prefill_suffix(self, params, batch, lengths, cached_lens, prior, *,
                       shard_ctx=None):
        """Suffix-only bucketed prefill over a cached prefix (paged reuse).

        tokens [B, L] hold each row's UNCACHED suffix (right-padded,
        ``lengths`` [B] real suffix lengths); ``prior`` is a cache-shaped
        {"k","v"} tree [.., B, Pp, ..] of already-RoPE'd prefix KV and
        ``cached_lens`` [B] says how much of it each row actually uses.
        Queries run at absolute positions ``cached_lens[b] + i`` and attend
        to (valid prior) ++ (causal suffix), so logits match a full prefill
        of prefix+suffix bit-for-math (not bit-for-bit: different jit
        shapes reassociate the bf16 sums). Returns
        (first_logits [B,V], suffix_caches, total_lengths [B]) — the
        returned caches hold ONLY the suffix KV; the caller splices them
        after the cached prefix (attention-only stacks, like
        prefill_bucketed).
        """
        cfg = self.cfg
        x = self._embed_inputs(params, batch, shard_ctx)
        S = x.shape[1]
        pos = cached_lens[:, None] + jnp.arange(S)[None, :]  # [B,S] absolute
        x, _, caches = stack_apply_full(
            params["decoder"], cfg, x, pos,
            causal=True, want_cache=True, shard_ctx=shard_ctx,
            remat=self.remat, groups=self.groups, q_chunk=self.q_chunk,
            unroll=self.unroll, remat_policy=self.remat_policy,
            prior=prior, prior_valid=cached_lens,
        )
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        idx = jnp.clip(lengths - 1, 0, S - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = lm_head(params["embed"], x_last, cfg.vocab_size)
        total = (cached_lens + lengths).astype(jnp.int32)
        return logits[:, 0], caches, total

    def prefill_packed(self, params, tokens, positions, segment_ids,
                       last_idx, *, shard_ctx=None):
        """Token-packed prefill: several prompts concatenated into ONE row.

        tokens [1, T] hold the segments back to back (pad token 0 after the
        last segment); ``positions`` [1, T] are segment-RELATIVE (each
        prompt restarts at 0, so RoPE matches an unpacked prefill exactly);
        ``segment_ids`` [1, T] carry the segment index per token (-1 on
        pads); ``last_idx`` [N] is the packed index of each segment's last
        real token (pad segments may point anywhere — their logits are
        dummy rows the caller drops). Attention is segment-masked (see
        chunked_attention), so each segment's hidden states — and its KV
        run in the returned packed caches [.., 1, T, ..] — are EXACTLY what
        a lone prefill of that prompt produces. Cost tracks total true
        tokens: one [1, T] pass replaces a [rows, bucket] padded batch.
        Returns (last_logits [N, V], packed_caches). Attention-only,
        non-MLA, token-only stacks (the engine gates archs).
        """
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens, shard_ctx).astype(self.dtype)
        x, _, caches = stack_apply_full(
            params["decoder"], cfg, x, positions,
            causal=True, want_cache=True, shard_ctx=shard_ctx,
            remat=self.remat, groups=self.groups, q_chunk=self.q_chunk,
            unroll=self.unroll, remat_policy=self.remat_policy,
            segment_ids=segment_ids,
        )
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        x_last = jnp.take_along_axis(
            x, last_idx[None, :, None], axis=1
        )  # [1, N, d]
        logits = lm_head(params["embed"], x_last, cfg.vocab_size)
        return logits[0], caches

    def decode_step(self, params, caches, tokens, lengths, *, shard_ctx=None):
        """tokens: [B,1] -> (logits [B,V], new_caches, lengths+1)."""
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens, shard_ctx).astype(self.dtype)
        x, new_caches = stack_apply_decode(
            params["decoder"], cfg, x, caches, lengths,
            shard_ctx=shard_ctx, groups=self.groups, unroll=self.unroll,
        )
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = lm_head(params["embed"], x, cfg.vocab_size)
        return logits[:, 0], new_caches, lengths + 1

    # ------------------------------------------------------------------ #
    # Cache construction
    # ------------------------------------------------------------------ #
    def _seq_budget(self, seq_len: int) -> int:
        if self.cfg.sliding_window:
            return min(seq_len, self.cfg.sliding_window)
        return seq_len

    def cache_specs(self, B: int, seq_len: int, dtype=None):
        dtype = dtype or self.dtype
        W = self._seq_budget(seq_len)
        enc_len = seq_len // 8 if self.cfg.is_encdec else 0
        out = {}
        for gi, g in enumerate(self.groups):
            block = {
                f"l{j}": kvc.layer_cache_specs(self.cfg, sig, B, W, enc_len, dtype)
                for j, sig in enumerate(g.sigs)
            }
            if g.count > 1:
                block = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((g.count,) + s.shape, s.dtype),
                    block,
                )
            out[f"g{gi}"] = block
        return out

    def init_cache(self, B: int, seq_len: int, dtype=None):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_specs(B, seq_len, dtype)
        )

    def cache_pspecs(self, rules: dict):
        from jax.sharding import PartitionSpec as P

        kv_sharded = rules.get("kv_seq") is not None
        is_p = lambda x: isinstance(x, P)
        out = {}
        for gi, g in enumerate(self.groups):
            block = {}
            for j, sig in enumerate(g.sigs):
                axes = kvc.cache_logical_axes(self.cfg, sig, kv_sharded)
                block[f"l{j}"] = {
                    k: P(*[(rules.get(a) if a is not None else None) for a in ax])
                    for k, ax in axes.items()
                }
            if g.count > 1:  # scan-stacked: prepend the layers dim
                block = jax.tree.map(
                    lambda p: P(*((None,) + tuple(p))), block, is_leaf=is_p
                )
            out[f"g{gi}"] = block
        return out

    # ------------------------------------------------------------------ #
    # input_specs: ShapeDtypeStruct stand-ins per input shape
    # ------------------------------------------------------------------ #
    def input_specs(self, shape: InputShape) -> dict:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32

        def tok(*s):
            return jax.ShapeDtypeStruct(s, i32)

        def feat(*s):
            return jax.ShapeDtypeStruct(s, self.dtype)

        if shape.kind == "train":
            if cfg.is_encdec:
                s_src = S // 2
                s_tgt = S - s_src
                return {
                    "features": feat(B, s_src, FRONTEND_DIM),
                    "tokens": tok(B, s_tgt),
                    "labels": tok(B, s_tgt),
                }
            if cfg.frontend:  # vlm
                s_img = int(S * cfg.frontend_tokens_fraction)
                s_txt = S - s_img
                return {
                    "features": feat(B, s_img, FRONTEND_DIM),
                    "tokens": tok(B, s_txt),
                    "labels": tok(B, s_txt),
                }
            return {"tokens": tok(B, S), "labels": tok(B, S)}

        if shape.kind == "prefill":
            if cfg.is_encdec:
                s_src = S // 2
                return {"features": feat(B, s_src, FRONTEND_DIM), "tokens": tok(B, S - s_src)}
            if cfg.frontend:
                s_img = int(S * cfg.frontend_tokens_fraction)
                return {"features": feat(B, s_img, FRONTEND_DIM), "tokens": tok(B, S - s_img)}
            return {"tokens": tok(B, S)}

        # decode: ONE new token against a cache of seq_len slots
        return {
            "tokens": tok(B, 1),
            "lengths": jax.ShapeDtypeStruct((B,), i32),
            "caches": self.cache_specs(B, S),
        }
