from repro.models.model import FRONTEND_DIM, Model

__all__ = ["Model", "FRONTEND_DIM"]
