"""Decoder/encoder stacks: layer grouping, scan-over-layers, all layer kinds.

Layers are grouped so that heterogeneous stacks still lower to compact HLO:
  * homogeneous stacks (llama, qwen, ...)      -> one scan
  * periodic stacks (jamba: 8-layer pattern)   -> scan over superblocks
  * prefix-irregular stacks (deepseek: dense layer 0 then 59 MoE) -> maximal
    homogeneous runs, each scanned

A layer signature is ``(kind, is_moe)`` with kind in {"attn", "ssm"}.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import kvcache as kvc
from repro.models.attention import (
    attn_schema,
    chunked_attention,
    decode_attention,
    decode_attention_update,
    project_qkv,
)
from repro.models.layers import ffn_apply, ffn_schema, rmsnorm, rmsnorm_schema
from repro.models.mamba import mamba_forward, mamba_schema
from repro.models.mla import latent_kv, mla_decode_update, mla_prefill, mla_schema
from repro.models.moe import moe_apply, moe_schema
from repro.models.schema import ParamSpec, stack


@dataclasses.dataclass(frozen=True)
class Group:
    sigs: tuple  # layer signatures within one superblock
    count: int  # number of superblocks (scan length)


def layer_signatures(cfg):
    return tuple(
        (cfg.layer_kind(i), cfg.layer_is_moe(i)) for i in range(cfg.n_layers)
    )


def layer_groups(cfg) -> list:
    sigs = layer_signatures(cfg)
    n = len(sigs)
    for P in range(1, min(8, n) + 1):
        if n % P == 0 and all(sigs[i] == sigs[i % P] for i in range(n)):
            return [Group(sigs[:P], n // P)]
    groups, i = [], 0
    while i < n:
        j = i
        while j < n and sigs[j] == sigs[i]:
            j += 1
        groups.append(Group((sigs[i],), j - i))
        i = j
    return groups


# --------------------------------------------------------------------------- #
# Schemas
# --------------------------------------------------------------------------- #
def layer_schema(cfg, sig, cross: bool = False) -> dict:
    kind, is_moe = sig
    d = cfg.d_model
    s = {"ln1": rmsnorm_schema(d)}
    if kind == "attn":
        s["attn"] = mla_schema(cfg) if cfg.mla is not None else attn_schema(cfg)
        if cross:
            s["ln_x"] = rmsnorm_schema(d)
            s["xattn"] = attn_schema(cfg)
    else:
        s["ssm"] = mamba_schema(cfg)
    if cfg.family != "ssm":
        s["ln2"] = rmsnorm_schema(d)
        s["moe" if is_moe else "ffn"] = (
            moe_schema(cfg) if is_moe else ffn_schema(d, cfg.d_ff)
        )
    return s


def stack_schema(cfg, cross: bool = False) -> dict:
    groups = layer_groups(cfg)
    out = {}
    for gi, g in enumerate(groups):
        block = {
            f"l{j}": layer_schema(cfg, sig, cross) for j, sig in enumerate(g.sigs)
        }
        out[f"g{gi}"] = stack(block, g.count) if g.count > 1 else block
    return out


def encoder_schema(cfg) -> dict:
    """Bidirectional encoder: attention + dense FFN, homogeneous."""
    d = cfg.d_model
    block = {
        "ln1": rmsnorm_schema(d),
        "attn": attn_schema(cfg),
        "ln2": rmsnorm_schema(d),
        "ffn": ffn_schema(d, cfg.d_ff),
    }
    return {"g0": stack(block, cfg.encoder_layers)}


# --------------------------------------------------------------------------- #
# Cross attention (no RoPE)
# --------------------------------------------------------------------------- #
def _cross_kv(p, cfg, enc_out):
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(enc_out.shape[:2] + (hk, hd))
    v = (enc_out @ p["wv"]).reshape(enc_out.shape[:2] + (hk, hd))
    return k, v


def _cross_attend_full(p, cfg, h, k, v, shard_ctx=None):
    q = (h @ p["wq"]).reshape(h.shape[:2] + (cfg.n_heads, cfg.head_dim))
    o = chunked_attention(q, k, v, causal=False, shard_ctx=shard_ctx)
    return o.reshape(h.shape[:2] + (-1,)) @ p["wo"]


# --------------------------------------------------------------------------- #
# Full-sequence layer application (train / prefill / encoder)
# --------------------------------------------------------------------------- #
def apply_layer_full(
    lp,
    cfg,
    sig,
    x,
    positions,
    *,
    causal: bool = True,
    want_cache: bool = False,
    enc_out=None,
    shard_ctx=None,
    q_chunk: int = 1024,
    prior=None,
    prior_valid=None,
    segment_ids=None,
):
    """Returns (x, aux_loss, cache_or_None).

    ``prior`` ({"k","v"} leaves [B, Pp, Hkv, hd], RoPE'd at absolute
    positions) + ``prior_valid`` [B] enable suffix prefill over a cached
    prefix (paged prefix reuse); the caller must pass per-row absolute
    ``positions`` to match. Attention-only (the serving tier gates archs).

    ``segment_ids`` [B, S] turns this into a packed prefill: attention is
    confined within each id's contiguous token run (see chunked_attention).
    Attention-only, non-MLA (latent-KV packing is not position-stable).
    """
    kind, is_moe = sig
    if segment_ids is not None and (kind != "attn" or cfg.mla is not None):
        raise ValueError("packed prefill requires plain attention layers")
    B, S, d = x.shape
    aux = jnp.zeros((), jnp.float32)
    cache = None

    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if kind == "attn":
        if cfg.mla is not None:
            if prior is not None:
                raise ValueError("prefix-reuse prefill not supported for MLA")
            o, mla_cache = mla_prefill(
                lp["attn"], cfg, h, positions, q_chunk=q_chunk,
                window=cfg.sliding_window, shard_ctx=shard_ctx,
            )
            x = x + o
            if want_cache:
                cache = mla_cache
        else:
            q, k, v = project_qkv(lp["attn"], cfg, h, positions)
            o = chunked_attention(
                q, k, v, causal=causal, window=cfg.sliding_window,
                q_chunk=q_chunk, shard_ctx=shard_ctx,
                prior_k=None if prior is None else prior["k"],
                prior_v=None if prior is None else prior["v"],
                prior_valid=prior_valid,
                segment_ids=segment_ids,
            )
            x = x + o.reshape(B, S, -1) @ lp["attn"]["wo"]
            if want_cache:
                cache = {"k": k, "v": v}
        if enc_out is not None:
            hx = rmsnorm(x, lp["ln_x"], cfg.norm_eps)
            xk, xv = _cross_kv(lp["xattn"], cfg, enc_out)
            x = x + _cross_attend_full(lp["xattn"], cfg, hx, xk, xv, shard_ctx)
            if want_cache:
                cache.update({"xk": xk, "xv": xv})
    else:
        o, ssm_cache = mamba_forward(lp["ssm"], cfg, h)
        x = x + o
        if want_cache:
            cache = ssm_cache

    if cfg.family != "ssm":
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if is_moe:
            o, aux = moe_apply(lp["moe"], cfg, h2.reshape(B * S, d), shard_ctx)
            o = o.reshape(B, S, d)
        else:
            o = ffn_apply(lp["ffn"], h2)
        x = x + o
    if shard_ctx is not None and shard_ctx.rules.get("act_seq"):
        # sequence parallelism: the residual stream (and thus the remat-saved
        # scan carry) lives seq-sharded over "model"; XLA turns the TP
        # all-reduces into reduce-scatter + all-gather pairs.
        x = shard_ctx.constrain(x, "batch", "act_seq", None)
    return x, aux, cache


# --------------------------------------------------------------------------- #
# One-token decode layer application
# --------------------------------------------------------------------------- #
def apply_layer_decode(lp, cfg, sig, x, lcache, lengths, *, shard_ctx=None):
    """x: [B,1,d]. Returns (x, new_cache)."""
    kind, is_moe = sig
    B = x.shape[0]
    d = cfg.d_model
    new_cache = dict(lcache)
    positions = lengths[:, None]  # [B,1]

    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if kind == "attn":
        W = lcache["ckv" if cfg.mla is not None else "k"].shape[1]
        valid_len = jnp.minimum(lengths + 1, W)
        if cfg.mla is not None:
            o, mla_cache = mla_decode_update(
                lp["attn"], cfg, h, lcache, lengths, positions,
                valid_len=valid_len, shard_ctx=shard_ctx,
            )
            new_cache.update(mla_cache)
            x = x + o
        else:
            q, k, v = project_qkv(lp["attn"], cfg, h, positions)
            o, kc, vc = decode_attention_update(
                q, k, v, lcache["k"], lcache["v"], lengths,
                valid_len=valid_len, shard_ctx=shard_ctx,
            )
            new_cache["k"] = kc
            new_cache["v"] = vc
            x = x + o.reshape(B, 1, -1) @ lp["attn"]["wo"]
        if cfg.is_encdec and "xk" in lcache:
            hx = rmsnorm(x, lp["ln_x"], cfg.norm_eps)
            qx = (hx @ lp["xattn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
            ox = decode_attention(qx, lcache["xk"], lcache["xv"])
            x = x + ox.reshape(B, 1, -1) @ lp["xattn"]["wo"]
    else:
        o, ssm_cache = mamba_forward(
            lp["ssm"], cfg, h, state=lcache["state"], conv_state=lcache["conv"],
            decode=True,
        )
        x = x + o
        new_cache.update(ssm_cache)

    if cfg.family != "ssm":
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if is_moe:
            o, _ = moe_apply(lp["moe"], cfg, h2.reshape(B, d), shard_ctx)
            o = o.reshape(B, 1, d)
        else:
            o = ffn_apply(lp["ffn"], h2)
        x = x + o
    return x, new_cache


# --------------------------------------------------------------------------- #
# Stack application
# --------------------------------------------------------------------------- #
REMAT_POLICIES = {
    "full": None,  # save nothing, recompute everything (min memory)
    "dots": "dots_with_no_batch_dims_saveable",  # save matmul outputs
    "none": "everything_saveable",  # no recompute (max memory)
}


def _maybe_remat(fn, remat, policy: str = "full"):
    if not remat:
        return fn
    name = REMAT_POLICIES.get(policy, None)
    if name is None:
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=getattr(jax.checkpoint_policies, name))


def stack_apply_full(
    params,
    cfg,
    x,
    positions,
    *,
    causal: bool = True,
    want_cache: bool = False,
    enc_out=None,
    shard_ctx=None,
    remat: bool = False,
    groups: Optional[list] = None,
    q_chunk: int = 1024,
    unroll: bool = False,
    remat_policy: str = "full",
    prior=None,
    prior_valid=None,
    segment_ids=None,
):
    """Train/prefill/encoder pass. Returns (x, aux_total, caches).

    ``prior`` is an optional cache-shaped tree (same grouping/stacking as
    the returned caches) holding each layer's cached-prefix K/V; with
    ``prior_valid`` [B] it turns this into a suffix prefill (see
    apply_layer_full). When a group is scanned, the prior stack rides the
    scan xs next to the params. ``segment_ids`` [B, S] makes every
    attention layer a packed (segment-masked) prefill.
    """
    groups = groups or layer_groups(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}

    for gi, g in enumerate(groups):
        gp = params[f"g{gi}"]
        pg = None if prior is None else prior[f"g{gi}"]

        def block(xc, lp_pg):
            lp, pr = lp_pg if pg is not None else (lp_pg, None)
            aux_b = jnp.zeros((), jnp.float32)
            cache_b = {}
            for j, sig in enumerate(g.sigs):
                xc, aux, cache = apply_layer_full(
                    lp[f"l{j}"], cfg, sig, xc, positions,
                    causal=causal, want_cache=want_cache, enc_out=enc_out,
                    shard_ctx=shard_ctx, q_chunk=q_chunk,
                    prior=None if pr is None else pr[f"l{j}"],
                    prior_valid=prior_valid, segment_ids=segment_ids,
                )
                aux_b = aux_b + aux
                if want_cache:
                    cache_b[f"l{j}"] = cache
            return xc, (aux_b, cache_b)

        if g.count == 1:
            arg = (gp, pg) if pg is not None else gp
            x, (aux_b, cache_b) = _maybe_remat(block, remat, remat_policy)(x, arg)
            caches[f"g{gi}"] = cache_b
            aux_total = aux_total + aux_b
        elif unroll:
            cache_list = []
            for i in range(g.count):
                lp_i = jax.tree.map(lambda a: a[i], gp)
                if pg is not None:
                    lp_i = (lp_i, jax.tree.map(lambda a: a[i], pg))
                x, (aux_b, cache_b) = _maybe_remat(block, remat, remat_policy)(x, lp_i)
                aux_total = aux_total + aux_b
                cache_list.append(cache_b)
            if want_cache:
                caches[f"g{gi}"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *cache_list
                )
        else:
            xs = (gp, pg) if pg is not None else gp
            x, (aux_s, cache_s) = jax.lax.scan(
                _maybe_remat(block, remat, remat_policy), x, xs)
            caches[f"g{gi}"] = cache_s
            aux_total = aux_total + jnp.sum(aux_s)
    return x, aux_total, (caches if want_cache else None)


def stack_apply_decode(params, cfg, x, caches, lengths, *, shard_ctx=None,
                       groups: Optional[list] = None, unroll: bool = False):
    """One-token decode pass. Returns (x, new_caches)."""
    groups = groups or layer_groups(cfg)
    new_caches = {}
    for gi, g in enumerate(groups):
        gp = params[f"g{gi}"]
        gc = caches[f"g{gi}"]

        def block(xc, lp_lc):
            lp, lc = lp_lc
            new_lc = {}
            for j, sig in enumerate(g.sigs):
                xc, nc = apply_layer_decode(
                    lp[f"l{j}"], cfg, sig, xc, lc[f"l{j}"], lengths,
                    shard_ctx=shard_ctx,
                )
                new_lc[f"l{j}"] = nc
            return xc, new_lc

        if g.count == 1:
            x, nc = block(x, (gp, gc))
        elif unroll:
            ncs = []
            for i in range(g.count):
                slice_i = lambda a: a[i]
                x, nc_i = block(x, (jax.tree.map(slice_i, gp), jax.tree.map(slice_i, gc)))
                ncs.append(nc_i)
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        else:
            # The cache stack rides in the scan CARRY and is updated with a
            # dynamic_update on the (unsharded) layer dim: XLA bufferizes the
            # while-loop carry in place, so a decode step holds ONE cache
            # buffer — stacking per-layer caches as scan ys would instead
            # double the live cache and defeat donation.
            def carry_block(carry, lp_li):
                xc, gcs = carry
                lp, li = lp_li
                lc = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
                    gcs,
                )
                xc, new_lc = block(xc, (lp, lc))
                gcs = jax.tree.map(
                    lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n, li, 0),
                    gcs,
                    new_lc,
                )
                return (xc, gcs), None

            (x, nc), _ = jax.lax.scan(
                carry_block, (x, gc), (gp, jnp.arange(g.count))
            )
        new_caches[f"g{gi}"] = nc
    return x, new_caches


def encoder_apply(params, cfg, x, positions, *, shard_ctx=None, remat=False,
                  unroll: bool = False, remat_policy: str = "full"):
    """Bidirectional encoder (seamless): one homogeneous scanned group."""
    gp = params["g0"]

    def block(xc, lp):
        h = rmsnorm(xc, lp["ln1"], cfg.norm_eps)
        q, k, v = project_qkv(lp["attn"], cfg, h, positions)
        o = chunked_attention(q, k, v, causal=False, shard_ctx=shard_ctx)
        xc = xc + o.reshape(xc.shape[:2] + (-1,)) @ lp["attn"]["wo"]
        h2 = rmsnorm(xc, lp["ln2"], cfg.norm_eps)
        xc = xc + ffn_apply(lp["ffn"], h2)
        return xc, None

    if unroll:
        n = jax.tree.leaves(gp)[0].shape[0]
        for i in range(n):
            x, _ = _maybe_remat(block, remat, remat_policy)(
                x, jax.tree.map(lambda a: a[i], gp))
        return x
    x, _ = jax.lax.scan(_maybe_remat(block, remat, remat_policy), x, gp)
    return x
