"""Shared layer math: RMSNorm, RoPE, SwiGLU, vocab-parallel embedding."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.schema import ParamSpec


# --------------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------------- #
def rmsnorm_schema(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(x, w, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype) * w


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# SwiGLU FFN
# --------------------------------------------------------------------------- #
def ffn_schema(d: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamSpec((d, d_ff), ("embed", "ffn")),
        "w_up": ParamSpec((d, d_ff), ("embed", "ffn")),
        "w_down": ParamSpec((d_ff, d), ("ffn", "embed")),
    }


def ffn_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# --------------------------------------------------------------------------- #
# Vocab-parallel embedding (Megatron-style) + output head
# --------------------------------------------------------------------------- #
def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def embed_schema(vocab_padded: int, d: int) -> ParamSpec:
    return ParamSpec((vocab_padded, d), ("vocab", "embed"), init="small_normal")


def embed_lookup(table, tokens, shard_ctx=None):
    """Gather rows of a (possibly vocab-sharded) embedding table.

    With a sharding context, runs Megatron VocabParallelEmbedding inside
    shard_map: each model-shard gathers its local vocab range (out-of-range
    tokens produce zero) and the partials are summed with a single all-reduce
    of the [tokens, d_model] activations — avoiding an all-gather of the
    full table.
    """
    if shard_ctx is None or not shard_ctx.shards_vocab:
        return jnp.take(table, tokens, axis=0)

    mesh = shard_ctx.mesh
    model_axis = shard_ctx.rules["vocab"]
    tok_spec = shard_ctx.activation_pspec(tokens.ndim, batch_dim=0)

    def local(table_shard, tok):
        n_local = table_shard.shape[0]
        start = jax.lax.axis_index(model_axis) * n_local
        local_ids = tok - start
        in_range = (local_ids >= 0) & (local_ids < n_local)
        safe = jnp.where(in_range, local_ids, 0)
        out = jnp.take(table_shard, safe, axis=0)
        out = jnp.where(in_range[..., None], out, 0).astype(table_shard.dtype)
        return jax.lax.psum(out, model_axis)

    return jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(model_axis, None), tok_spec),
        out_specs=P(*tuple(tok_spec) + (None,)),
    )(table, tokens)


def lm_head(table, x, true_vocab: int):
    """Logits against the (tied, vocab-sharded) table; pad ids masked out."""
    logits = x @ table.T.astype(x.dtype)  # [..., vocab_padded]
    vp = table.shape[0]
    if vp != true_vocab:
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(iota < true_vocab, logits, -1e9)
    return logits


import functools


@functools.partial(jax.custom_jvp, nondiff_argnums=(1,))
def _pmax_sg(x, axis):
    """pmax with a zero tangent — shard_map autodiff lacks a pmax rule, and
    the softmax max-shift needs no gradient anyway."""
    return jax.lax.pmax(x, axis)


@_pmax_sg.defjvp
def _pmax_sg_jvp(axis, primals, tangents):
    (x,) = primals
    out = jax.lax.pmax(x, axis)
    return out, out * 0.0  # zero tangent with matching vma/type


def vocab_parallel_nll(x, table, labels, shard_ctx, true_vocab: int,
                       chunk: int = 1024):
    """Fused LM-head + cross-entropy with the vocab sharded over "model".

    Never materializes the full [B,S,V] logits: each model shard computes its
    local-vocab logits chunk-by-chunk over the sequence (rematerialized in the
    backward pass), and the softmax statistics are combined with pmax/psum —
    Megatron vocab-parallel CE adapted to shard_map. Returns nll [B, S] fp32.
    """
    model_ax = shard_ctx.rules["vocab"]
    batch_ax = shard_ctx.rules.get("batch")

    def local(x_l, tab_l, lab_l):
        B, S, _ = x_l.shape
        vloc = tab_l.shape[0]
        start = jax.lax.axis_index(model_ax) * vloc
        iota = start + jnp.arange(vloc)

        c = min(chunk, S)
        n = S // c if S % c == 0 else -1
        if n == -1:  # ragged: fall back to one chunk
            c, n = S, 1
        xc = x_l.reshape(B, n, c, -1)
        lc = lab_l.reshape(B, n, c)

        def body(_, inp):
            xs, ls = inp  # [B,c,d], [B,c]
            logits = (xs @ tab_l.T).astype(jnp.float32)
            logits = jnp.where(iota < true_vocab, logits, -jnp.inf)
            # max is for numerical stability only — no gradient needed
            m = _pmax_sg(jnp.max(logits, axis=-1), model_ax)
            se = jax.lax.psum(
                jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), model_ax
            )
            lse = jnp.log(se) + m
            lid = ls - start
            in_r = (lid >= 0) & (lid < vloc)
            safe = jnp.clip(lid, 0, vloc - 1)
            gold_l = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
            gold = jax.lax.psum(jnp.where(in_r, gold_l, 0.0), model_ax)
            return 0.0, lse - gold

        _, nll = jax.lax.scan(
            jax.checkpoint(body), 0.0, (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0))
        )
        return jnp.moveaxis(nll, 0, 1).reshape(B, S)

    return jax.shard_map(
        local,
        mesh=shard_ctx.mesh,
        in_specs=(
            P(batch_ax, None, None),
            P(model_ax, None),
            P(batch_ax, None),
        ),
        out_specs=P(batch_ax, None),
    )(x, table, labels)


def cross_entropy(logits, labels, mask=None):
    """Mean token NLL, fp32 accumulation, no full-softmax materialization."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
