"""Parameter schema: declare each weight once (shape + logical axes + init).

A schema is a nested dict whose leaves are :class:`ParamSpec`. From one schema
we derive (a) initialized params, (b) ``ShapeDtypeStruct`` stand-ins for the
dry-run, and (c) ``PartitionSpec`` trees for pjit — guaranteeing the three
always agree.

Logical axis names used across the models:
  batch, seq, embed, heads, kv_heads, head_dim, ffn, vocab, experts,
  expert_ffn, ssm_heads, ssm_in, state, conv, lora, rope, layers (stacking)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    axes: tuple  # logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: Optional[float] = None  # fan-in scale override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[0], 1)
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    if spec.init == "small_normal":
        scale = 0.02
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(rng, schema, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    )


def abstract_params(schema, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), schema, is_leaf=is_spec
    )


def partition_specs(schema, rules: dict):
    """Map logical axes -> mesh axes via ``rules`` (name -> mesh axis or None)."""

    def one(s: ParamSpec):
        return P(*[rules.get(a) if a is not None else None for a in s.axes])

    return jax.tree.map(one, schema, is_leaf=is_spec)


def stack(schema, n: int):
    """Prepend a 'layers' stacking dim of size ``n`` to every leaf."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        schema,
        is_leaf=is_spec,
    )


def param_bytes(schema, bytes_per_el: int = 2) -> int:
    total = 0
    for leaf in jax.tree.leaves(schema, is_leaf=is_spec):
        total += math.prod(leaf.shape) * bytes_per_el
    return total
