"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
Each block of 8 layers has one attention layer (offset 3, matching the paper's
a:m = 1:7 ratio); MoE replaces the MLP on every other layer (e=16, top-2).
Adaptation note (DESIGN.md §2): Jamba uses Mamba-1 blocks; we use the Mamba-2
SSD formulation throughout so the hybrid shares the chunked-scan kernel.
"""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_every=8,
    attn_offset=3,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, every=2, first_dense=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64),
    source="arXiv:2403.19887",
)
