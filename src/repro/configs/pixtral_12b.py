"""pixtral-12b [vlm] — Pixtral-ViT frontend + Mistral-Nemo decoder backbone.

[hf:mistralai/Pixtral-12B-2409] 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072. The vision encoder is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed patch embeddings; this config is the
language decoder that consumes them.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,  # Nemo-style: n_heads*head_dim (4096) != d_model
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_tokens_fraction=0.5,
    source="hf:mistralai/Pixtral-12B-2409",
)
