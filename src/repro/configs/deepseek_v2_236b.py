"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

[arXiv:2405.04434] 60L d_model=5120 128H d_ff(expert)=1536 vocab=102400.
First layer keeps a dense SwiGLU FFN (width 12288, per the paper); layers
1..59 are MoE with 160 routed experts (top-6) + 2 shared experts.
MLA: compressed KV latent of 512 + decoupled RoPE key of 64 per token — the
natively "small-payload" cache for the serving-transfer study.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,  # MLA is effectively MHA over decompressed heads
    head_dim=128,
    d_ff=12288,  # dense FFN width (first layer)
    vocab_size=102400,
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff=1536, n_shared_experts=2,
                  every=1, first_dense=1),
    source="arXiv:2405.04434",
)
