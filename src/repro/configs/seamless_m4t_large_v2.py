"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.

[arXiv:2308.11596] 24L d_model=1024 16H d_ff=8192 vocab=256206.
24 encoder + 24 decoder layers. The speech frontend (mel-spectrogram +
conformer feature extractor) is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed frame embeddings feeding the encoder.

long_500k is SKIPPED for this arch (enc-dec: a 500k-token decode target is
meaningless for speech translation) — recorded in DESIGN.md §4.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=10_000.0,
    frontend="audio",
    frontend_tokens_fraction=1.0,  # encoder input is all frame embeddings
    source="arXiv:2308.11596",
)
