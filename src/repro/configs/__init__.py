"""Architecture registry: the 10 assigned configs + the 4 input shapes."""

from __future__ import annotations

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.configs.shapes import SHAPES, InputShape, get_shape

from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B
from repro.configs.llama3_8b import CONFIG as LLAMA3_8B
from repro.configs.jamba_v01_52b import CONFIG as JAMBA_V01_52B
from repro.configs.deepseek_v2_236b import CONFIG as DEEPSEEK_V2_236B
from repro.configs.seamless_m4t_large_v2 import CONFIG as SEAMLESS_M4T_LARGE_V2
from repro.configs.qwen3_32b import CONFIG as QWEN3_32B
from repro.configs.starcoder2_3b import CONFIG as STARCODER2_3B
from repro.configs.grok1_314b import CONFIG as GROK1_314B
from repro.configs.mamba2_130m import CONFIG as MAMBA2_130M
from repro.configs.granite_34b import CONFIG as GRANITE_34B

ARCHITECTURES: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        PIXTRAL_12B,
        LLAMA3_8B,
        JAMBA_V01_52B,
        DEEPSEEK_V2_236B,
        SEAMLESS_M4T_LARGE_V2,
        QWEN3_32B,
        STARCODER2_3B,
        GROK1_314B,
        MAMBA2_130M,
        GRANITE_34B,
    )
}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHITECTURES[name]
    except KeyError:
        raise KeyError(
            f"unknown architecture {name!r}; options: {sorted(ARCHITECTURES)}"
        ) from None


__all__ = [
    "ARCHITECTURES",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "InputShape",
    "SHAPES",
    "get_config",
    "get_shape",
]
