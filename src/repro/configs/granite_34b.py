"""granite-34b [dense] — llama-arch, code, MQA (kv=1). [arXiv:2405.04324]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=10_000.0,
    source="arXiv:2405.04324",
)
