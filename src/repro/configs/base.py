"""Model configuration schema shared by all assigned architectures.

Every architecture in ``src/repro/configs/<id>.py`` instantiates a
:class:`ModelConfig` with the exact assigned hyper-parameters (source cited in
each file). ``reduced()`` derives the CPU-smoke-test variant (<=2 layers,
d_model<=512, <=4 experts) from the same family, as required by the spec.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block hyper-parameters."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256  # SSD chunk length

    def n_heads(self, d_model: int) -> int:
        return (d_model * self.expand) // self.head_dim


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden width
    n_shared_experts: int = 0
    # Layer l uses MoE iff l >= first_dense and (l - first_dense) % every == 0.
    every: int = 1
    first_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    # 0 = full attention. The long_500k sliding-window *variant* for
    # dense-family archs sets this at dry-run time (see DESIGN.md §4).
    sliding_window: int = 0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): layer i is attention iff i % attn_every == attn_offset,
    # else an SSM block. attn_every=0 => pure attention stack.
    attn_every: int = 0
    attn_offset: int = 3
    # encoder-decoder (seamless): 0 => decoder-only.
    encoder_layers: int = 0
    # multimodal frontend stub: "" | "vision" | "audio".
    frontend: str = ""
    frontend_tokens_fraction: float = 0.5  # fraction of seq that is embeddings
    source: str = ""  # citation

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "ssm" and self.ssm is None:
            object.__setattr__(self, "ssm", SSMConfig())

    # -- derived helpers ------------------------------------------------ #
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for decoder layer ``i``."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_every > 0:  # hybrid
            return "attn" if i % self.attn_every == self.attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        m = self.moe
        if m is None:
            return False
        return i >= m.first_dense and (i - m.first_dense) % m.every == 0

    def n_attn_layers(self) -> int:
        return sum(1 for i in range(self.n_layers) if self.layer_kind(i) == "attn")

    # -- parameter count (for MODEL_FLOPS = 6*N*D roofline term) --------- #
    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, embeddings included."""
        d = self.d_model
        n = 0
        # embeddings (+ output head unless tied)
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        layers = self.n_layers + self.encoder_layers
        for i in range(self.n_layers):
            n += self._layer_params(i, active_only, cross=self.is_encdec)
        for i in range(self.encoder_layers):
            n += self._layer_params(i, active_only, cross=False, force_dense=True)
        n += d  # final norm
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.mla is not None:
            m = self.mla
            q_in = m.q_lora_rank or d
            n = 0
            if m.q_lora_rank:
                n += d * m.q_lora_rank
            n += q_in * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            n += d * (m.kv_lora_rank + m.qk_rope_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d
            return n
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def _ssm_params(self) -> int:
        s = self.ssm or SSMConfig()
        d = self.d_model
        d_in = d * s.expand
        nh = s.n_heads(d)
        n = d * (2 * d_in + nh)  # in_proj for x, z and dt
        n += s.d_conv * (d_in + 2 * s.d_state)  # depthwise conv (x;B;C)
        n += d * 2 * s.d_state  # B, C projections (1 group)
        n += nh * 2  # A_log, D
        n += d_in * d  # out_proj
        return n

    def _ffn_params(self, i: int, active_only: bool) -> int:
        d = self.d_model
        if self.layer_is_moe(i):
            m = self.moe
            per_expert = 3 * d * m.d_ff
            routed = m.top_k if active_only else m.n_experts
            return routed * per_expert + m.n_shared_experts * per_expert + d * m.n_experts
        return 3 * d * self.d_ff  # SwiGLU

    def _layer_params(self, i: int, active_only: bool, cross: bool, force_dense: bool = False) -> int:
        d = self.d_model
        n = 2 * d  # norms
        if force_dense or self.layer_kind(i) == "attn":
            n += self._attn_params()
            if cross:
                n += self._attn_params() + d
        else:
            n += self._ssm_params()
        if not (self.family == "ssm"):
            n += self._ffn_params(i, active_only) if not force_dense else 3 * d * self.d_ff
        return n

    # -- smoke-test variant ---------------------------------------------- #
    def reduced(self) -> "ModelConfig":
        """<=2 layers, d_model<=512, <=4 experts: same family, CPU-sized."""
        changes: dict = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            d_ff=512,
            vocab_size=512,
            head_dim=64,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2), d_ff=256,
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                first_dense=min(self.moe.first_dense, 1),
            )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                kv_lora_rank=64, q_lora_rank=96, qk_nope_dim=32, qk_rope_dim=16,
                v_head_dim=32,
            )
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk=32)
        if self.attn_every > 0:  # keep the hybrid interleave visible in 2 layers
            changes["attn_every"] = 2
            changes["attn_offset"] = 1
        if self.encoder_layers:
            changes["encoder_layers"] = 2
        return dataclasses.replace(self, **changes)
