"""Blocked flash attention (prefill) — Pallas TPU kernel.

TPU-native design (DESIGN.md §6): the grid is (B, H, n_q, n_kv) with the KV
dimension innermost/sequential; online-softmax statistics (m, l) and the
output accumulator live in VMEM scratch that persists across the KV sweep.
Q/K tiles are MXU-aligned (block sizes multiples of 128 where the inputs
allow). Causal and sliding-window masking skip fully-masked KV blocks via
pl.when, so the kernel does ~half the naive FLOPs on causal prefill.

Segment masking (packed prefill): when per-token segment ids ride along,
the in-block mask additionally requires q and kv ids to match, so tokens
from different packed prompts never attend to each other. The causal
block-skip still applies — packed segments are contiguous, so any block
pair reachable within a segment is causally reachable on packed indices.

Layout: [B, H, S, hd] (the ops.py wrapper transposes from the model's
[B, S, H, hd]). GQA: KV-head index = q-head // G via the BlockSpec index map —
no KV expansion is materialized (unlike the XLA fallback path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, *rest,
    scale, causal, window, bq, bk, n_kv, sq_real, skv_real, segmented,
):
    if segmented:
        qseg_ref, kseg_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        qseg_ref = kseg_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    # block-level reachability (skip fully masked KV blocks)
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window > 0:
        reachable = jnp.logical_and(
            reachable, k_start + bk - 1 > q_start - window
        )

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0]  # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = (kpos < skv_real) & (qpos < sq_real)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= kpos > qpos - window
        if segmented:
            qs = qseg_ref[0]  # [bq]
            ks = kseg_ref[0]  # [bk]
            mask &= qs[:, None] == ks[None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q, k, v, *, causal=True, window=0, scale=None,
    block_q=128, block_k=128, interpret=False, sq_real=None, skv_real=None,
    q_segment_ids=None, k_segment_ids=None,
):
    """q: [B,H,Sq,hd]; k,v: [B,Hkv,Skv,hd] — padded to block multiples by ops.

    sq_real/skv_real: pre-padding lengths (mask out the pad region).
    q_segment_ids/k_segment_ids: [B, Sq] / [B, Skv] int32 packed-prefill
    segment ids (pad tokens -1); both or neither.
    """
    B, H, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else hd ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    n_q = pl.cdiv(Sq, bq)
    n_kv = pl.cdiv(Skv, bk)
    segmented = q_segment_ids is not None
    if segmented != (k_segment_ids is not None):
        raise ValueError("q_segment_ids and k_segment_ids: both or neither")

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv,
        sq_real=sq_real if sq_real is not None else Sq,
        skv_real=skv_real if skv_real is not None else Skv,
        segmented=segmented,
    )
    in_specs = [
        pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
    ]
    args = [q, k, v]
    if segmented:
        in_specs += [
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)),
        ]
        args += [q_segment_ids.astype(jnp.int32),
                 k_segment_ids.astype(jnp.int32)]
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
