"""Single-token decode attention against a (ring) KV cache — Pallas TPU.

The serving critical path (paper §IV: inference-time dominates decode).
Grid: (B, Hkv, n_kv_blocks) with the KV sweep innermost; each step streams a
KV tile HBM->VMEM and updates online-softmax statistics for the whole GQA
group (G q-heads per KV head) at once, so the cache is read EXACTLY once —
the kernel is purely HBM-bandwidth-bound, which is the roofline optimum for
decode. valid_len masking supports ragged ring buffers.

Layout: q [B, Hkv, G, hd]; k,v [B, Hkv, W, hd] (ops.py transposes).

Length-aware KV streaming: the ``lengths`` scalars are prefetched (SMEM)
before the grid runs, so the KV BlockSpec ``index_map`` can clamp the block
index to the last *valid* block per sequence. Grid steps past a short
sequence's tail re-reference the resident block instead of issuing a fresh
HBM->VMEM DMA for dead cache (Pallas elides the copy when consecutive grid
steps map to the same block), so a ragged batch pays bandwidth proportional
to sum(lengths), not B * W. The in-kernel ``pl.when`` / position mask still
gates compute, so outputs are bit-identical to the unclamped kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, bk, n_kv, w_real,
):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_len = len_ref[b]
    k_start = ik * bk

    @pl.when(k_start < valid_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
        v = v_ref[0, 0]  # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [G, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos < valid_len) & (kpos < w_real)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_bhgd(
    q, k, v, lengths, *, scale=None, block_k=512, interpret=False, w_real=None,
    length_aware=True,
):
    """q: [B,Hkv,G,hd]; k,v: [B,Hkv,W,hd]; lengths: [B] int32 valid slots.

    w_real: pre-padding cache capacity (mask out the pad region).
    length_aware: clamp KV block fetches to the valid prefix (see module doc).
    """
    B, Hkv, G, hd = q.shape
    W = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    bk = min(block_k, W)
    n_kv = pl.cdiv(W, bk)

    kernel = functools.partial(
        _decode_kernel, scale=scale, bk=bk, n_kv=n_kv,
        w_real=w_real if w_real is not None else W,
    )

    if length_aware:
        # Last block holding live KV for sequence b (>= 0 so empty slots
        # still map somewhere resident).
        def kv_index(b, h, j, lens):
            last = jnp.maximum((lens[b] + bk - 1) // bk - 1, 0)
            return (b, h, jnp.minimum(j, last), 0)
    else:
        def kv_index(b, h, j, lens):
            return (b, h, j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), kv_index),
            pl.BlockSpec((1, 1, bk, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)


def _paged_decode_kernel(
    len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, bk, n_kv, w_real,
):
    # identical online-softmax body; the page table only changes WHERE the
    # BlockSpec fetched this tile from, not what it means (logical page j
    # still covers ring positions [j*bk, (j+1)*bk))
    _decode_kernel(
        len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
        scale=scale, bk=bk, n_kv=n_kv, w_real=w_real,
    )


def paged_decode_attention_bhgd(
    q, k_blocks, v_blocks, page_table, lengths, *, scale=None,
    interpret=False, w_real=None,
):
    """Paged decode attention: KV gathered through a page table.

    q: [B,Hkv,G,hd]; k_blocks, v_blocks: [N, Hkv, page, hd] block pool;
    page_table: [B, n_pages] int32 (logical page j of row b lives in
    physical block page_table[b, j]); lengths: [B] valid ring slots.

    Same grid/body as :func:`decode_attention_bhgd` with block size =
    page; the KV index_map dereferences the (prefetched) page table, so
    each grid step DMAs exactly one physical block HBM->VMEM — shared
    prefix blocks are fetched from the one pooled copy, never duplicated
    per row. The logical-page index is length-clamped exactly like the
    ring kernel, so a ragged batch streams sum(lengths) bytes.
    """
    B, Hkv, G, hd = q.shape
    N, _, page, _ = k_blocks.shape
    n_pages = page_table.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    W = n_pages * page

    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, bk=page, n_kv=n_pages,
        w_real=w_real if w_real is not None else W,
    )

    def kv_index(b, h, j, lens, pt):
        last = jnp.maximum((lens[b] + page - 1) // page - 1, 0)
        jc = jnp.minimum(j, last)
        return (pt[b * n_pages + jc], h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, lens, pt: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page, hd), kv_index),
            pl.BlockSpec((1, 1, page, hd), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, h, j, lens, pt: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, page_table.reshape(-1), q, k_blocks, v_blocks)
