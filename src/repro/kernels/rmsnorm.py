"""Fused RMSNorm — Pallas TPU kernel.

Bandwidth-bound op: one HBM pass, row-tiled (block rows x full feature dim in
VMEM), fp32 reduction, bf16 output. Grid: (n_row_blocks,).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # [br, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = (x * jax.lax.rsqrt(var + eps)).astype(o_ref.dtype) * w_ref[...]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_2d(x, w, *, eps=1e-5, block_rows=256, interpret=False):
    """x: [N, D]; w: [D]."""
    N, D = x.shape
    br = min(block_rows, N)
    grid = (pl.cdiv(N, br),)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, w)
