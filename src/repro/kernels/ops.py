"""jit'd public wrappers around the Pallas kernels.

Each op accepts model-layout tensors, handles padding/transposes, and picks
interpret mode automatically on CPU (the kernels TARGET TPU; interpret=True
executes the kernel body in Python for validation — see DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import preprocess as _pp
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --------------------------------------------------------------------------- #
@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128, interpret=None,
                    segment_ids=None):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,Hkv,hd] -> [B,Sq,H,hd].

    segment_ids: optional [B, Sq] int32 packed-prefill ids (requires
    Sq == Skv; pad tokens -1) — forbids cross-segment attention.
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, Sq, H, hd = q.shape
    qt = _pad_to(jnp.moveaxis(q, 1, 2), 2, block_q)
    kt = _pad_to(jnp.moveaxis(k, 1, 2), 2, block_k)
    vt = _pad_to(jnp.moveaxis(v, 1, 2), 2, block_k)
    q_seg = k_seg = None
    if segment_ids is not None:
        seg = segment_ids.astype(jnp.int32)
        q_seg = _pad_to(seg, 1, block_q)
        k_seg = _pad_to(seg, 1, block_k)
    # real (unpadded) lengths are baked into the kernel's masks
    o = _fa.flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, scale=scale,
        block_q=min(block_q, qt.shape[2]), block_k=min(block_k, kt.shape[2]),
        interpret=interpret, sq_real=Sq, skv_real=k.shape[1],
        q_segment_ids=q_seg, k_segment_ids=k_seg,
    )
    return jnp.moveaxis(o[:, :, :Sq], 2, 1)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret", "length_aware"))
def decode_attention(q, k, v, lengths, *, scale=None, block_k=512,
                     interpret=None, length_aware=True):
    """q: [B,1,H,hd]; k,v: [B,W,Hkv,hd]; lengths: [B] -> [B,1,H,hd].

    length_aware: short sequences in a ragged batch only stream their valid
    KV prefix from HBM (dead tail blocks re-reference a resident block).
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, _, H, hd = q.shape
    W, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, hd)[:, 0]  # [B,Hkv,G,hd]
    kt = _pad_to(jnp.moveaxis(k, 1, 2), 2, block_k)  # [B,Hkv,W,hd]
    vt = _pad_to(jnp.moveaxis(v, 1, 2), 2, block_k)
    o = _dec.decode_attention_bhgd(
        qg, kt, vt, lengths.astype(jnp.int32),
        scale=scale, block_k=min(block_k, kt.shape[2]), interpret=interpret,
        w_real=W, length_aware=length_aware,
    )
    return o.reshape(B, 1, H, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_blocks, v_blocks, page_table, lengths, *,
                           scale=None, interpret=None):
    """q: [B,1,H,hd]; k_blocks, v_blocks: [N, page, Hkv, hd] block pool;
    page_table: [B, n_pages] int32; lengths: [B] -> [B,1,H,hd].

    Paged variant of :func:`decode_attention`: the kernel's KV index_map
    dereferences the page table, streaming each row's blocks from the
    shared pool (block size = page, length-clamped like the ring kernel).
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, _, H, hd = q.shape
    Hkv = k_blocks.shape[2]
    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, hd)[:, 0]  # [B,Hkv,G,hd]
    kt = jnp.moveaxis(k_blocks, 1, 2)  # [N,Hkv,page,hd]
    vt = jnp.moveaxis(v_blocks, 1, 2)
    o = _dec.paged_decode_attention_bhgd(
        qg, kt, vt, page_table.astype(jnp.int32), lengths.astype(jnp.int32),
        scale=scale, interpret=interpret,
        w_real=page_table.shape[1] * k_blocks.shape[1],
    )
    return o.reshape(B, 1, H, hd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=128, interpret=None):
    """Model layout: x [b,S,nh,hd]; dt [b,S,nh]; A [nh]; B,C [b,S,1,ds].

    Returns (y [b,S,nh,hd], final_state [b,nh,hd,ds] fp32).
    """
    interpret = _interpret_default() if interpret is None else interpret
    b, S, nh, hd = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, S)
    Sp = S + ((-S) % chunk)
    xt = _pad_to(jnp.moveaxis(x, 1, 2), 2, chunk)  # [b,nh,S,hd]
    dtt = _pad_to(jnp.moveaxis(dt, 1, 2), 2, chunk)  # [b,nh,S]
    Bb = jnp.broadcast_to(B, (b, S, nh, ds))
    Cc = jnp.broadcast_to(C, (b, S, nh, ds))
    Bt = _pad_to(jnp.moveaxis(Bb, 1, 2), 2, chunk)
    Ct = _pad_to(jnp.moveaxis(Cc, 1, 2), 2, chunk)
    y, state = _ssd.ssd_scan_bhsd(
        xt, dtt, A.astype(jnp.float32), Bt, Ct, chunk=chunk, interpret=interpret
    )
    return jnp.moveaxis(y[:, :, :S], 2, 1), state


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps=1e-5, block_rows=256, interpret=None):
    """x: [..., D]; w: [D]."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    x2p = _pad_to(x2, 0, block_rows) if x2.shape[0] > block_rows else x2
    o = _rn.rmsnorm_2d(
        x2p, w, eps=eps, block_rows=min(block_rows, x2p.shape[0]),
        interpret=interpret,
    )
    return o[: x2.shape[0]].reshape(shape)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_rows", "interpret"))
def preprocess(x_u8, mean, std, *, out_dtype=jnp.bfloat16, block_rows=512,
               interpret=None):
    """x_u8: [..., D] uint8; mean/std: [D]."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x_u8.shape
    x2 = x_u8.reshape(-1, shape[-1])
    x2p = _pad_to(x2, 0, block_rows) if x2.shape[0] > block_rows else x2
    o = _pp.preprocess_2d(
        x2p, mean, std, out_dtype=out_dtype,
        block_rows=min(block_rows, x2p.shape[0]), interpret=interpret,
    )
    return o[: x2.shape[0]].reshape(shape[:-1] + (shape[-1],))
