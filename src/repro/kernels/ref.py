"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (tests sweep
shapes/dtypes with interpret=True). They are intentionally naive — clarity
over speed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# preprocess: dequantize uint8 features + normalize (the paper's
# "preprocessing" serving stage — mean/std image-style normalization)
# --------------------------------------------------------------------------- #
def preprocess_ref(x_u8, mean, std, out_dtype=jnp.bfloat16):
    """x_u8: [N, D] uint8; mean/std: [D] fp32. -> [N, D] out_dtype."""
    x = x_u8.astype(jnp.float32) / 255.0
    return ((x - mean) / std).astype(out_dtype)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #
def rmsnorm_ref(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w).astype(x.dtype)


# --------------------------------------------------------------------------- #
# flash attention (prefill): causal + optional sliding window, GQA
# --------------------------------------------------------------------------- #
def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,Hkv,hd]."""
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    kx = jnp.repeat(k, G, axis=2)
    vx = jnp.repeat(v, G, axis=2)
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q, kx, preferred_element_type=jnp.float32
    ) * scale
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qi >= ki
    if window > 0:
        mask &= ki > qi - window
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), vx)


# --------------------------------------------------------------------------- #
# decode attention: one token vs ring cache, GQA
# --------------------------------------------------------------------------- #
def decode_attention_ref(q, k, v, valid_len=None, scale=None):
    """q: [B,1,H,hd]; k,v: [B,W,Hkv,hd]; valid_len: [B] or None."""
    B, _, H, hd = q.shape
    W, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    kx = jnp.repeat(k, G, axis=2)
    vx = jnp.repeat(v, G, axis=2)
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q, kx, preferred_element_type=jnp.float32
    ) * scale  # [B,H,1,W]
    if valid_len is not None:
        valid = jnp.arange(W)[None, None, None, :] < valid_len[:, None, None, None]
        scores = jnp.where(valid, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), vx)


# --------------------------------------------------------------------------- #
# SSD (mamba2): naive sequential recurrence — the definitional oracle
# --------------------------------------------------------------------------- #
def ssd_scan_ref(x, dt, A, B, C, initial_state=None):
    """x: [b,S,nh,hd]; dt: [b,S,nh]; A: [nh]; B,C: [b,S,1,ds].

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t
    Returns (y [b,S,nh,hd], final_state [b,nh,hd,ds]).
    """
    b, S, nh, hd = x.shape
    ds = B.shape[-1]
    state = (
        jnp.zeros((b, nh, hd, ds), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # [b,nh,hd], [b,nh], [b,1,ds], [b,1,ds]
        dA = jnp.exp(dtt * A[None, :])  # [b,nh]
        Bx = jnp.einsum("bs,bhd->bhds", Bt[:, 0, :], (xt * dtt[..., None]))
        state = state * dA[:, :, None, None] + Bx
        y = jnp.einsum("bhds,bs->bhd", state, Ct[:, 0, :])
        return state, y

    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C.astype(jnp.float32), 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state
