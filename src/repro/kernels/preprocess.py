"""Serving-stage preprocessing — Pallas TPU kernel.

The paper's "preprocessing" pipeline stage (resize/normalize before
inference). On TPU this is a fused dequantize (uint8 -> fp) + per-feature
mean/std normalize + bf16 cast, tiled in lane-aligned [block_rows, D] blocks
so client payloads stream HBM->VMEM exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _preprocess_kernel(x_ref, mean_ref, std_ref, o_ref):
    x = x_ref[...].astype(jnp.float32) * (1.0 / 255.0)
    y = (x - mean_ref[...]) / std_ref[...]
    o_ref[...] = y.astype(o_ref.dtype)


def preprocess_2d(x_u8, mean, std, *, out_dtype=jnp.bfloat16, block_rows=512,
                  interpret=False):
    """x_u8: [N, D] uint8; mean/std: [D] fp32 -> [N, D] out_dtype."""
    N, D = x_u8.shape
    br = min(block_rows, N)
    return pl.pallas_call(
        functools.partial(_preprocess_kernel),
        grid=(pl.cdiv(N, br),),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), out_dtype),
        interpret=interpret,
    )(x_u8, mean, std)
