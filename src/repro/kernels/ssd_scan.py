"""Chunked SSD scan (Mamba-2, arXiv:2405.21060) — Pallas TPU kernel.

TPU rethink of the SSD algorithm: the per-chunk work is two dense matmuls
(C B^T masked by the decay kernel, and the L x L score times the inputs) that
map straight onto the MXU, while the O(hd x d_state) inter-chunk state lives
in VMEM scratch and is carried across the sequential innermost grid dim —
the recurrence never touches HBM. Grid: (b, nh, n_chunks).

Layout: x [b,nh,S,hd]; dt [b,nh,S]; B,C [b,nh,S,ds]; A [nh] (ops transposes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_out_ref, state_scr,
    *, chunk, n_chunks,
):
    # a_ref is the scalar-prefetch input: the full [nh] A vector in SMEM
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)  # [L, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)  # [L]
    A = a_ref[pl.program_id(1)].astype(jnp.float32)  # this head's A (negative)
    B = b_ref[0, 0].astype(jnp.float32)  # [L, ds]
    C = c_ref[0, 0].astype(jnp.float32)  # [L, ds]

    dA = dt * A  # [L]
    cums = jnp.cumsum(dA)  # [L]
    # decay kernel: exp(cums_i - cums_j) for j <= i (segment sums)
    L = chunk
    diff = cums[:, None] - cums[None, :]
    tril = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    )
    decay = jnp.where(tril, jnp.exp(diff), 0.0)

    xa = x * dt[:, None]  # [L, hd]
    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * decay  # [L, L]
    y_intra = jax.lax.dot_general(
        scores, xa, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, hd]

    # inter-chunk: contribution of the state entering this chunk
    state = state_scr[...]  # [hd, ds] fp32
    y_inter = jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cums)[:, None]  # [L, hd]

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: decay old state to chunk end + inject this chunk
    total = jnp.exp(cums[-1])
    decay_to_end = jnp.exp(cums[-1] - cums)  # [L]
    inject = jax.lax.dot_general(
        xa, B * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [hd, ds]
    state_scr[...] = state * total + inject

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_scr[...]


def ssd_scan_bhsd(x, dt, A, B, C, *, chunk=128, interpret=False):
    """x: [b,nh,S,hd]; dt: [b,nh,S]; A: [nh]; B,C: [b,nh,S,ds].

    Returns (y [b,nh,S,hd], final_state [b,nh,hd,ds] fp32). S % chunk == 0
    (ops.py pads).
    """
    b, nh, S, hd = x.shape
    ds = B.shape[-1]
    n_chunks = S // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda i, h, c, a: (i, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda i, h, c, a: (i, h, c)),
            pl.BlockSpec((1, 1, chunk, ds), lambda i, h, c, a: (i, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, ds), lambda i, h, c, a: (i, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda i, h, c, a: (i, h, c, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda i, h, c, a: (i, h, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
    )
    y, state = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, S, hd), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, ds), jnp.float32),
        ],
        interpret=interpret,
    )(A, x, dt, B, C)
    return y, state
